"""Training substrate: optimizer schedules, microbatching equivalence,
gradient compression with error feedback, chunked CE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed.compression import compress_grads, quant_dequant
from repro.models import get_model
from repro.training.optimizer import (
    OptimizerConfig,
    adamw_update,
    init_opt_state,
    learning_rate,
)
from repro.training.train_loop import (
    TrainConfig,
    chunked_cross_entropy,
    cross_entropy,
    make_train_step,
)


class TestSchedules:
    def test_warmup_then_peak(self):
        cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                              schedule="constant")
        assert float(learning_rate(cfg, jnp.asarray(0))) < 1e-4 + 1e-9
        assert float(learning_rate(cfg, jnp.asarray(10))) == pytest.approx(1e-3)

    def test_cosine_decays_to_final_frac(self):
        cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, total_steps=100,
                              schedule="cosine", final_lr_frac=0.1)
        end = float(learning_rate(cfg, jnp.asarray(100)))
        assert end == pytest.approx(1e-4, rel=1e-3)

    def test_wsd_stable_then_decay(self):
        """MiniCPM's WSD: flat at peak for stable_frac, then decays."""
        cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=110,
                              schedule="wsd", stable_frac=0.8, final_lr_frac=0.1)
        mid = float(learning_rate(cfg, jnp.asarray(50)))
        assert mid == pytest.approx(1e-3, rel=1e-4)  # stable region
        end = float(learning_rate(cfg, jnp.asarray(110)))
        assert end == pytest.approx(1e-4, rel=1e-2)  # decayed

    def test_adamw_moves_params_and_clips(self):
        cfg = OptimizerConfig(grad_clip=1.0, peak_lr=1e-2)
        params = {"w": jnp.ones((4, 4), jnp.float32)}
        grads = {"w": jnp.full((4, 4), 100.0, jnp.float32)}  # must clip
        opt = init_opt_state(params)
        new_p, new_opt, m = adamw_update(cfg, params, grads, opt)
        assert float(m["grad_norm"]) == pytest.approx(400.0)
        assert int(new_opt["step"]) == 1
        assert not np.array_equal(np.asarray(new_p["w"], np.float32),
                                  np.asarray(params["w"], np.float32))


class TestMicrobatching:
    def test_grad_accum_matches_full_batch(self):
        cfg = ARCHS["minitron-4b"].reduced()
        bundle = get_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (4, 32)), jnp.int32)}

        def run(accum):
            tcfg = TrainConfig(optimizer=OptimizerConfig(peak_lr=1e-3),
                               grad_accum=accum)
            step = jax.jit(make_train_step(bundle, tcfg))
            state = {"params": params, "opt": init_opt_state(params),
                     "error_fb": None}
            new_state, metrics = step(state, batch)
            return float(metrics["loss"]), new_state["params"]

        l1, p1 = run(1)
        l2, p2 = run(4)
        assert l1 == pytest.approx(l2, rel=2e-2)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-3)


class TestChunkedCE:
    def test_matches_unchunked(self):
        rng = np.random.RandomState(1)
        B, S, D, V = 2, 70, 16, 50  # S not divisible by the chunk => padding
        hidden = jnp.asarray(rng.randn(B, S, D), jnp.float32)
        head = jnp.asarray(rng.randn(D, V), jnp.float32)
        targets = jnp.asarray(rng.randint(0, V, (B, S)), jnp.int32)
        want = cross_entropy(jnp.einsum("bsd,dv->bsv", hidden, head), targets)
        got = chunked_cross_entropy(
            lambda p, h: jnp.einsum("bsd,dv->bsv", h, p), head, hidden, targets
        )
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


class TestCompression:
    def test_quant_dequant_bounded_error(self):
        rng = np.random.RandomState(2)
        x = jnp.asarray(rng.randn(1000) * 5, jnp.float32)
        y = quant_dequant(x)
        err = np.abs(np.asarray(y) - np.asarray(x)).max()
        scale = np.abs(np.asarray(x)).max() / 127
        assert err <= scale * 1.01

    def test_error_feedback_preserves_sum(self):
        """Over many steps, Σ compressed ≈ Σ raw (EF carries the residual)."""
        rng = np.random.RandomState(3)
        g_raw = [jnp.asarray(rng.randn(256) * 0.1, jnp.float32) for _ in range(50)]
        e = {"g": jnp.zeros((256,), jnp.float32)}
        total_c = np.zeros(256, np.float32)
        total_r = np.zeros(256, np.float32)
        for g in g_raw:
            comp, e_new = compress_grads({"g": g}, e)
            e = e_new
            total_c += np.asarray(comp["g"])
            total_r += np.asarray(g)
        drift = np.abs(total_c + np.asarray(e["g"]) - total_r).max()
        assert drift < 1e-3
