"""Elastic topology + straggler policy + end-to-end host-failure drill."""

import numpy as np

from repro.data.pipeline import SyntheticTokenPipeline
from repro.training.elastic import HostTopology, StragglerPolicy


class TestHostTopology:
    def test_rebalance_after_failure(self):
        topo = HostTopology(["h0", "h1", "h2", "h3"])
        a = topo.rebalance(failed=["h2"], resume_step=100)
        assert set(a) == {"h0", "h1", "h3"}
        assert all(v.n_hosts == 3 and v.resume_step == 100 for v in a.values())
        assert sorted(v.host_id for v in a.values()) == [0, 1, 2]

    def test_join_after_replacement(self):
        topo = HostTopology(["h0", "h1"])
        a = topo.rebalance(joined=["h9"], resume_step=7)
        assert set(a) == {"h0", "h1", "h9"}

    def test_stream_is_exactly_the_smaller_jobs_stream(self):
        """After dropping a host, survivors produce the same global stream a
        fresh 3-host job would — the exactly-once contract."""
        topo = HostTopology(["h0", "h1", "h2", "h3"])
        assign = topo.rebalance(failed=["h3"], resume_step=5)

        def batch_for(host, step):
            a = assign[host]
            p = SyntheticTokenPipeline(
                vocab=100, seq_len=8, global_batch=6,
                host_id=a.host_id, n_hosts=a.n_hosts, seed=0,
            )
            p.state.step = step
            return p.next_batch()["tokens"]

        fresh = [
            SyntheticTokenPipeline(vocab=100, seq_len=8, global_batch=6,
                                   host_id=i, n_hosts=3, seed=0)
            for i in range(3)
        ]
        for f in fresh:
            f.state.step = 5
        for host, ref in zip(sorted(assign), fresh):
            np.testing.assert_array_equal(
                batch_for(host, 5), ref.next_batch()["tokens"]
            )


class TestStragglerPolicy:
    def test_flags_persistent_straggler_only(self):
        pol = StragglerPolicy(tolerance=2.0, patience=2)
        for step in range(4):
            for h in ("h0", "h1", "h2"):
                pol.record(h, 1.0)
            pol.record("slow", 5.0)
            pol.update_strikes()
        assert pol.stragglers() == ["slow"]
        assert "h0" not in pol.stragglers()

    def test_transient_blip_not_flagged(self):
        pol = StragglerPolicy(tolerance=2.0, patience=3)
        for h in ("h0", "h1", "h2"):
            pol.record(h, 1.0)
        pol.record("blip", 9.0)
        pol.update_strikes()
        for _ in range(3):
            for h in ("h0", "h1", "h2", "blip"):
                pol.record(h, 1.0)
            pol.update_strikes()
        assert pol.stragglers() == []

    def test_no_deadline_with_single_host(self):
        pol = StragglerPolicy()
        pol.record("h0", 1.0)
        assert pol.deadline_s() is None
