"""Storage-graph fsck tests: a healthy store is clean, and each injected
corruption is reported with the right rule and severity — no more, no less.

Corruption injections (one per test, each asserting the exact finding set):

* ``stored_base`` cycle        → ``fsck.cycle`` ERROR
* deleted object file          → ``fsck.missing-object`` ERROR
* bit-flipped stored payload   → ``fsck.unreadable`` ERROR (codec refuses)
* crafted wrong-content payload→ ``fsck.fingerprint`` ERROR (decodes fine,
  bytes differ — the cache-bypass case)
* dangling branch ref          → ``fsck.ref`` ERROR
* constraint drift post-repack → ``fsck.constraint`` ERROR
* orphaned object              → ``fsck.orphan-object`` WARNING
"""

import numpy as np
import pytest

from repro.analysis.cli import build_synthetic_store
from repro.analysis.findings import Severity
from repro.core import OptimizeSpec
from repro.store import Repository
from repro.store.delta import flatten_payload


def payload(seed: int, shape=(48, 32)):
    rng = np.random.RandomState(seed)
    return {
        "w": rng.randn(*shape).astype(np.float32),
        "b": rng.randn(shape[1]).astype(np.float32),
    }


@pytest.fixture()
def repo(tmp_path):
    """Four versions forming a delta chain: v1 full, v2..v4 incremental
    edits (small enough that the commit path picks delta storage)."""
    r = Repository(tmp_path / "store")
    tree = payload(0)
    r.commit(tree, message="c0")
    for i in range(1, 4):
        tree = dict(tree)
        w = tree["w"].copy()
        w[i, :4] += 1.0
        tree["w"] = w
        r.commit(tree, message=f"c{i}")
    assert r.store.versions[4].stored_base == 3
    return r


def rule_map(report):
    """{rule: [(subject, severity)]} over the report's findings."""
    out = {}
    for f in report.findings:
        out.setdefault(f.rule, []).append((f.subject, f.severity))
    return out


def _obj_path(store, vid):
    return store.objects._path(store.versions[vid].object_key)


class TestCleanStores:
    def test_fresh_store_is_clean(self, repo):
        assert repo.fsck().findings == []

    def test_synthetic_store_is_clean(self, tmp_path):
        # the CI self-check fixture: commits + branch + tag + repack
        r = build_synthetic_store(tmp_path / "syn")
        report = r.fsck()
        assert report.findings == [], \
            "\n".join(f.render() for f in report.findings)
        # the repack recorded its constraint, and fsck re-validated it
        assert r.store.last_repack["constraints"] == [
            {"metric": "max_recreation", "bound": 10.0}
        ]
        assert report.checked["fsck.constraint"] == 1

    def test_sampling_still_covers_endpoints(self, repo):
        report = repo.fsck(sample=2)
        assert report.findings == []
        assert report.checked["fsck.fingerprint"] == 2


class TestInjectedCorruption:
    def test_stored_base_cycle(self, repo):
        store = repo.store
        a, b = 3, 4
        store.versions[a].stored_base = b
        store.versions[b].stored_base = a
        rm = rule_map(store.fsck())
        assert rm["fsck.cycle"] == [(f"v{a}", Severity.ERROR)]
        # cycle members are excluded from decoding, not double-reported
        assert "fsck.unreadable" not in rm and "fsck.fingerprint" not in rm

    def test_deleted_object_file(self, repo):
        store = repo.store
        _obj_path(store, 2).unlink()
        rm = rule_map(store.fsck())
        assert rm["fsck.missing-object"] == [("v2", Severity.ERROR)]
        # v2's chain descendants can't be decoded, but the one root cause
        # is the only error reported
        assert "fsck.unreadable" not in rm

    def test_bit_flipped_payload_is_unreadable(self, repo):
        store = repo.store
        p = _obj_path(store, 1)
        blob = bytearray(p.read_bytes())
        blob[len(blob) // 2] ^= 0xFF
        p.write_bytes(bytes(blob))
        rm = rule_map(store.fsck())
        subjects = dict(rm["fsck.unreadable"])
        assert subjects["v1"] == Severity.ERROR

    def test_wrong_content_payload_flunks_fingerprint(self, repo):
        # decodes cleanly but bytes differ — only the recomputed content
        # fingerprint can catch this (the materialization cache key cannot:
        # the storage-graph triples are unchanged)
        store = repo.store
        tip = max(store.versions)
        assert store.versions[tip].stored_base is not None
        tampered = dict(store.checkout(tip))
        w = tampered["w"].copy()
        w[0, 0] += 1.0
        tampered["w"] = w
        from repro.store.delta import encode_delta

        base_tree = store.checkout(store.versions[tip].stored_base)
        payload_bytes, _ = encode_delta(base_tree, flatten_payload(tampered))
        _obj_path(store, tip).write_bytes(
            store.objects.codec.compress(payload_bytes)
        )
        rm = rule_map(store.fsck())
        assert rm["fsck.fingerprint"] == [(f"v{tip}", Severity.ERROR)]
        assert "fsck.unreadable" not in rm

    def test_dangling_branch_ref(self, repo):
        repo.store.refs["branches"]["ghost"] = 999
        rm = rule_map(repo.fsck())
        assert rm["fsck.ref"] == [("branch:ghost", Severity.ERROR)]

    def test_head_naming_missing_branch(self, repo):
        repo.store.refs["head"] = "nope"
        rm = rule_map(repo.fsck())
        assert rm["fsck.ref"] == [("head:nope", Severity.ERROR)]

    def test_constraint_drift_after_repack(self, repo):
        store = repo.store
        repo.repack(OptimizeSpec.problem(6, theta=10.0))
        m = max(store.recreation_cost(v) for v in store.versions)
        # tighten the recorded bound to just above what the repacked graph
        # achieves — the agreed SLA the commits below will drift past (each
        # delta hop adds at least the cost model's per-object seek latency)
        store.last_repack["constraints"] = [
            {"metric": "max_recreation", "bound": m + 0.004}
        ]
        assert repo.fsck().findings == []  # bound honored right after repack
        tree = dict(repo.checkout())
        for i in range(3):
            tree = dict(tree)
            w = tree["w"].copy()
            w[i] += 1.0
            tree["w"] = w
            repo.commit(tree, message=f"drift {i}")
        assert store.versions[max(store.versions)].stored_base is not None
        rm = rule_map(repo.fsck())
        assert rm["fsck.constraint"] == [
            ("constraint:max_recreation", Severity.ERROR)
        ]

    def test_orphan_object_warns_and_gc_clears(self, repo):
        store = repo.store
        store.objects.put(b"orphaned bytes that no version references")
        rm = rule_map(store.fsck())
        ((subject, sev),) = rm["fsck.orphan-object"]
        assert sev == Severity.WARNING and subject.startswith("object:")
        store.gc()
        assert store.fsck().findings == []

    def test_dangling_parent_and_base(self, repo):
        store = repo.store
        store.versions[4].parents = [99]
        store.versions[3].stored_base = 42
        rm = rule_map(store.fsck())
        assert rm["fsck.dangling-parent"] == [("v4", Severity.ERROR)]
        assert rm["fsck.dangling-base"] == [("v3", Severity.ERROR)]


class TestLastRepackPersistence:
    def test_round_trips_through_metadata(self, tmp_path):
        from repro.store import VersionStore

        root = tmp_path / "s"
        repo = Repository(root)
        for i in range(3):
            repo.commit(payload(i))
        repo.repack(OptimizeSpec.problem(6, theta=10.0))
        lr = repo.store.last_repack
        assert lr["problem"] == 6 and lr["objective"] == "storage"
        repo.close()

        reopened = VersionStore(root)
        assert reopened.last_repack == lr
        # and fsck still validates the recorded constraint after reload
        report = reopened.fsck()
        assert report.findings == []
        assert report.checked["fsck.constraint"] == 1

    def test_unrepacked_store_has_no_record(self, repo):
        assert repo.store.last_repack is None
        assert "fsck.constraint" not in repo.fsck().checked
