"""Integration tests: object store, delta codec, version store, repack,
versioned checkpointing with elastic restore."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import PreemptionGuard, VersionedCheckpointManager
from repro.store import (
    ObjectStore,
    VersionStore,
    apply_delta,
    decode_full,
    encode_delta,
    encode_full,
    flatten_payload,
)


def make_payload(rng, scale=1.0, shape=(64, 96)):
    return {
        "w": rng.randn(*shape).astype(np.float32) * scale,
        "b": rng.randn(shape[1]).astype(np.float32),
        "emb": {"table": rng.randn(128, 32).astype(np.float32)},
    }


def perturb(payload, rng, frac=0.05):
    """Touch a small contiguous slice of each tensor (a localized edit)."""
    out = jax.tree.map(lambda x: x.copy(), payload)
    n = max(1, int(out["w"].shape[0] * frac))
    out["w"][:n] += rng.randn(n, out["w"].shape[1]).astype(np.float32)
    return out


class TestObjectStore:
    def test_roundtrip_and_dedup(self, tmp_path):
        st = ObjectStore(tmp_path)
        k1, s1 = st.put(b"hello world" * 1000)
        k2, s2 = st.put(b"hello world" * 1000)
        assert k1 == k2
        assert st.get(k1) == b"hello world" * 1000
        assert s1 < 11000  # zstd compressed
        assert len(list(st.keys())) == 1
        st.delete(k1)
        assert not st.exists(k1)


class TestDeltaCodec:
    def test_full_roundtrip(self):
        rng = np.random.RandomState(0)
        flat = flatten_payload(make_payload(rng))
        rec = decode_full(encode_full(flat))
        assert set(rec) == set(flat)
        for k in flat:
            np.testing.assert_array_equal(rec[k], flat[k])

    def test_delta_roundtrip_localized_edit(self):
        rng = np.random.RandomState(1)
        base = flatten_payload(make_payload(rng))
        new = flatten_payload(perturb(make_payload(rng), np.random.RandomState(1)))
        # rebuild identical base for a valid delta
        base = flatten_payload(make_payload(np.random.RandomState(1)))
        new_p = perturb(make_payload(np.random.RandomState(1)), np.random.RandomState(2))
        new = flatten_payload(new_p)
        payload, stats = encode_delta(base, new)
        assert stats["changed_blocks"] < stats["total_blocks"]
        rec = apply_delta(base, payload)
        for k in new:
            np.testing.assert_array_equal(rec[k], new[k])

    def test_delta_much_smaller_than_full(self):
        rng = np.random.RandomState(2)
        base_p = make_payload(rng, shape=(512, 512))
        new_p = perturb(base_p, rng, frac=0.02)
        base, new = flatten_payload(base_p), flatten_payload(new_p)
        d, _ = encode_delta(base, new)
        f = encode_full(new)
        assert len(d) < 0.2 * len(f)

    def test_delta_handles_new_and_deleted_leaves(self):
        rng = np.random.RandomState(3)
        base = flatten_payload(make_payload(rng))
        new = dict(base)
        del new["b"]
        new["extra"] = rng.randn(7, 7).astype(np.float32)
        payload, _ = encode_delta(base, new)
        rec = apply_delta(base, payload)
        assert "b" not in rec and "extra" in rec
        np.testing.assert_array_equal(rec["extra"], new["extra"])

    def test_delta_handles_reshaped_leaf(self):
        rng = np.random.RandomState(4)
        base = flatten_payload(make_payload(rng))
        new = dict(base)
        new["w"] = rng.randn(10, 10).astype(np.float32)
        rec = apply_delta(base, encode_delta(base, new)[0])
        np.testing.assert_array_equal(rec["w"], new["w"])


def build_linear_history(store, n=6, shape=(256, 256), seed=0):
    rng = np.random.RandomState(seed)
    payload = make_payload(rng, shape=shape)
    vids = [store.commit(payload, message="v1")]
    for i in range(n - 1):
        payload = perturb(payload, rng, frac=0.03)
        vids.append(store.commit(payload, parents=[vids[-1]], message=f"v{i+2}"))
    return vids, payload


class TestVersionStore:
    def test_commit_checkout_chain(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, last_payload = build_linear_history(store)
        rec = store.checkout(vids[-1])
        want = flatten_payload(last_payload)
        for k in want:
            np.testing.assert_array_equal(rec[k], want[k])
        # deltas must be much smaller than fulls
        metas = store.log()
        assert metas[0].stored_base is None
        assert all(m.stored_base is not None for m in metas[1:])
        assert sum(m.stored_bytes for m in metas[1:]) < metas[0].stored_bytes

    def test_branch_and_merge(self, tmp_path):
        store = VersionStore(tmp_path)
        rng = np.random.RandomState(1)
        p0 = make_payload(rng)
        v1 = store.commit(p0)
        pa = perturb(p0, rng)
        va = store.commit(pa, parents=[v1])
        pb = perturb(p0, rng)
        vb = store.commit(pb, parents=[v1])
        merged = jax.tree.map(lambda a, b: (a + b) / 2, pa, pb)
        vm = store.commit(merged, parents=[va, vb])
        rec = store.checkout(vm)
        want = flatten_payload(merged)
        for k in want:
            np.testing.assert_array_equal(rec[k], want[k])
        assert store.versions[vm].parents == [va, vb]

    def test_persistence_across_reopen(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, last_payload = build_linear_history(store, n=3)
        del store
        store2 = VersionStore(tmp_path)
        rec = store2.checkout(vids[-1])
        want = flatten_payload(last_payload)
        for k in want:
            np.testing.assert_array_equal(rec[k], want[k])

    @pytest.mark.parametrize("solver,kw", [
        ("mca", {}),
        ("spt", {}),
        ("last", {"alpha": 2.0}),
        ("gith", {"window": 5, "max_depth": 5}),
    ])
    def test_repack_preserves_contents(self, tmp_path, solver, kw):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=5)
        originals = {v: store.checkout(v) for v in vids}
        store.repack(solver, **kw)
        for v in vids:
            rec = store.checkout(v)
            for k in originals[v]:
                np.testing.assert_array_equal(rec[k], originals[v][k])

    def test_repack_spt_materializes_everything(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=4)
        stats = store.repack("spt")
        assert all(m.stored_base is None for m in store.log())
        assert stats["after"]["max_recreation_s"] <= stats["before"]["max_recreation_s"] + 1e-9

    def test_repack_mp_enforces_theta(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=8)
        # find the SPT bound then ask for 1.5x it
        g, _ = store.build_cost_graph()
        from repro.core import shortest_path_tree
        theta = shortest_path_tree(g).max_recreation() * 1.5
        store.repack("mp", theta=theta)
        worst = max(store.recreation_cost(v) for v in store.versions)
        assert worst <= theta + 1e-9

    def test_repack_lmg_under_budget(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=8)
        g, _ = store.build_cost_graph()
        from repro.core import minimum_storage_tree
        budget = minimum_storage_tree(g).storage_cost() * 1.5
        before_sum = sum(store.recreation_cost(v) for v in store.versions)
        store.repack("lmg", budget=budget)
        after_sum = sum(store.recreation_cost(v) for v in store.versions)
        assert after_sum <= before_sum + 1e-9

    def test_gc_reclaims_orphans(self, tmp_path):
        store = VersionStore(tmp_path)
        build_linear_history(store, n=4)
        store.repack("spt")  # rewrites everything as fulls
        # gc ran inside repack: only live objects remain
        live = {m.object_key for m in store.log()}
        assert set(store.objects.keys()) == live

    def test_repack_empty_store(self, tmp_path):
        # regression: used to crash on max() over the empty version set
        store = VersionStore(tmp_path)
        stats = store.repack("spt")
        zero = {"storage_bytes": 0, "sum_recreation_s": 0.0,
                "max_recreation_s": 0.0}
        assert stats == {"before": zero, "after": zero, "gc_freed_bytes": 0}
        assert store.versions == {}

    def test_content_fp_stable_across_checkout_reencode(self, tmp_path):
        # regression: encode_full serialized leaves in dict insertion order,
        # so a checkout (base-order + appended full leaves) re-encoded to
        # different bytes than the commit path and the fp-keyed Δ/Φ edge
        # cache was spuriously invalidated
        import hashlib

        store = VersionStore(tmp_path)
        rng = np.random.RandomState(0)
        p1 = make_payload(rng)
        v1 = store.commit(p1, message="v1")
        # v2 must be stored as a *delta* (checkout then rebuilds the tree in
        # apply_delta order): small perturbation + one added leaf whose key
        # sorts before the base keys
        p2 = perturb(p1, rng, frac=0.03)
        p2["aa_added"] = rng.randn(16).astype(np.float32)
        v2 = store.commit(p2, parents=[v1], message="v2")
        assert store.versions[v2].stored_base == v1
        for v in (v1, v2):
            flat = store.checkout(v)
            refp = hashlib.sha256(encode_full(flat)).hexdigest()
            assert refp == store.versions[v].content_fp


class TestVersionedCheckpointing:
    def _state(self, rng):
        return {
            "params": {"w": jnp.asarray(rng.randn(128, 64), jnp.float32)},
            "opt": {"mu": jnp.asarray(rng.randn(128, 64), jnp.float32),
                    "nu": jnp.asarray(rng.randn(128, 64), jnp.float32)},
            "step": jnp.asarray(0, jnp.int32),
        }

    def test_save_restore_roundtrip(self, tmp_path):
        rng = np.random.RandomState(0)
        mgr = VersionedCheckpointManager(tmp_path)
        state = self._state(rng)
        for step in range(3):
            state = jax.tree.map(
                lambda x: x + 1 if x.dtype == jnp.int32 else x * 1.01, state
            )
            mgr.save(step, state)
        mgr.wait()
        restored = mgr.restore(template=state)
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.latest_step() == 2
        mgr.close()

    def test_restore_with_resharding(self, tmp_path):
        rng = np.random.RandomState(1)
        mgr = VersionedCheckpointManager(tmp_path)
        state = self._state(rng)
        mgr.save(0, state, blocking=True)
        sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        restored = mgr.restore(template=state, shardings=sharding)
        assert all(
            leaf.sharding == sharding for leaf in jax.tree.leaves(restored)
        )
        mgr.close()

    def test_emergency_save_is_synchronous(self, tmp_path):
        rng = np.random.RandomState(2)
        mgr = VersionedCheckpointManager(tmp_path)
        guard = PreemptionGuard()
        state = self._state(rng)
        guard.trigger()
        assert guard.preempted
        vid = mgr.emergency_save(7, state)
        assert mgr.store.versions[vid].message.startswith("EMERGENCY")
        restored = mgr.restore(template=state, step=7)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
        )
        mgr.close()

    def test_repack_enforces_restore_sla(self, tmp_path):
        rng = np.random.RandomState(3)
        mgr = VersionedCheckpointManager(tmp_path, max_restore_cost_s=10.0)
        state = self._state(rng)
        for step in range(6):
            state = jax.tree.map(lambda x: x * 1.001, state)
            mgr.save(step, state)
        mgr.wait()
        stats = mgr.repack()
        assert stats["after"]["max_recreation_s"] <= 10.0
        restored = mgr.restore(template=state)
        np.testing.assert_array_equal(
            np.asarray(restored["opt"]["mu"]), np.asarray(state["opt"]["mu"])
        )
        mgr.close()
