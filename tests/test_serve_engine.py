"""ServeEngine regression tests.

The enc-dec cache-shape bug: ``generate()`` used to re-initialize the
enc-dec cache with ``enc_len=self.max_len`` instead of the ``enc_len`` the
engine was constructed with, so the *second* generate on an encoder-decoder
model ran against a cache of different shapes — silently retriggering XLA
compilation and decoding against a wrong-length encoder output.
"""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import get_model
from repro.serving.engine import ServeEngine

B, ENC_LEN, PROMPT, MAX_LEN = 2, 8, 4, 16


@pytest.fixture(scope="module")
def encdec_engine():
    cfg = ARCHS["seamless-m4t-medium"].reduced()
    assert cfg.is_encdec
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model, params, max_len=MAX_LEN, batch=B, enc_len=ENC_LEN
    )
    return cfg, engine


def _batch(cfg, rng):
    import jax.numpy as jnp

    return {
        "frames": jnp.asarray(
            rng.randn(B, ENC_LEN, cfg.d_model), jnp.bfloat16
        ),
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab, (B, PROMPT)), jnp.int32
        ),
    }


def _shapes(tree):
    return jax.tree.map(lambda a: tuple(a.shape), tree)


class TestEncDecCacheShapes:
    def test_cache_shapes_stable_across_generates(self, encdec_engine):
        cfg, engine = encdec_engine
        before = _shapes(engine._cache0)
        res = engine.generate(_batch(cfg, np.random.RandomState(0)),
                              max_new_tokens=3)
        assert res.tokens.shape == (B, 3)
        after = _shapes(engine._cache0)
        assert before == after, (
            "generate() rebuilt the enc-dec cache with different shapes — "
            "enc_len drifted from the constructor value"
        )

    def test_second_generate_works(self, encdec_engine):
        cfg, engine = encdec_engine
        res = engine.generate(_batch(cfg, np.random.RandomState(1)),
                              max_new_tokens=2)
        assert res.tokens.shape == (B, 2)
        assert np.all(res.tokens >= 0)
