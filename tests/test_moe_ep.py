"""EP (shard_map all_to_all) MoE vs the pjit dispatch baseline.

Numerical equivalence needs a real multi-device mesh, so the check runs in a
subprocess with forced host devices (the main pytest process has already
locked jax to 1 device).
"""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCHS
    from repro.distributed.sharding import logical_sharding
    from repro.models import moe as moe_lib
    from repro.models import moe_ep

    if hasattr(jax.sharding, "AxisType"):
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    else:  # older jax: axes are Auto by default and axis_types doesn't exist
        mesh = jax.make_mesh((2, 4), ("data", "model"))

    cfg = dataclasses.replace(
        ARCHS["phi3.5-moe-42b-a6.6b"].reduced(),
        n_experts=8, top_k=2, expert_d_ff=32, d_model=64,
        capacity_factor=4.0,  # drop-free so both impls agree exactly
        n_shared_experts=1,
    )
    rng = np.random.RandomState(0)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    p = {
        "router": jnp.asarray(rng.randn(D, E), jnp.float32) * 0.1,
        "w_gate": jnp.asarray(rng.randn(E, D, F), jnp.bfloat16) * 0.1,
        "w_up": jnp.asarray(rng.randn(E, D, F), jnp.bfloat16) * 0.1,
        "w_down": jnp.asarray(rng.randn(E, F, D), jnp.bfloat16) * 0.1,
        "shared_w_gate": jnp.asarray(rng.randn(D, F), jnp.bfloat16) * 0.1,
        "shared_w_up": jnp.asarray(rng.randn(D, F), jnp.bfloat16) * 0.1,
        "shared_w_down": jnp.asarray(rng.randn(F, D), jnp.bfloat16) * 0.1,
    }
    B, S = 4, 16
    x = jnp.asarray(rng.randn(B, S, D), jnp.bfloat16) * 0.5

    base = jax.jit(lambda p, x: moe_lib.moe_block(cfg, p, x))(p, x)

    with logical_sharding(mesh):
        ep_fn = jax.jit(lambda p, x: moe_ep.moe_block_ep(cfg, p, x))
        got = ep_fn(p, x)

    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(base, np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # gradients must also agree (the shard_map AD path)
    def loss_ep(p, x):
        with logical_sharding(mesh):
            return jnp.sum(moe_ep.moe_block_ep(cfg, p, x).astype(jnp.float32) ** 2)

    def loss_base(p, x):
        return jnp.sum(moe_lib.moe_block(cfg, p, x).astype(jnp.float32) ** 2)

    g_ep = jax.grad(loss_ep)(p, x)
    g_b = jax.grad(loss_base)(p, x)
    for k in ("w_gate", "w_down", "router", "shared_w_down"):
        np.testing.assert_allclose(
            np.asarray(g_ep[k], np.float32), np.asarray(g_b[k], np.float32),
            rtol=5e-2, atol=5e-2,
        )
    print("EP==dispatch OK")
""")


def test_ep_matches_dispatch_on_8dev_mesh():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "EP==dispatch OK" in out.stdout
