"""Regression tests for sparse_encode capacity overflow.

An explicit undersized ``capacity`` used to silently drop the overflowing
changed blocks in ``_compact``'s drop-mode scatter, producing a corrupt
delta that ``sparse_apply`` could not detect.  Kept separate from
``test_kernels.py``, whose module-level hypothesis gate skips the whole
file on containers without hypothesis.
"""

import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.block_diff import changed_block_mask


def _pair(nb=32, changed=12, seed=3):
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    a = jnp.asarray(
        rng.randint(-(2**31), 2**31, size=(nb, 8, 128), dtype=np.int64)
        .astype(np.int32)
    )
    b = a
    for r in range(changed):
        b = b.at[r, 0, 0].add(1)
    return a, b, changed


class TestSparseEncodeCapacity:
    def test_undersized_capacity_raises(self):
        a, b, changed = _pair()
        with pytest.raises(ValueError, match="capacity overflow"):
            ops.sparse_encode(a, b, capacity=changed // 2)

    def test_true_changed_count_propagates_from_compact(self):
        # the traced compaction itself must report the *true* count so
        # fully-traced callers can detect the overflow
        a, b, changed = _pair()
        mask = changed_block_mask(a, b)
        _, _, n = ops._compact(mask, b, capacity=4)
        assert int(n) == changed

    def test_sufficient_capacity_roundtrips(self):
        a, b, changed = _pair()
        idx, blocks, n = ops.sparse_encode(a, b, capacity=16)
        assert n == changed
        rec = ops.sparse_apply(a, blocks, idx)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(b))

    def test_auto_capacity_unaffected(self):
        a, b, changed = _pair()
        idx, blocks, n = ops.sparse_encode(a, b)
        assert n == changed
        rec = ops.sparse_apply(a, blocks, idx)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(b))
