"""Array-native core refactor tests.

* ``EdgeArrays`` unit tests: canonical layout, dedup-keep-last, lookups.
* Property tests: on ≥50 random instances the vectorized MST / SPT / LMG /
  MP solvers reproduce the seed implementations (``reference_solvers.py``)
  exactly — same trees, same storage/recreation costs.
* ``generate_flat``: structural sanity of array-native synthetic instances.
* ``VersionStore``: incremental Δ/Φ measurement (second ``build_cost_graph``
  measures nothing), repack idempotence, checkout roundtrip after repack
  with every solver, and the zlib codec fallback.
"""

import random

import numpy as np
import pytest

from repro.core import (
    EdgeArrays,
    VersionGraph,
    WorkloadSpec,
    dc_like,
    generate,
    generate_flat,
    lc_like,
    local_move_greedy,
    minimum_storage_tree,
    modified_prim,
    shortest_path_tree,
)
from reference_solvers import (
    ref_local_move_greedy,
    ref_minimum_storage_tree,
    ref_modified_prim,
    ref_shortest_path_tree,
)


# ------------------------------------------------------------------ arrays
class TestEdgeArrays:
    def test_layout_sorted_and_csr(self):
        g = VersionGraph(3)
        g.set_materialization(1, 10, 10)
        g.set_materialization(2, 20, 20)
        g.set_materialization(3, 30, 30)
        g.set_delta(2, 1, 5, 5)
        g.set_delta(1, 2, 7, 7)
        g.set_delta(1, 3, 9, 9)
        ea = g.arrays()
        assert ea.m == 6
        # sorted by (src, dst)
        pairs = list(zip(ea.src.tolist(), ea.dst.tolist()))
        assert pairs == sorted(pairs)
        s, e = ea.out_range(1)
        assert ea.dst[s:e].tolist() == [2, 3]
        assert set(ea.src[ea.in_edge_ids(1)].tolist()) == {0, 2}

    def test_dedup_keeps_last_write(self):
        g = VersionGraph(2)
        g.set_materialization(1, 1, 1)
        g.set_materialization(2, 1, 1)
        g.set_delta(1, 2, 100, 100)
        g.set_delta(1, 2, 42, 43)  # overwrite, dict-style
        ea = g.arrays()
        assert ea.m == 3
        c = g.cost(1, 2)
        assert (c.delta, c.phi) == (42.0, 43.0)

    def test_lookup_many_and_missing(self):
        g = VersionGraph(4)
        for i in g.versions():
            g.set_materialization(i, i * 10, i * 10)
        g.set_delta(1, 2, 3, 3)
        ea = g.arrays()
        eid = ea.lookup_many(
            np.array([0, 1, 2, 3]), np.array([4, 2, 1, 1])
        )
        assert eid[0] >= 0 and eid[1] >= 0
        assert eid[2] == -1 and eid[3] == -1
        assert ea.lookup(9999 % 5, 1) == ea.lookup(4, 1)  # in-range form only

    def test_mutation_invalidates_cache(self):
        g = VersionGraph(2)
        g.set_materialization(1, 1, 1)
        g.set_materialization(2, 2, 2)
        assert g.n_edges == 2
        g.set_delta(1, 2, 5, 5)
        assert g.n_edges == 3

    def test_bulk_load_matches_scalar(self):
        g1 = VersionGraph(3)
        g2 = VersionGraph(3)
        edges = [(0, 1, 10.0, 11.0), (0, 2, 20.0, 21.0), (0, 3, 30.0, 31.0),
                 (1, 2, 1.0, 2.0), (2, 3, 3.0, 4.0)]
        for s, d, dl, ph in edges:
            if s == 0:
                g1.set_materialization(d, dl, ph)
            else:
                g1.set_delta(s, d, dl, ph)
        arr = np.array(edges)
        g2.add_edges_bulk(
            arr[:, 0].astype(np.int64), arr[:, 1].astype(np.int64),
            arr[:, 2], arr[:, 3],
        )
        a1, a2 = g1.arrays(), g2.arrays()
        np.testing.assert_array_equal(a1.src, a2.src)
        np.testing.assert_array_equal(a1.dst, a2.dst)
        np.testing.assert_array_equal(a1.delta, a2.delta)
        np.testing.assert_array_equal(a1.phi, a2.phi)

    def test_bulk_rejects_bad_ids(self):
        g = VersionGraph(2)
        with pytest.raises(ValueError):
            g.add_edges_bulk(
                np.array([0]), np.array([3]), np.array([1.0]), np.array([1.0])
            )
        with pytest.raises(ValueError):
            g.add_edges_bulk(
                np.array([1]), np.array([1]), np.array([1.0]), np.array([1.0])
            )


# ------------------------------------------------- reference-match property
def _random_graph(seed: int) -> VersionGraph:
    """Dense-ish random instance in the style of the seed hypothesis tests."""
    rng = random.Random(seed)
    n = rng.randint(6, 24)
    directed = bool(seed % 2)
    g = VersionGraph(n, directed=directed)
    for i in g.versions():
        size = rng.uniform(100, 10000)
        g.set_materialization(i, size, size * rng.uniform(0.5, 2.0))
    for i in g.versions():
        for j in g.versions():
            if (i >= j) if not directed else (i == j):
                continue
            if rng.random() < 0.45:
                d = rng.uniform(1, 2000)
                g.set_delta(i, j, d, d * rng.uniform(0.5, 2.0))
    return g


def _instances():
    """56 random instances: 4 synthetic families × 8 seeds + 24 random."""
    out = []
    for seed in range(8):
        out.append(generate(dc_like(50 + 5 * seed, seed=seed)).graph)
        out.append(generate(lc_like(50 + 5 * seed, seed=seed + 100)).graph)
        out.append(
            generate(
                WorkloadSpec(commits=40 + 4 * seed, seed=seed + 200,
                             phi_independent=True)
            ).graph
        )
        out.append(
            generate(
                WorkloadSpec(commits=40 + 4 * seed, seed=seed + 300,
                             directed=False)
            ).graph
        )
    out.extend(_random_graph(s) for s in range(24))
    return out


@pytest.fixture(scope="module")
def instances():
    return _instances()


class TestVectorizedMatchesSeed:
    """The acceptance bar: identical solutions on ≥50 random instances."""

    def test_instance_count(self, instances):
        assert len(instances) >= 50

    def test_mst_exact_match(self, instances):
        for g in instances:
            new = minimum_storage_tree(g)
            ref = ref_minimum_storage_tree(g)
            assert new.parent == ref.parent
            assert new.storage_cost() == ref.storage_cost()

    def test_spt_exact_match(self, instances):
        for g in instances:
            new = shortest_path_tree(g)
            ref = ref_shortest_path_tree(g)
            assert new.parent == ref.parent
            assert new.recreation_costs() == ref.recreation_costs()

    def test_lmg_exact_match(self, instances):
        for g in instances:
            base = minimum_storage_tree(g)
            for mult in (1.05, 1.35):
                budget = base.storage_cost() * mult
                new = local_move_greedy(g, budget)
                ref = ref_local_move_greedy(g, budget)
                assert new.parent == ref.parent, f"budget mult {mult}"
                assert new.storage_cost() == ref.storage_cost()
                assert new.sum_recreation() == ref.sum_recreation()

    def test_mp_exact_match(self, instances):
        for g in instances:
            spt_max = shortest_path_tree(g).max_recreation()
            for mult in (1.2, 2.5):
                theta = spt_max * mult
                new = modified_prim(g, theta)
                ref = ref_modified_prim(g, theta)
                assert new.parent == ref.parent, f"theta mult {mult}"
                assert new.storage_cost() == ref.storage_cost()
                assert new.max_recreation() == ref.max_recreation()

    def test_lmg_workload_aware_invariants(self, instances):
        # weighted masses accumulate in a different float order than the seed,
        # so the weighted variant asserts invariants rather than bit-equality
        from repro.core import zipf_weights

        for g in instances[:8]:
            w = zipf_weights(g.n, seed=3)
            base = minimum_storage_tree(g)
            budget = base.storage_cost() * 1.3
            sol = local_move_greedy(g, budget, weights=w)
            sol.validate()
            assert sol.storage_cost() <= budget + 1e-6
            assert sol.sum_recreation(w) <= base.sum_recreation(w) + 1e-6


# ------------------------------------------------------------ generate_flat
class TestGenerateFlat:
    @pytest.mark.parametrize("directed", [True, False])
    def test_structure(self, directed):
        wl = generate_flat(
            WorkloadSpec(commits=300, seed=5, reveal_hops=4, directed=directed)
        )
        g = wl.graph
        assert g.n == 300
        assert g.has_all_materializations()
        assert wl.blocks is None
        mst = minimum_storage_tree(g)
        spt = shortest_path_tree(g)
        mst.validate()
        spt.validate()
        assert mst.storage_cost() <= spt.storage_cost() + 1e-6

    def test_solvers_agree_with_reference_on_flat_instance(self):
        g = generate_flat(WorkloadSpec(commits=120, seed=9, reveal_hops=3)).graph
        assert minimum_storage_tree(g).parent == ref_minimum_storage_tree(g).parent
        assert shortest_path_tree(g).parent == ref_shortest_path_tree(g).parent
        base = minimum_storage_tree(g)
        budget = base.storage_cost() * 1.2
        assert (
            local_move_greedy(g, budget).parent
            == ref_local_move_greedy(g, budget).parent
        )

    def test_scales_without_block_dicts(self):
        wl = generate_flat(
            WorkloadSpec(commits=5000, seed=1, reveal_hops=2)
        )
        assert wl.graph.n == 5000
        assert wl.graph.n_edges > 5000  # materializations + revealed deltas


# ------------------------------------------------- reveal-ball equivalence
def _naive_reveal_ball(parents, added, deleted, n, hops, directed):
    """The scalar per-source BFS `_reveal_ball_arrays` replaced, kept as the
    semantic oracle: source-major, hops ascending, first reach wins in
    (frontier-position, adjacency-order), volumes accumulated one float add
    per hop — the vectorized expansion must be bit-equal to this."""
    adj = {x: [] for x in range(n + 1)}
    for v, ps in parents.items():
        av, dv = float(added[v]), float(deleted[v])
        for p in ps:
            adj[p].append((v, av, dv))   # descend into v
            adj[v].append((p, dv, av))   # ascend out of v
    out = []
    for s in range(1, n + 1):
        seen = {s}
        frontier = [(s, 0.0, 0.0)]
        for _ in range(hops):
            nxt = []
            for node, fw, bw in frontier:
                for nbr, stepf, stepb in adj[node]:
                    if nbr in seen:
                        continue
                    seen.add(nbr)
                    nxt.append((nbr, fw + stepf, bw + stepb))
            if not nxt:
                break
            frontier = nxt
            for node, fw, bw in frontier:
                if directed or s < node:
                    out.append((s, node, fw, bw))
    return out


class TestRevealBallArrays:
    @pytest.mark.parametrize("directed", [True, False])
    def test_matches_naive_per_source_bfs(self, directed):
        from repro.core.synthetic import _build_dag, _reveal_ball_arrays

        for seed, commits, hops in [(0, 40, 3), (3, 77, 4), (7, 120, 2)]:
            spec = WorkloadSpec(
                commits=commits, seed=seed, reveal_hops=hops,
                directed=directed, branch_prob=0.6, branch_interval=3,
            )
            parents = _build_dag(spec, random.Random(spec.seed))
            n = len(parents)
            nrng = np.random.default_rng(seed + 100)
            added = np.zeros(n + 1)
            deleted = np.zeros(n + 1)
            added[1:] = nrng.uniform(10.0, 1000.0, n)
            deleted[1:] = nrng.uniform(5.0, 500.0, n)
            src, dst, fwd, bwd = _reveal_ball_arrays(
                parents, added, deleted, n, hops, directed
            )
            got = list(zip(src.tolist(), dst.tolist(), fwd.tolist(),
                           bwd.tolist()))
            ref = _naive_reveal_ball(parents, added, deleted, n, hops,
                                     directed)
            assert got == ref  # order AND bit-exact float accumulation
