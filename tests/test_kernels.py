"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode).

Sweeps shapes and dtypes per the deliverable spec; hypothesis drives the
property tests (round-trips, idempotence, oracle equivalence).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_diff import block_hash, changed_block_mask, hash_coefficients
from repro.kernels.sparse_apply import sparse_delta_apply
from repro.kernels.xor_delta import xor_delta


def rand_blocks(rng, nb):
    return jnp.asarray(
        rng.randint(-(2**31), 2**31, size=(nb, 8, 128), dtype=np.int64).astype(np.int32)
    )


NB_SWEEP = [1, 2, 7, 64, 255, 256, 300]


class TestXorDelta:
    @pytest.mark.parametrize("nb", NB_SWEEP)
    def test_matches_oracle(self, nb):
        rng = np.random.RandomState(nb)
        a, b = rand_blocks(rng, nb), rand_blocks(rng, nb)
        got = xor_delta(a, b)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.xor_delta_ref(a, b)))

    @pytest.mark.parametrize("rows", [1, 8, 64, 256])
    def test_block_shape_sweep(self, rows):
        rng = np.random.RandomState(rows)
        a, b = rand_blocks(rng, 128), rand_blocks(rng, 128)
        got = xor_delta(a, b, rows_per_program=rows)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.xor_delta_ref(a, b)))

    def test_self_inverse(self):
        rng = np.random.RandomState(0)
        a, b = rand_blocks(rng, 33), rand_blocks(rng, 33)
        d = xor_delta(a, b)
        np.testing.assert_array_equal(np.asarray(xor_delta(a, d)), np.asarray(b))


class TestChangedBlockMask:
    @pytest.mark.parametrize("nb", NB_SWEEP)
    def test_matches_oracle(self, nb):
        rng = np.random.RandomState(nb)
        a = rand_blocks(rng, nb)
        b = a
        if nb > 2:
            b = a.at[jnp.asarray([0, nb // 2, nb - 1])].add(1)
        got = changed_block_mask(a, b)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ref.changed_block_mask_ref(a, b))
        )

    def test_identical_inputs_all_zero(self):
        rng = np.random.RandomState(1)
        a = rand_blocks(rng, 50)
        assert int(jnp.sum(changed_block_mask(a, a))) == 0

    def test_single_bit_flip_detected(self):
        rng = np.random.RandomState(2)
        a = rand_blocks(rng, 20)
        b = a.at[13, 5, 77].set(a[13, 5, 77] ^ 1)
        m = np.asarray(changed_block_mask(a, b))[:, 0]
        assert m[13] == 1 and m.sum() == 1


class TestBlockHash:
    @pytest.mark.parametrize("nb", NB_SWEEP)
    def test_matches_oracle(self, nb):
        rng = np.random.RandomState(nb)
        x = rand_blocks(rng, nb)
        coef = jnp.asarray(hash_coefficients())
        np.testing.assert_array_equal(
            np.asarray(block_hash(x, coef)), np.asarray(ref.block_hash_ref(x, coef))
        )

    def test_equal_blocks_equal_hashes(self):
        rng = np.random.RandomState(3)
        x = rand_blocks(rng, 4)
        x = x.at[2].set(x[0])
        h = np.asarray(ops.block_hashes(x))
        assert h[2] == h[0]


class TestSparseApply:
    @pytest.mark.parametrize("nb,k", [(8, 3), (64, 1), (100, 37), (256, 256)])
    def test_matches_oracle(self, nb, k):
        rng = np.random.RandomState(nb * 1000 + k)
        base = rand_blocks(rng, nb)
        blocks = rand_blocks(rng, k)
        idx = jnp.asarray(rng.choice(nb, size=k, replace=False).astype(np.int32))
        got = sparse_delta_apply(base, blocks, idx)
        want = ref.sparse_delta_apply_ref(base, blocks, idx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_padding_rows_ignored(self):
        rng = np.random.RandomState(9)
        base = rand_blocks(rng, 16)
        blocks = rand_blocks(rng, 4)
        idx = jnp.asarray([2, -1, 5, -1], dtype=jnp.int32)
        got = np.asarray(sparse_delta_apply(base, blocks, idx))
        want = np.array(base)  # writable copy
        want[2] = np.asarray(blocks[0])
        want[5] = np.asarray(blocks[2])
        np.testing.assert_array_equal(got, want)


DTYPES = ["float32", "bfloat16", "int32", "int8", "float16", "uint8"]


class TestBlockLayout:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("shape", [(3, 5), (1024,), (2, 8, 130), (4096, 3)])
    def test_roundtrip(self, dtype, shape):
        rng = np.random.RandomState(hash((dtype, shape)) % 2**31)
        if np.issubdtype(np.dtype(dtype if dtype != "bfloat16" else "float32"), np.floating):
            x = jnp.asarray(rng.randn(*shape), dtype=dtype)
        else:
            x = jnp.asarray(rng.randint(0, 100, size=shape), dtype=dtype)
        blocks, meta = ops.to_blocks(x)
        assert blocks.shape[1:] == (8, 128)
        y = ops.from_blocks(blocks, meta)
        assert y.dtype == x.dtype and y.shape == x.shape
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


class TestEndToEndDelta:
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_sparse_roundtrip_tensor(self, dtype):
        rng = np.random.RandomState(7)
        base_t = jnp.asarray(rng.randn(1000, 257), dtype=dtype)
        new_t = base_t.at[100:120].add(1.0)  # localized edit
        bb, meta = ops.to_blocks(base_t)
        nb_, _ = ops.to_blocks(new_t)
        idx, blocks, n = ops.sparse_encode(bb, nb_)
        assert 0 < n < bb.shape[0]
        rec = ops.sparse_apply(bb, blocks, idx)
        out = ops.from_blocks(rec, meta)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(new_t))

    def test_xor_roundtrip_tensor(self):
        rng = np.random.RandomState(8)
        a_t = jnp.asarray(rng.randn(513, 129), dtype=jnp.float32)
        b_t = a_t * 1.5
        ab, meta = ops.to_blocks(a_t)
        bb, _ = ops.to_blocks(b_t)
        delta = ops.xor_encode(ab, bb)
        rec = ops.from_blocks(ops.xor_apply(ab, delta), meta)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(b_t))


# ----------------------------------------------------------------- hypothesis
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


@st.composite
def block_pairs(draw):
    nb = draw(st.integers(min_value=1, max_value=48))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.RandomState(seed)
    a = rand_blocks(rng, nb)
    n_edits = draw(st.integers(min_value=0, max_value=nb))
    rows = rng.choice(nb, size=n_edits, replace=False) if n_edits else []
    b = a
    for r in rows:
        b = b.at[int(r), rng.randint(8), rng.randint(128)].add(
            int(rng.randint(1, 1000))
        )
    return a, b, sorted(int(r) for r in rows)


class TestKernelProperties:
    @settings(max_examples=25, deadline=None)
    @given(block_pairs())
    def test_mask_identifies_exact_rows(self, pair):
        a, b, rows = pair
        m = np.asarray(changed_block_mask(a, b))[:, 0]
        # edits could be no-ops only if add(0), which we exclude
        assert sorted(np.nonzero(m)[0].tolist()) == rows

    @settings(max_examples=25, deadline=None)
    @given(block_pairs())
    def test_sparse_encode_apply_roundtrip(self, pair):
        a, b, rows = pair
        idx, blocks, n = ops.sparse_encode(a, b)
        assert n == len(rows)
        rec = ops.sparse_apply(a, blocks, idx)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(b))

    @settings(max_examples=25, deadline=None)
    @given(block_pairs())
    def test_xor_is_involution(self, pair):
        a, b, _ = pair
        d = ops.xor_encode(a, b)
        np.testing.assert_array_equal(
            np.asarray(ops.xor_apply(a, d)), np.asarray(b)
        )
        # delta of identical inputs is all-zero
        z = ops.xor_encode(a, a)
        assert int(jnp.sum(jnp.abs(z))) == 0
