"""Unit + property tests for the paper's core algorithms (§2-§4)."""

import random

import pytest

from repro.core import (
    InfeasibleError,
    StorageSolution,
    VersionGraph,
    WorkloadSpec,
    dc_like,
    exact_min_storage,
    generate,
    git_heuristic,
    last_tree,
    lc_like,
    local_move_greedy,
    min_max_recreation_under_budget,
    minimize_storage_sum_recreation,
    minimum_storage_tree,
    modified_prim,
    shortest_path_tree,
    zipf_weights,
)
from repro.core.solvers.spt import dijkstra


# ----------------------------------------------------------- paper Figure 1
def figure1_graph() -> VersionGraph:
    g = VersionGraph(5, directed=True)
    g.set_materialization(1, 10000, 10000)
    g.set_materialization(2, 10100, 10100)
    g.set_materialization(3, 9700, 9700)
    g.set_materialization(4, 9800, 9800)
    g.set_materialization(5, 10120, 10120)
    g.set_delta(1, 2, 200, 350)
    g.set_delta(1, 3, 1000, 3000)
    g.set_delta(2, 4, 50, 200)
    g.set_delta(3, 5, 800, 2500)
    g.set_delta(2, 5, 200, 550)
    g.set_delta(3, 2, 1100, 3200)
    return g


class TestPaperExample:
    def test_mca_matches_figure1_iii(self):
        """Figure 1(iii): all-delta chain, storage 11450."""
        sol = minimum_storage_tree(figure1_graph())
        sol.validate()
        assert sol.storage_cost() == pytest.approx(11450)
        assert sol.materialized() == [1]

    def test_spt_matches_figure1_ii(self):
        """Figure 1(ii): everything materialized, storage 49720."""
        sol = shortest_path_tree(figure1_graph())
        sol.validate()
        assert sol.storage_cost() == pytest.approx(49720)
        assert sol.materialized() == [1, 2, 3, 4, 5]
        # paper: recreating V5 via the chain costs 13550 > direct 10120
        assert sol.recreation_costs()[5] == pytest.approx(10120)

    def test_v5_chain_recreation_cost(self):
        """Paper Example 1: path V1->V3->V5 recreation = 13550."""
        g = figure1_graph()
        sol = StorageSolution(parent={1: 0, 2: 1, 3: 1, 4: 2, 5: 3}, graph=g)
        sol.validate()
        assert sol.recreation_costs()[5] == pytest.approx(10000 + 3000 + 2500)

    def test_triangle_inequality_holds(self):
        # figure numbers are fictitious; checker must at least run
        g = figure1_graph()
        g.check_triangle_inequality()


# --------------------------------------------------------------- invariants
def _workloads():
    return [
        generate(dc_like(100, seed=0)),
        generate(lc_like(100, seed=1)),
        generate(WorkloadSpec(commits=80, seed=2, phi_independent=True)),
        generate(WorkloadSpec(commits=80, seed=3, directed=False)),
    ]


@pytest.fixture(scope="module", params=range(4), ids=["dc", "lc", "phi_ne", "undir"])
def workload(request):
    return _workloads()[request.param]


class TestSolverInvariants:
    def test_mca_minimal_vs_alternatives(self, workload):
        g = workload.graph
        mca = minimum_storage_tree(g)
        mca.validate()
        for other in (
            shortest_path_tree(g),
            last_tree(g, 2.0),
            git_heuristic(g, window=20, max_depth=20),
        ):
            assert mca.storage_cost() <= other.storage_cost() + 1e-6

    def test_spt_dominates_recreation(self, workload):
        """SPT minimizes every R_i simultaneously (paper Problem 2 remark)."""
        g = workload.graph
        spt_rc = shortest_path_tree(g).recreation_costs()
        for other in (
            minimum_storage_tree(g),
            last_tree(g, 2.0),
            local_move_greedy(g, minimum_storage_tree(g).storage_cost() * 1.3),
        ):
            rc = other.recreation_costs()
            for v in g.versions():
                assert spt_rc[v] <= rc[v] + 1e-6

    def test_lmg_budget_and_improvement(self, workload):
        g = workload.graph
        base = minimum_storage_tree(g)
        for mult in (1.05, 1.2, 2.0):
            budget = base.storage_cost() * mult
            sol = local_move_greedy(g, budget)
            sol.validate()
            assert sol.storage_cost() <= budget + 1e-6
            assert sol.sum_recreation() <= base.sum_recreation() + 1e-6

    def test_lmg_monotone_in_budget(self, workload):
        g = workload.graph
        base = minimum_storage_tree(g)
        sums = [
            local_move_greedy(g, base.storage_cost() * m).sum_recreation()
            for m in (1.0, 1.1, 1.5, 3.0)
        ]
        for a, b in zip(sums, sums[1:]):
            assert b <= a + 1e-6

    def test_mp_respects_theta(self, workload):
        g = workload.graph
        spt = shortest_path_tree(g)
        for mult in (1.2, 2.0, 4.0):
            theta = spt.max_recreation() * mult
            sol = modified_prim(g, theta)
            sol.validate()
            assert sol.max_recreation() <= theta + 1e-6

    def test_mp_infeasible_raises(self, workload):
        g = workload.graph
        spt = shortest_path_tree(g)
        with pytest.raises(InfeasibleError):
            modified_prim(g, spt.max_recreation() * 0.5)

    def test_problem4_budget(self, workload):
        g = workload.graph
        base = minimum_storage_tree(g)
        budget = base.storage_cost() * 1.5
        sol = min_max_recreation_under_budget(g, budget)
        sol.validate()
        assert sol.storage_cost() <= budget + 1e-6
        assert sol.max_recreation() <= base.max_recreation() + 1e-6

    def test_problem5_constraint(self, workload):
        g = workload.graph
        base = minimum_storage_tree(g)
        spt = shortest_path_tree(g)
        theta = 0.5 * (base.sum_recreation() + spt.sum_recreation())
        sol = minimize_storage_sum_recreation(g, theta)
        sol.validate()
        assert sol.sum_recreation() <= theta + 1e-6
        assert sol.storage_cost() <= spt.storage_cost() + 1e-6

    def test_gith_window_and_depth(self, workload):
        g = workload.graph
        sol = git_heuristic(g, window=10, max_depth=5)
        sol.validate()
        # GitH depth 0 == materialized == tree depth 1 (the root edge)
        for v in g.versions():
            assert sol.depth(v) - 1 <= 5

    def test_workload_aware_lmg_not_worse(self, workload):
        g = workload.graph
        w = zipf_weights(g.n, seed=7)
        base = minimum_storage_tree(g)
        budget = base.storage_cost() * 1.3
        aware = local_move_greedy(g, budget, weights=w)
        oblivious = local_move_greedy(g, budget)
        aware.validate()
        assert aware.sum_recreation(w) <= oblivious.sum_recreation(w) + 1e-6


class TestLASTGuarantees:
    """Khuller et al. bounds hold for undirected Δ=Φ instances (paper §4.3)."""

    @pytest.mark.parametrize("alpha", [1.5, 2.0, 3.0])
    def test_bounds(self, alpha):
        wl = generate(WorkloadSpec(commits=90, seed=11, directed=False))
        g = wl.graph
        mst = minimum_storage_tree(g)
        sol = last_tree(g, alpha)
        sol.validate()
        dist, _ = dijkstra(g, weight="phi")
        rc = sol.recreation_costs()
        for v in g.versions():
            assert rc[v] <= alpha * dist[v] + 1e-6
        assert sol.storage_cost() <= (1 + 2 / (alpha - 1)) * mst.storage_cost() + 1e-6


class TestExactSolver:
    def _small(self, seed, n=8):
        return generate(WorkloadSpec(commits=n, seed=seed, reveal_hops=4)).graph

    def test_exact_beats_or_matches_mp(self):
        for seed in range(4):
            g = self._small(seed)
            spt = shortest_path_tree(g)
            theta = spt.max_recreation() * 1.5
            mp = modified_prim(g, theta)
            ex = exact_min_storage(g, theta_max=theta, time_budget_s=20)
            assert ex.optimal
            assert ex.solution.max_recreation() <= theta + 1e-6
            assert ex.solution.storage_cost() <= mp.storage_cost() + 1e-6

    def test_exact_matches_mca_when_unconstrained(self):
        g = self._small(5)
        mca = minimum_storage_tree(g)
        loose = 10 * shortest_path_tree(g).max_recreation() * g.n
        ex = exact_min_storage(g, theta_max=loose, time_budget_s=20)
        assert ex.optimal
        assert ex.solution.storage_cost() == pytest.approx(mca.storage_cost())

    def test_exact_sum_variant(self):
        g = self._small(6)
        base = minimum_storage_tree(g)
        spt = shortest_path_tree(g)
        theta = 0.5 * (base.sum_recreation() + spt.sum_recreation())
        ex = exact_min_storage(g, theta_sum=theta, time_budget_s=30)
        assert ex.solution is not None
        assert ex.solution.sum_recreation() <= theta + 1e-6
        lmg = minimize_storage_sum_recreation(g, theta)
        if ex.optimal:
            assert ex.solution.storage_cost() <= lmg.storage_cost() + 1e-6


# ----------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @st.composite
    def random_graphs(draw):
        n = draw(st.integers(min_value=2, max_value=14))
        seed = draw(st.integers(min_value=0, max_value=2**31))
        rng = random.Random(seed)
        directed = draw(st.booleans())
        g = VersionGraph(n, directed=directed)
        for i in g.versions():
            size = rng.uniform(100, 10000)
            g.set_materialization(i, size, size * rng.uniform(0.5, 2.0))
        # random subset of delta edges
        for i in g.versions():
            for j in g.versions():
                if i >= j if not directed else i == j:
                    continue
                if rng.random() < 0.4:
                    d = rng.uniform(1, 2000)
                    g.set_delta(i, j, d, d * rng.uniform(0.5, 2.0))
        return g

    class TestProperties:
        @settings(max_examples=40, deadline=None)
        @given(random_graphs())
        def test_solutions_are_spanning_trees(self, g):
            """Lemma 1: every solver output is a spanning tree rooted at V0."""
            for sol in (
                minimum_storage_tree(g),
                shortest_path_tree(g),
                last_tree(g, 2.0),
                git_heuristic(g, window=5, max_depth=8),
            ):
                sol.validate()  # checks parents exist + acyclicity
                assert len(sol.parent) == g.n

        @settings(max_examples=40, deadline=None)
        @given(random_graphs())
        def test_mst_lower_bounds_everything(self, g):
            mst = minimum_storage_tree(g)
            spt = shortest_path_tree(g)
            assert mst.storage_cost() <= spt.storage_cost() + 1e-6
            # SPT recreation lower-bounds MST's
            s_rc, m_rc = spt.recreation_costs(), mst.recreation_costs()
            for v in g.versions():
                assert s_rc[v] <= m_rc[v] + 1e-6

        @settings(max_examples=25, deadline=None)
        @given(random_graphs(), st.floats(min_value=1.01, max_value=3.0))
        def test_lmg_budget_never_violated(self, g, mult):
            base = minimum_storage_tree(g)
            sol = local_move_greedy(g, base.storage_cost() * mult)
            assert sol.storage_cost() <= base.storage_cost() * mult + 1e-6
            sol.validate()

        @settings(max_examples=25, deadline=None)
        @given(random_graphs(), st.floats(min_value=1.05, max_value=4.0))
        def test_mp_theta_never_violated(self, g, mult):
            spt = shortest_path_tree(g)
            theta = spt.max_recreation() * mult
            sol = modified_prim(g, theta)
            sol.validate()
            assert sol.max_recreation() <= theta + 1e-6

        @settings(max_examples=10, deadline=None)
        @given(random_graphs())
        def test_exact_is_lower_bound(self, g):
            if g.n > 9:
                return  # keep exact tractable
            spt = shortest_path_tree(g)
            theta = spt.max_recreation() * 2
            ex = exact_min_storage(g, theta_max=theta, time_budget_s=10)
            if not ex.optimal:
                return
            mp = modified_prim(g, theta)
            assert ex.solution.storage_cost() <= mp.storage_cost() + 1e-6


# ------------------------------------------------------- mergeable run-heap
class _SortedListOracle:
    """Naive `(weight, id)` multiset mirroring every RunHeap operation with
    the same float-op order (one add per offset), so comparisons are exact
    even for non-integer weights."""

    def __init__(self, items=()):
        self.items = list(items)

    def push(self, w, i):
        self.items.append((w, i))

    def add_offset(self, c):
        self.items = [(w + c, i) for w, i in self.items]

    def meld(self, other):
        self.items.extend(other.items)
        other.items = []
        return self

    def pop(self):
        m = min(self.items)
        self.items.remove(m)
        return m

    def min_tied_ids(self):
        w = min(self.items)[0]
        return w, sorted(i for ww, i in self.items if ww == w)

    def purge(self, is_dead):
        self.items = [(w, i) for w, i in self.items if not is_dead(i)]

    def snapshot(self):
        return sorted(self.items)


class TestRunHeap:
    """Property tests for `repro.core.solvers.meldable_heap.RunHeap` against
    the naive sorted-list oracle, including the eager-offset bit-exactness
    and stable-dead purging contracts the Edmonds solver relies on."""

    def _dead_fn(self, dead_set):
        import numpy as np

        def dead(ids):
            return np.array([int(i) in dead_set for i in ids], dtype=bool)

        return dead

    def test_random_ops_vs_oracle(self):
        import numpy as np

        from repro.core.solvers.meldable_heap import RunHeap

        for seed in range(8):
            rng = random.Random(seed)
            n_heaps = 5
            next_id = 0
            dead_set = set()
            heaps, oracles = [], []
            for _ in range(n_heaps):
                k = rng.randint(0, 30)
                ws = sorted(
                    round(rng.uniform(0, 50), 2) for _ in range(k)
                )
                ids = list(range(next_id, next_id + k))
                next_id += k
                heaps.append(RunHeap.from_sorted(
                    np.asarray(ws, dtype=np.float64),
                    np.asarray(ids, dtype=np.int64),
                ))
                oracles.append(_SortedListOracle(zip(ws, ids)))
            for _ in range(300):
                j = rng.randrange(len(heaps))
                h, o = heaps[j], oracles[j]
                op = rng.choice(
                    ["push", "pop", "meld", "offset", "tied", "purge"]
                )
                if op == "push":
                    w = round(rng.uniform(0, 50), 2)
                    h.push(w, next_id)
                    o.push(w, next_id)
                    next_id += 1
                elif op == "pop" and len(h):
                    assert h.pop() == o.pop()
                elif op == "meld":
                    k = rng.randrange(len(heaps))
                    if k != j:
                        heaps[j] = h.meld(heaps[k])
                        oracles[j] = o.meld(oracles[k])
                        heaps[k] = RunHeap()
                        oracles[k] = _SortedListOracle()
                elif op == "offset":
                    c = round(rng.uniform(-5, 5), 2)
                    h.add_offset(c)
                    o.add_offset(c)
                elif op == "tied" and len(h):
                    w, ids = h.min_tied_ids()
                    ow, oids = o.min_tied_ids()
                    assert w == ow
                    assert sorted(ids.tolist()) == oids
                elif op == "purge" and len(h):
                    # stable-dead contract: ids only ever *join* dead_set
                    alive = [i for _, i in o.items]
                    for i in rng.sample(alive, len(alive) // 3):
                        dead_set.add(i)
                    dead = self._dead_fn(dead_set)
                    if rng.random() < 0.5:
                        h.compact(dead)
                    else:
                        h.drop_while(dead)
                        h.compact(dead)  # oracle purges fully; align
                    o.purge(lambda i: i in dead_set)
                # re-fetch: meld may have made `h` the emptied donor
                assert len(heaps[j]) == len(oracles[j].items)
            for h, o in zip(heaps, oracles):
                assert sorted(h.items()) == o.snapshot()

    def test_eager_offsets_bit_exact(self):
        """Offsets must be applied individually in order — `(w+c1)+c2`, not
        `w+(c1+c2)` — matching the seed oracle's sequential subtractions."""
        import numpy as np

        from repro.core.solvers.meldable_heap import RunHeap

        w0, c1, c2 = 0.1, 0.2, 0.3
        h = RunHeap.from_sorted(
            np.array([w0]), np.array([7], dtype=np.int64)
        )
        h.add_offset(c1)
        h.add_offset(c2)
        assert h.peek() == ((w0 + c1) + c2, 7)
        assert h.peek() != (w0 + (c1 + c2), 7)  # the regrouping this guards

    def test_min_tied_ids_after_collapse(self):
        """An offset can collapse two distinct weights to bitwise equality;
        the tied-min block must still report every id at the min."""
        import numpy as np

        from repro.core.solvers.meldable_heap import RunHeap

        # 1.0 and 1.0+2^-53 differ, but both + 1e10 round to the same float
        a, b = 1.0, 1.0 + 2.0**-53
        h = RunHeap.from_sorted(
            np.array([a, b]), np.array([9, 3], dtype=np.int64)
        )
        h.add_offset(1e10)
        w, ids = h.min_tied_ids()
        assert w == a + 1e10 == b + 1e10
        assert sorted(ids.tolist()) == [3, 9]

    def test_compact_drops_empty_runs_and_rebounds(self):
        import numpy as np

        from repro.core.solvers.meldable_heap import RunHeap

        h = RunHeap.from_sorted(
            np.arange(100, dtype=np.float64),
            np.arange(100, dtype=np.int64),
        )
        h.compact(self._dead_fn(set(range(0, 100, 2))))
        assert len(h) == 50
        assert sorted(i for _, i in h.items()) == list(range(1, 100, 2))
        h.compact(self._dead_fn(set(range(100))))
        assert len(h) == 0 and not h


# ------------------------------------------------ adversarial Edmonds MCA
def _two_cycle_chain(n, eps=1e-3):
    """Directed instance whose cheapest in-edges pair up into 2-cycles that
    re-pair after every contraction round — a log-deep tower of nested
    cycles, the worst case for the contraction bookkeeping."""
    g = VersionGraph(n, directed=True)
    for i in range(1, n + 1):
        g.set_materialization(i, 1000.0 + i, 1000.0 + i)
    for i in range(1, n):
        g.set_delta(i, i + 1, 10.0 + eps * i, 20.0)
        g.set_delta(i + 1, i, 10.0 + eps * i, 20.0)
    return g


def _dense_tied(n, seed, levels=(5.0, 10.0)):
    """Dense directed instance with only two distinct delta costs: maximal
    weight ties stress the lowest-edge-id tie-break through contractions."""
    rng = random.Random(seed)
    g = VersionGraph(n, directed=True)
    for i in range(1, n + 1):
        g.set_materialization(i, 100.0 + i, 100.0 + i)
    for u in range(1, n + 1):
        for v in range(1, n + 1):
            if u != v:
                g.set_delta(u, v, rng.choice(levels), 15.0)
    return g


class TestEdmondsAdversarial:
    """The mergeable-heap Edmonds must stay exactly equal to the seed oracle
    on instances engineered to maximize contraction depth and tie pressure
    (the regimes the run-heap rewrite optimizes)."""

    def test_two_cycle_chain_matches_oracle(self):
        from reference_solvers import ref_minimum_storage_tree

        for n in (2, 3, 17, 64, 129):
            g = _two_cycle_chain(n)
            new = minimum_storage_tree(g)
            ref = ref_minimum_storage_tree(g)
            assert new.parent == ref.parent, f"n={n}"
            assert new.storage_cost() == ref.storage_cost()

    def test_two_cycle_chain_all_ties(self):
        from reference_solvers import ref_minimum_storage_tree

        g = _two_cycle_chain(40, eps=0.0)  # every 2-cycle costs the same
        new = minimum_storage_tree(g)
        ref = ref_minimum_storage_tree(g)
        assert new.parent == ref.parent
        assert new.storage_cost() == ref.storage_cost()

    def test_dense_two_level_ties_match_oracle(self):
        from reference_solvers import ref_minimum_storage_tree

        for seed, n in ((0, 24), (1, 31), (2, 40)):
            g = _dense_tied(n, seed)
            new = minimum_storage_tree(g)
            ref = ref_minimum_storage_tree(g)
            assert new.parent == ref.parent, f"seed={seed}"
            assert new.storage_cost() == ref.storage_cost()
