"""Backend-parity property tests: ``backend="jax"`` vs ``backend="numpy"``.

The jitted solver backend (``repro.core.solvers.jax_backend``) runs 32-bit
device selection with host-side f64 cost recomputation: the parent trees must
match the NumPy oracles exactly, and since all reported costs are derived in
f64 from tree + edge arrays, cost equality follows.  Enforced here on the
56-instance random suite of
``test_array_refactor`` (4 synthetic families × 8 seeds + 24 dense random,
directed and undirected) plus corner cases — single version, star graph,
disconnected-but-for-root — and, on a subset, with the Pallas segment
kernels enabled (``pallas=True``, interpret mode on CPU).

The segment-op kernels themselves are unit-tested against NumPy reductions
at the bottom.
"""

import numpy as np
import pytest

from repro.core import (
    SOLVERS,
    VersionGraph,
    local_move_greedy,
    minimum_storage_tree,
    modified_prim,
    shortest_path_tree,
)
from test_array_refactor import _instances


@pytest.fixture(scope="module")
def instances():
    return _instances()


def _assert_spt_parity(g, **kw):
    a = shortest_path_tree(g)
    b = shortest_path_tree(g, backend="jax", **kw)
    assert a.parent == b.parent
    assert a.recreation_costs() == b.recreation_costs()


def _assert_mst_parity(g, **kw):
    a = minimum_storage_tree(g)
    b = minimum_storage_tree(g, backend="jax", **kw)
    assert a.parent == b.parent
    assert a.storage_cost() == b.storage_cost()


def _assert_lmg_parity(g, mult, **kw):
    budget = minimum_storage_tree(g).storage_cost() * mult
    a = local_move_greedy(g, budget)
    b = local_move_greedy(g, budget, backend="jax", **kw)
    assert a.parent == b.parent
    assert a.storage_cost() == b.storage_cost()
    assert a.sum_recreation() == b.sum_recreation()


def _assert_mp_parity(g, mult, **kw):
    theta = shortest_path_tree(g).max_recreation() * mult
    a = modified_prim(g, theta)
    b = modified_prim(g, theta, backend="jax", **kw)
    assert a.parent == b.parent
    assert a.storage_cost() == b.storage_cost()
    assert a.max_recreation() == b.max_recreation()


class TestBackendParitySuite:
    """Bit-identical trees/costs on the full 56-instance random suite."""

    def test_instance_count(self, instances):
        assert len(instances) >= 50

    def test_spt(self, instances):
        for g in instances:
            _assert_spt_parity(g)

    def test_mst(self, instances):
        # undirected instances exercise the jitted Prim; directed ones the
        # documented Edmonds fallback (identical by construction, still
        # asserted so the dispatch path stays covered)
        for g in instances:
            _assert_mst_parity(g)

    def test_lmg(self, instances):
        for g in instances:
            for mult in (1.05, 1.35):
                _assert_lmg_parity(g, mult)

    def test_mp(self, instances):
        for g in instances:
            for mult in (1.2, 2.5):
                _assert_mp_parity(g, mult)

    def test_solver_registry_accepts_backend(self, instances):
        g = instances[0]
        a = SOLVERS["spt"](g)
        b = SOLVERS["spt"](g, backend="jax")
        assert a.parent == b.parent

    def test_unknown_backend_rejected(self, instances):
        g = instances[0]
        with pytest.raises(ValueError, match="backend"):
            shortest_path_tree(g, backend="torch")
        with pytest.raises(ValueError, match="backend"):
            minimum_storage_tree(g, backend="torch")
        with pytest.raises(ValueError, match="backend"):
            local_move_greedy(g, 1e18, backend="torch")
        with pytest.raises(ValueError, match="backend"):
            modified_prim(g, 1e18, backend="torch")


class TestPallasKernelPath:
    """A subset re-run with the Pallas segment kernels (interpret mode)."""

    def test_parity_with_pallas(self, instances):
        for g in instances[:4]:
            _assert_spt_parity(g, pallas=True)
            _assert_mst_parity(g, pallas=True)
            _assert_lmg_parity(g, 1.2, pallas=True)
            _assert_mp_parity(g, 1.5, pallas=True)


# ------------------------------------------------------------- corner cases
def _star(n, directed):
    """Materializations only — no delta edges at all."""
    g = VersionGraph(n, directed=directed)
    for i in g.versions():
        g.set_materialization(i, 100.0 + i, 50.0 + i)
    return g


def _disconnected_but_for_root(directed):
    """Two delta clusters with no edges between them; the root reaches all."""
    g = VersionGraph(6, directed=directed)
    for i in g.versions():
        g.set_materialization(i, 1000.0 + 10 * i, 900.0 + 10 * i)
    g.set_delta(1, 2, 5.0, 4.0)
    g.set_delta(2, 3, 6.0, 5.0)
    g.set_delta(4, 5, 7.0, 6.0)
    g.set_delta(5, 6, 8.0, 7.0)
    return g


class TestCornerCases:
    @pytest.mark.parametrize("directed", [True, False])
    def test_single_version(self, directed):
        g = VersionGraph(1, directed=directed)
        g.set_materialization(1, 42.0, 17.0)
        _assert_spt_parity(g)
        _assert_mst_parity(g)
        _assert_lmg_parity(g, 1.5)
        _assert_mp_parity(g, 2.0)
        sol = shortest_path_tree(g, backend="jax")
        assert sol.parent == {1: 0}

    @pytest.mark.parametrize("directed", [True, False])
    def test_star_graph(self, directed):
        g = _star(9, directed)
        _assert_spt_parity(g)
        _assert_mst_parity(g)
        # LMG candidate set is empty (SPT == MST == the star)
        _assert_lmg_parity(g, 1.5)
        _assert_mp_parity(g, 1.0)
        assert shortest_path_tree(g, backend="jax").materialized() == list(
            g.versions()
        )

    @pytest.mark.parametrize("directed", [True, False])
    def test_disconnected_but_for_root(self, directed):
        g = _disconnected_but_for_root(directed)
        _assert_spt_parity(g)
        _assert_mst_parity(g)
        _assert_lmg_parity(g, 1.3)
        _assert_mp_parity(g, 1.4)

    def test_near_tie_relaxation_slack_matches(self):
        # a 2-hop path that undercuts the direct edge by ~5e-17 (< the 1e-15
        # relaxation slack): the heap Dijkstra rejects the improvement, and
        # the jitted Bellman-Ford must apply the same EPS guard
        g = VersionGraph(2, directed=True)
        g.set_materialization(1, 10.0, 0.15)
        g.set_materialization(2, 10.0, float(np.nextafter(0.3, 1)))
        g.set_delta(1, 2, 1.0, 0.15)
        _assert_spt_parity(g)
        assert shortest_path_tree(g, backend="jax").parent == {1: 0, 2: 0}

    def test_unreachable_version_raises_like_numpy(self):
        # version 2 has no materialization and no in-edges at all
        g = VersionGraph(2, directed=True)
        g.set_materialization(1, 10.0, 10.0)
        with pytest.raises(ValueError, match="unreachable"):
            shortest_path_tree(g)
        with pytest.raises(ValueError, match="unreachable"):
            shortest_path_tree(g, backend="jax")

    def test_degree_skew_guard(self):
        # a hub vertex whose degree would blow up the dense padded layout
        # must produce a clear error, not an OOM (numpy handles it in CSR)
        from repro.core.solvers import jax_backend

        n = 8192
        g = VersionGraph(n, directed=True)
        ids = np.arange(1, n + 1, dtype=np.int64)
        ones = np.ones(n, dtype=np.float64)
        g.add_edges_bulk(np.zeros(n, dtype=np.int64), ids, 100 * ones, ones)
        hub_dst = ids[1:]  # vertex 1 -> everyone else
        g.add_edges_bulk(
            np.full(n - 1, 1, dtype=np.int64), hub_dst,
            ones[1:], ones[1:],
        )
        assert 16384 * hub_dst.shape[0] > jax_backend.MAX_PADDED_CELLS
        with pytest.raises(ValueError, match="degree skew"):
            modified_prim(g, 1e9, backend="jax")
        # the numpy backend still solves the same instance
        modified_prim(g, 1e9).validate()


# --------------------------------------------------------- segment-op kernels
class TestSegmentOps:
    """Unit tests run under enable_x64 to check the kernels are
    dtype-polymorphic; the production solver path feeds them f32/i32."""

    def _rows(self, seed, shape=(37, 19)):
        rng = np.random.RandomState(seed)
        x = rng.uniform(-100, 100, size=shape)
        x[rng.rand(*shape) < 0.15] = np.inf  # padding-like entries
        return x

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_row_min_matches_numpy(self, use_pallas):
        from jax.experimental import enable_x64

        from repro.kernels.segment_ops import segment_min_rows

        with enable_x64():
            for seed in range(3):
                x = self._rows(seed)
                got = np.asarray(segment_min_rows(x, use_pallas=use_pallas))
                np.testing.assert_array_equal(got, x.min(axis=1))

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_row_argmin_first_occurrence(self, use_pallas):
        from jax.experimental import enable_x64

        from repro.kernels.segment_ops import segment_argmin_rows

        with enable_x64():
            x = self._rows(7)
            x[:, 3] = x[:, 11] = -500.0  # forced ties within every row
            got = np.asarray(segment_argmin_rows(x, use_pallas=use_pallas))
            np.testing.assert_array_equal(got, x.argmin(axis=1))

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_min_argmin_1d(self, use_pallas):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.kernels.segment_ops import min_argmin_1d

        with enable_x64():
            rng = np.random.RandomState(0)
            for n in (1, 5, 128, 301):
                x = rng.uniform(-10, 10, size=n)
                if n > 200:
                    x[57] = x[260] = x.min() - 5.0  # cross-tile tie
                m, i = min_argmin_1d(jnp.asarray(x), use_pallas=use_pallas)
                assert int(i) == int(np.argmin(x))
                assert float(m) == x.min()

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_min_argmin_all_inf(self, use_pallas):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.kernels.segment_ops import min_argmin_1d

        with enable_x64():
            x = jnp.full((40,), jnp.inf)
            m, i = min_argmin_1d(x, use_pallas=use_pallas)
            assert int(i) == 0 and not np.isfinite(float(m))
