"""Pallas flash-attention vs the chunked-XLA oracle (interpret mode)."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_hbm_bytes
from repro.models.layers import chunked_attention


CASES = [
    # B, Sq, Sk, H, KV, hd, causal, bq, bk
    (2, 512, 512, 8, 2, 64, True, 256, 256),
    (1, 1024, 1024, 4, 4, 128, True, 512, 512),
    (2, 512, 512, 8, 8, 64, False, 128, 256),
    (1, 256, 256, 6, 2, 32, True, 128, 128),
    (2, 256, 256, 4, 1, 64, True, 128, 64),   # MQA
]


@pytest.mark.parametrize("case", CASES, ids=[str(c[:6]) for c in CASES])
def test_matches_oracle(case):
    B, Sq, Sk, H, KV, hd, causal, bq, bk = case
    rng = np.random.RandomState(hash(case) % 2**31)
    q = jnp.asarray(rng.randn(B, Sq, H, hd), jnp.bfloat16)
    k = jnp.asarray(rng.randn(B, Sk, KV, hd), jnp.bfloat16)
    v = jnp.asarray(rng.randn(B, Sk, KV, hd), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = chunked_attention(q, k, v, causal=causal, chunk=min(256, Sk))
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_block_shape_sweep():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, 512, 4, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 512, 2, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 512, 2, 64), jnp.bfloat16)
    want = chunked_attention(q, k, v, causal=True, chunk=128)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-2,
        )


def test_scale_override():
    rng = np.random.RandomState(8)
    q = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 256, 4, 32), jnp.bfloat16)
    s = 1.0 / math.sqrt(57.0)
    got = flash_attention(q, k, v, causal=True, softmax_scale=s,
                          block_q=128, block_k=128)
    want = chunked_attention(q, k, v, causal=True, softmax_scale=s, chunk=128)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


def test_traffic_model_is_linear_in_s():
    """The analytic HBM model must be ~O(S) (vs O(S²) unfused)."""
    b1 = flash_hbm_bytes(32, 32768, 32768, 64, 8, 128)
    b2 = flash_hbm_bytes(32, 65536, 65536, 64, 8, 128)
    assert b2 < 4.2 * b1  # K/V re-reads grow with q-waves: ≲4x for 2x S
