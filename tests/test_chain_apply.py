"""Fused delta-chain pipeline tests: kernel vs stepwise oracle, wire decode
round-trips, and a randomized chain property suite (mixed sparse/full/
tombstone steps, all dtypes incl. bfloat16) asserting the fused path is
bit-identical to folding ``apply_delta`` step by step."""

import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.chain_apply import chain_delta_apply, chain_delta_apply_batched
from repro.kernels.ref import chain_delta_apply_ref
from repro.store import delta as D


def _rand_blocks(rng, n):
    return jnp.asarray(rng.randint(-(2**31), 2**31 - 1, (n, 8, 128), np.int64).astype(np.int32))


class TestChainKernel:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    @pytest.mark.parametrize("k,cap", [(1, 8), (4, 8), (7, 16)])
    def test_matches_stepwise_oracle(self, seed, k, cap):
        # random chains with duplicate rows across steps (later must win)
        # and -1 padding interleaved
        rng = np.random.RandomState(seed)
        nb = 24
        base = _rand_blocks(rng, nb)
        idx = rng.randint(0, nb, (k, cap)).astype(np.int32)
        idx[rng.rand(k, cap) < 0.4] = -1
        blocks = _rand_blocks(rng, k * cap).reshape(k, cap, 8, 128)
        want = chain_delta_apply_ref(base, blocks, jnp.asarray(idx))
        got = chain_delta_apply(base, blocks, jnp.asarray(idx))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_empty_and_all_padding(self):
        rng = np.random.RandomState(3)
        base = _rand_blocks(rng, 6)
        empty = chain_delta_apply(
            base, jnp.zeros((0, 8, 128), jnp.int32), jnp.zeros((0,), jnp.int32)
        )
        np.testing.assert_array_equal(np.asarray(empty), np.asarray(base))
        pad = chain_delta_apply(
            base, _rand_blocks(rng, 8), jnp.full((8,), -1, jnp.int32)
        )
        np.testing.assert_array_equal(np.asarray(pad), np.asarray(base))

    @pytest.mark.parametrize("seed", [0, 4])
    def test_batched_matches_per_leaf(self, seed):
        rng = np.random.RandomState(seed)
        l, nb, s = 3, 16, 8
        bases = _rand_blocks(rng, l * nb).reshape(l, nb, 8, 128)
        idx = rng.randint(0, nb, (l, s)).astype(np.int32)
        idx[rng.rand(l, s) < 0.3] = -1
        blocks = _rand_blocks(rng, l * s).reshape(l, s, 8, 128)
        got = chain_delta_apply_batched(bases, blocks, jnp.asarray(idx))
        for i in range(l):
            want = chain_delta_apply_ref(
                bases[i], blocks[i], jnp.asarray(idx[i])
            )
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


# ------------------------------------------------------- randomized chains
_DTYPES = [np.float32, np.int8, "bfloat16"]


def _rand_leaf(rng, shape, dtype):
    if dtype == "bfloat16":
        return jnp.asarray(rng.randn(*shape), jnp.bfloat16)
    if np.issubdtype(np.dtype(dtype), np.floating):
        return rng.randn(*shape).astype(dtype)
    return rng.randint(-100, 100, shape).astype(dtype)


def _as_np(a):
    return np.asarray(a, np.float32) if a.dtype == jnp.bfloat16 else np.asarray(a)


def _random_chain(rng, steps=5):
    """A base tree plus ``steps`` encoded deltas with mixed leaf events:
    sparse edits, whole-leaf rewrites (reshape), tombstones, new leaves."""
    shapes = [(40, 64), (128,), (16, 16, 4)]
    base = {
        f"leaf{i}": _rand_leaf(rng, shapes[i % len(shapes)], _DTYPES[i % len(_DTYPES)])
        for i in range(4)
    }
    payloads, cur = [], base
    for _ in range(steps):
        new = {k: np.asarray(v).copy() for k, v in cur.items()}
        for k in list(new):
            r = rng.rand()
            if r < 0.35:  # sparse edit: bump one element
                flat = new[k].reshape(-1)
                flat[rng.randint(0, flat.size)] += np.asarray(1, flat.dtype)
            elif r < 0.45:  # full rewrite: reshape/dtype change
                new[k] = _rand_leaf(
                    rng, (rng.randint(4, 32), 8), _DTYPES[rng.randint(0, 3)]
                )
            elif r < 0.52 and len(new) > 1:  # tombstone
                del new[k]
        if rng.rand() < 0.3:  # new leaf mid-chain
            new[f"new{rng.randint(0, 1000)}"] = _rand_leaf(rng, (8, 8), np.float32)
        payloads.append(D.encode_delta(cur, new)[0])
        cur = new
    return base, payloads


def _assert_trees_bitequal(a, b):
    assert set(a) == set(b), set(a) ^ set(b)
    for k in a:
        assert a[k].dtype == b[k].dtype and a[k].shape == b[k].shape, k
        np.testing.assert_array_equal(_as_np(a[k]), _as_np(b[k]), err_msg=k)


class TestChainProperty:
    @pytest.mark.parametrize("seed", range(6))
    def test_fused_bitidentical_to_stepwise(self, seed):
        rng = np.random.RandomState(seed)
        base, payloads = _random_chain(rng, steps=rng.randint(1, 8))
        want = functools.reduce(D.apply_delta, payloads, base)
        got = D.apply_delta_chain(base, payloads)
        _assert_trees_bitequal(want, got)

    def test_batched_requests_independent(self):
        rng = np.random.RandomState(99)
        chains = [_random_chain(rng, steps=4) for _ in range(3)]
        results = D.apply_delta_chains([(b, p, None) for b, p in chains])
        for (b, p), (tree, blocked) in zip(chains, results):
            _assert_trees_bitequal(functools.reduce(D.apply_delta, p, b), tree)
            # blocked companion covers exactly the kernel-path leaves
            for k, (blk, meta) in blocked.items():
                np.testing.assert_array_equal(
                    _as_np(np.asarray(ops.from_blocks(blk, meta))),
                    _as_np(tree[k]),
                )

    def test_base_blocked_memo_reused(self):
        rng = np.random.RandomState(5)
        base, payloads = _random_chain(rng, steps=3)
        blocked = {
            k: ops.to_blocks(jnp.asarray(v)) for k, v in base.items()
        }
        got = D.apply_delta_chain(base, payloads, base_blocked=blocked)
        _assert_trees_bitequal(functools.reduce(D.apply_delta, payloads, base), got)


class TestDeltaWire:
    def test_roundtrip_matches_bytes_path(self):
        rng = np.random.RandomState(1)
        base, payloads = _random_chain(rng, steps=4)
        cur = base
        for p in payloads:
            wire = D.decode_delta_wire(p)
            _assert_trees_bitequal(
                D.apply_delta(cur, p), D.apply_delta(cur, wire)
            )
            cur = D.apply_delta(cur, wire)

    def test_wire_fields(self):
        base = {"a": np.ones((8, 8), np.float32), "b": np.ones(4, np.float32)}
        new = {"a": base["a"].copy(), "c": np.zeros(3, np.float32)}
        new["a"][0, 0] = 2.0
        payload, _ = D.encode_delta(base, new)
        wire = D.decode_delta_wire(payload)
        assert wire.tombstones == frozenset({"b"})
        assert set(wire.full) == {"c"}
        assert wire.sparse["a"].n >= 1
        assert wire.sparse["a"].idx.shape == (wire.sparse["a"].n,)
        assert wire.sparse["a"].blocks.shape == (wire.sparse["a"].n, 8, 128)

    def test_corrupt_chain_raises(self):
        # a sparse delta for a leaf the running tree doesn't hold is
        # corruption, not silently-skippable data
        base = {"a": np.ones((8, 8), np.float32)}
        new = dict(base, a=base["a"] + 1)
        payload, _ = D.encode_delta(base, new)
        with pytest.raises(ValueError, match="corrupt chain"):
            D.apply_delta_chain({"other": np.ones(4, np.float32)}, [payload])


class TestSlotBucketing:
    def test_bucket_pow2_min8(self):
        assert [D._slot_bucket(n) for n in (1, 7, 8, 9, 64, 65)] == [
            8, 8, 8, 16, 64, 128,
        ]

    def test_same_bucket_shares_group(self):
        # two leaves with equal (num_blocks, slot_bucket) must land in one
        # launch: the whole point of capacity bucketing
        rng = np.random.RandomState(2)
        base = {
            "x": rng.randn(40, 64).astype(np.float32),
            "y": rng.randn(40, 64).astype(np.float32),
        }
        new = {k: v.copy() for k, v in base.items()}
        new["x"][0, 0] += 1
        new["y"][3, 0] += 1
        payload, _ = D.encode_delta(base, new)
        stats = {}
        D.apply_delta_chains([(base, [payload], None)], stats=stats)
        assert stats["launches"] == 1
