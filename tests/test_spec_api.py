"""Declarative spec API tests.

* Bit-identity property: ``optimize(g, OptimizeSpec.problem(n, ...))``
  returns exactly the same tree and float costs as the corresponding
  legacy ``solve_problemN`` entry point, for all six paper problems across
  the 56-instance random suite of ``test_array_refactor``.
* Spec construction/validation: off-grid combinations, duplicate or
  objective-shadowing constraints, workload routing, hashability.
* Dispatch failure modes: unknown solver names and unsupported kwargs
  raise ``ValueError`` naming the offender and the accepted set (never a
  bare ``KeyError``/``TypeError``).
* Backend fallbacks: degree-skew jax instances transparently take the
  NumPy path, recorded in the result diagnostics.
"""

import numpy as np
import pytest

from repro.core import (
    Constraint,
    Objective,
    OptimizeSpec,
    SOLVERS,
    VersionGraph,
    optimize,
    run_solver,
    solve_problem1,
    solve_problem2,
    solve_problem3,
    solve_problem4,
    solve_problem5,
    solve_problem6,
    spec_from_solver,
    zipf_weights,
)
from test_array_refactor import _instances


@pytest.fixture(scope="module")
def instances():
    return _instances()


# ---------------------------------------------------- bit-identity property
class TestOptimizeMatchesLegacyEntryPoints:
    """optimize(g, spec) ≡ solve_problemN on the full 56-instance suite."""

    def test_instance_count(self, instances):
        assert len(instances) >= 50

    def test_problem1(self, instances):
        for g in instances:
            ref = solve_problem1(g)
            res = optimize(g, OptimizeSpec.problem(1))
            assert res.problem == 1
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.storage_cost()

    def test_problem2(self, instances):
        for g in instances:
            ref = solve_problem2(g)
            res = optimize(g, OptimizeSpec.problem(2))
            assert res.problem == 2
            assert res.solution.parent == ref.parent
            assert res.solution.recreation_costs() == ref.recreation_costs()

    def test_problem3(self, instances):
        for g in instances:
            beta = solve_problem1(g).storage_cost() * 1.2
            ref = solve_problem3(g, beta)
            res = optimize(g, OptimizeSpec.problem(3, beta=beta))
            assert res.problem == 3
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.sum_recreation()
            assert res.constraint_slack["storage"] >= -1e-9

    def test_problem4(self, instances):
        for g in instances:
            beta = solve_problem1(g).storage_cost() * 1.3
            ref = solve_problem4(g, beta)
            res = optimize(g, OptimizeSpec.problem(4, beta=beta))
            assert res.problem == 4
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.max_recreation()

    def test_problem5(self, instances):
        for g in instances:
            theta = 0.5 * (
                solve_problem1(g).sum_recreation()
                + solve_problem2(g).sum_recreation()
            )
            ref = solve_problem5(g, theta)
            res = optimize(g, OptimizeSpec.problem(5, theta=theta))
            assert res.problem == 5
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.storage_cost()

    def test_problem6(self, instances):
        for g in instances:
            theta = solve_problem2(g).max_recreation() * 1.5
            ref = solve_problem6(g, theta)
            res = optimize(g, OptimizeSpec.problem(6, theta=theta))
            assert res.problem == 6
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.storage_cost()

    def test_workload_aware_parity(self, instances):
        # Problems 3 and 5 route the spec workload to the solver's weights
        for g in instances[:6]:
            w = zipf_weights(g.n, seed=5)
            beta = solve_problem1(g).storage_cost() * 1.25
            ref = solve_problem3(g, beta, weights=w)
            res = optimize(g, OptimizeSpec.problem(3, beta=beta, workload=w))
            assert res.solution.parent == ref.parent
            assert res.objective_value == ref.sum_recreation(w)

    def test_explicit_grid_point_equals_problem_constructor(self, instances):
        g = instances[0]
        beta = solve_problem1(g).storage_cost() * 1.2
        by_parts = OptimizeSpec(
            objective=Objective.sum_recreation(),
            constraints=(Constraint.storage_at_most(beta),),
        )
        assert by_parts == OptimizeSpec.problem(3, beta=beta)
        assert (
            optimize(g, by_parts).solution.parent
            == optimize(g, OptimizeSpec.problem(3, beta=beta)).solution.parent
        )

    def test_heuristic_specs(self, instances):
        from repro.core import git_heuristic, last_tree

        g = instances[1]
        res = optimize(g, OptimizeSpec.heuristic("gith", window=7, max_depth=9))
        assert res.problem is None and res.solver == "gith"
        assert res.solution.parent == git_heuristic(g, window=7, max_depth=9).parent
        res = optimize(g, OptimizeSpec.heuristic("last", alpha=2.0))
        assert res.solution.parent == last_tree(g, 2.0).parent


# ----------------------------------------------------------- spec validation
class TestSpecValidation:
    def test_off_grid_combinations_rejected(self):
        with pytest.raises(ValueError, match="off the paper grid"):
            OptimizeSpec(
                objective=Objective.max_recreation(),
                constraints=(Constraint.sum_recreation_at_most(1.0),),
            )
        with pytest.raises(ValueError, match="off the paper grid"):
            OptimizeSpec(objective=Objective.sum_recreation())

    def test_objective_cannot_be_constrained(self):
        with pytest.raises(ValueError, match="cannot also be constrained"):
            OptimizeSpec(
                objective=Objective.storage(),
                constraints=(Constraint.storage_at_most(10.0),),
            )

    def test_duplicate_constraints_rejected(self):
        with pytest.raises(ValueError, match="duplicate"):
            OptimizeSpec(
                objective=Objective.sum_recreation(),
                constraints=(
                    Constraint.storage_at_most(1.0),
                    Constraint.storage_at_most(2.0),
                ),
            )

    def test_unknown_metric_and_nonfinite_bound(self):
        with pytest.raises(ValueError, match="objective metric"):
            Objective("speed")
        with pytest.raises(ValueError, match="constraint metric"):
            Constraint("speed", 1.0)
        with pytest.raises(ValueError, match="finite"):
            Constraint.storage_at_most(float("inf"))

    def test_workload_only_on_lmg_problems(self):
        for n, kw in ((1, {}), (2, {}), (4, {"beta": 1.0}), (6, {"theta": 1.0})):
            with pytest.raises(ValueError, match="workload"):
                OptimizeSpec.problem(n, workload={1: 1.0}, **kw)
        # 3 and 5 accept it
        assert OptimizeSpec.problem(3, beta=1.0, workload={1: 1.0}).supports_workload()
        assert OptimizeSpec.problem(5, theta=1.0, workload={1: 1.0}).supports_workload()

    def test_problem_constructor_bounds(self):
        with pytest.raises(ValueError, match="requires beta"):
            OptimizeSpec.problem(3)
        with pytest.raises(ValueError, match="requires theta"):
            OptimizeSpec.problem(6)
        with pytest.raises(ValueError, match="does not take"):
            OptimizeSpec.problem(1, beta=1.0)
        with pytest.raises(ValueError, match="1..6"):
            OptimizeSpec.problem(7)

    def test_specs_are_hashable_and_equal(self):
        a = OptimizeSpec.problem(3, beta=5.0, workload={2: 0.25, 1: 0.75})
        b = OptimizeSpec.problem(3, beta=5.0, workload={1: 0.75, 2: 0.25})
        assert a == b and hash(a) == hash(b)
        assert a.weights() == {1: 0.75, 2: 0.25}
        assert hash(a) != hash(OptimizeSpec.problem(3, beta=6.0))

    def test_specs_hash_with_unhashable_options(self):
        # precomputed base/spt trees are legal options (benchmarks pass
        # them); they must not break the spec's hashable contract
        g = VersionGraph(2)
        g.set_materialization(1, 10, 10)
        g.set_materialization(2, 12, 12)
        g.set_delta(1, 2, 3, 3)
        mst = solve_problem1(g)
        a = OptimizeSpec.problem(3, beta=100.0, base=mst, spt=solve_problem2(g))
        b = OptimizeSpec.problem(3, beta=100.0, base=mst, spt=solve_problem2(g))
        assert isinstance(hash(a), int) and hash(a) == hash(b)
        assert optimize(g, a).solution.parent == solve_problem3(g, 100.0).parent

    def test_heuristic_solver_validation(self):
        with pytest.raises(ValueError, match="forcible heuristic"):
            OptimizeSpec(objective=Objective.storage(), solver="mp")
        with pytest.raises(ValueError, match="no constraints"):
            OptimizeSpec(
                objective=Objective.storage(),
                constraints=(Constraint.max_recreation_at_most(1.0),),
                solver="gith",
            )

    def test_unknown_backend(self):
        with pytest.raises(ValueError, match="backend"):
            OptimizeSpec.problem(1, backend="torch")

    def test_unknown_option_rejected_at_optimize(self):
        g = VersionGraph(1)
        g.set_materialization(1, 10, 10)
        with pytest.raises(ValueError, match="option"):
            optimize(g, OptimizeSpec.problem(1, frobnicate=3))


# ------------------------------------------------------- dispatch failure UX
class TestSolverDispatch:
    def _g(self):
        g = VersionGraph(2)
        g.set_materialization(1, 10, 10)
        g.set_materialization(2, 12, 12)
        g.set_delta(1, 2, 3, 3)
        return g

    def test_unknown_solver_name(self):
        g = self._g()
        with pytest.raises(ValueError, match=r"unknown solver 'quantum'.*accepted"):
            run_solver("quantum", g)
        # the registry itself explains misses too (no bare KeyError)
        with pytest.raises(ValueError, match=r"unknown solver 'quantum'.*accepted"):
            SOLVERS["quantum"]

    def test_unsupported_kwarg_named(self):
        g = self._g()
        with pytest.raises(ValueError, match=r"'mp'.*\['frobnicate'\].*accepted"):
            run_solver("mp", g, theta=100.0, frobnicate=1)
        with pytest.raises(ValueError, match=r"'gith'.*\['alpha'\]"):
            SOLVERS["gith"](g, alpha=2.0)

    def test_missing_required_kwarg_named(self):
        g = self._g()
        with pytest.raises(ValueError, match=r"'lmg' requires.*budget"):
            run_solver("lmg", g)
        with pytest.raises(ValueError, match=r"'mp' requires.*theta"):
            SOLVERS["mp"](g)

    def test_valid_dispatch_still_works(self):
        g = self._g()
        sol = run_solver("mca", g)
        assert sol.parent == {1: 0, 2: 1}
        sol = SOLVERS["lmg"](g, budget=1e9)
        sol.validate()

    def test_spec_from_solver_roundtrip(self):
        g = self._g()
        spec = spec_from_solver("mp", {"theta": 100.0})
        assert spec == OptimizeSpec.problem(6, theta=100.0)
        spec = spec_from_solver("lmg", {"budget": 50.0, "weights": {1: 1.0}})
        assert spec.problem_id() == 3 and spec.weights() == {1: 1.0}
        spec = spec_from_solver("gith", {"window": 3})
        assert spec.solver == "gith" and spec.options_dict() == {"window": 3}
        with pytest.raises(ValueError, match="unknown solver"):
            spec_from_solver("quantum", {})
        with pytest.raises(ValueError, match="does not accept"):
            spec_from_solver("spt", {"theta": 1.0})
        # a delta-weighted SPT is not a grid point: refuse, don't drop
        with pytest.raises(ValueError, match="phi"):
            spec_from_solver("spt", {"weight": "delta"})
        assert spec_from_solver("spt", {"weight": "phi"}) == OptimizeSpec.problem(2)


# --------------------------------------------------------- backend fallbacks
class TestBackendFallbacks:
    def test_degree_skew_falls_back_to_numpy(self):
        # hub vertex whose out-degree would blow up the dense padded out-row
        # layout of the jitted MP: optimize takes the bit-identical CSR host
        # path instead of raising (same instance as the jax-backend guard
        # test, which asserts the *solver* still refuses loudly)
        from repro.core.solvers import jax_backend

        n = 8192
        g = VersionGraph(n, directed=True)
        ids = np.arange(1, n + 1, dtype=np.int64)
        ones = np.ones(n, dtype=np.float64)
        g.add_edges_bulk(np.zeros(n, dtype=np.int64), ids, 100 * ones, ones)
        hub_dst = ids[1:]  # vertex 1 -> everyone else
        g.add_edges_bulk(
            np.full(n - 1, 1, dtype=np.int64), hub_dst, ones[1:], ones[1:],
        )
        assert 16384 * hub_dst.shape[0] > jax_backend.MAX_PADDED_CELLS
        # direct solver callers get the typed refusal...
        from repro.core.solvers import BackendUnsupported

        with pytest.raises(BackendUnsupported, match="degree skew"):
            run_solver("mp", g, theta=1e9, backend="jax")
        # ...optimize() falls back transparently and records it
        res = optimize(g, OptimizeSpec.problem(6, theta=1e9, backend="jax"))
        assert res.backend_used == "numpy"
        assert "degree skew" in res.diagnostics["backend_fallback"]
        ref = solve_problem6(g, 1e9)
        assert res.solution.parent == ref.parent

    def test_directed_mca_records_host_path(self, ):
        g = VersionGraph(3, directed=True)
        for i in range(1, 4):
            g.set_materialization(i, 100.0 * i, 100.0 * i)
        g.set_delta(1, 2, 5, 5)
        g.set_delta(2, 3, 7, 7)
        res = optimize(g, OptimizeSpec.problem(1, backend="jax"))
        assert res.backend_used == "numpy"
        assert "Edmonds" in res.diagnostics["backend_fallback"]
        assert res.solution.parent == solve_problem1(g).parent

    def test_jax_backend_parity_through_specs(self):
        from repro.core import generate, dc_like

        g = generate(dc_like(60, seed=3)).graph
        a = optimize(g, OptimizeSpec.problem(2))
        b = optimize(g, OptimizeSpec.problem(2, backend="jax"))
        assert b.backend_used == "jax" and not b.diagnostics
        assert a.solution.parent == b.solution.parent
        assert a.objective_value == b.objective_value


# --------------------------------------------------------------- result shape
class TestOptimizeResult:
    def test_result_fields(self):
        from repro.core import generate, dc_like

        g = generate(dc_like(40, seed=2)).graph
        beta = solve_problem1(g).storage_cost() * 1.3
        res = optimize(g, OptimizeSpec.problem(3, beta=beta))
        assert res.solver == "lmg" and res.backend_used == "numpy"
        assert set(res.objective_values) == {
            "storage", "sum_recreation", "max_recreation",
        }
        assert res.constraint_slack["storage"] == pytest.approx(
            beta - res.objective_values["storage"]
        )
        assert res.wall_time_s >= 0
        assert "P3" in res.summary()

    def test_optimize_rejects_strings(self):
        g = VersionGraph(1)
        g.set_materialization(1, 1, 1)
        with pytest.raises(TypeError, match="OptimizeSpec"):
            optimize(g, "lmg")

    def test_infeasible_bounds_still_raise(self):
        from repro.core import InfeasibleError, generate, dc_like

        g = generate(dc_like(30, seed=1)).graph
        tight = solve_problem2(g).max_recreation() * 0.5
        with pytest.raises(InfeasibleError):
            optimize(g, OptimizeSpec.problem(6, theta=tight))
