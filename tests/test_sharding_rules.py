"""Logical-axis sharding rules: spec construction, fallbacks, degradation.

Pure PartitionSpec logic — no devices needed (mesh built with AbstractMesh-
style shape inspection via jax.sharding.Mesh over the single local device is
not possible for 16x16, so we use jax.sharding.AbstractMesh).
"""

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.distributed.sharding import DEFAULT_RULES, spec_for

def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: old builds take ((name, size), ...),
    newer ones take (axis_sizes, axis_names)."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


class TestSpecFor:
    def test_weight_fsdp_tp(self):
        spec = spec_for(("embed", "mlp"), (5120, 25600), mesh=MESH)
        assert spec == P("data", "model")

    def test_multi_axis_batch_on_multipod(self):
        spec = spec_for(("batch", "seq"), (256, 4096), mesh=MESH3)
        assert spec == P(("pod", "data"))  # trailing None trimmed

    def test_kv_heads_too_small_replicates(self):
        spec = spec_for(("embed", "kv_heads", None), (4608, 8, 128), mesh=MESH)
        assert spec == P("data")  # kv=8 < 16 -> replicated, trailing None trimmed

    def test_strict_divisibility_blocks_uneven(self):
        # 36 heads on a 16-way axis: strict (jit args) replicates...
        assert spec_for(("heads",), (36,), mesh=MESH, strict=True) == P()
        # ...but activation constraints shard unevenly (GSPMD pads)
        assert spec_for(("heads",), (36,), mesh=MESH, strict=False) == P("model")

    def test_axis_tuple_degradation(self):
        # experts=16 cannot take ("data","model")=256 ways; degrades to model
        rules = dict(DEFAULT_RULES)
        spec = spec_for(("experts_ep", None, None), (16, 4096, 6400),
                        mesh=MESH, rules=rules)
        assert spec == P("model")

    def test_no_axis_reuse(self):
        # two logical dims mapping to the same mesh axis: second one drops
        spec = spec_for(("vocab", "mlp"), (1024, 1024), mesh=MESH)
        assert spec == P("model", None) or spec == P("model")

    def test_pod_only_rule(self):
        spec = spec_for(("expert_fsdp",), (7168,), mesh=MESH3)
        assert spec == P("pod")
        # single-pod mesh: pod absent -> replicated
        assert spec_for(("expert_fsdp",), (7168,), mesh=MESH) == P()
