"""Store-layer refactor tests: one-codec compression (zstd→zlib fallback),
incremental Δ/Φ measurement, and repack idempotence + checkout roundtrips."""

import numpy as np
import pytest

from repro.store import Codec, ObjectStore, VersionStore, flatten_payload

from test_store import build_linear_history, make_payload, perturb


class TestCodec:
    def test_zlib_roundtrip(self):
        c = Codec(backend="zlib")
        blob = c.compress(b"versioned bytes " * 500)
        assert c.decompress(blob) == b"versioned bytes " * 500
        assert c.compressed_size(b"versioned bytes " * 500) == len(blob)

    def test_magic_dispatch_reads_zlib_everywhere(self):
        # a zlib-written blob decompresses regardless of the default backend
        writer = Codec(backend="zlib")
        reader = Codec()  # whatever backend the environment provides
        assert reader.decompress(writer.compress(b"x" * 100)) == b"x" * 100

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError):
            Codec(backend="lz77")

    def test_objectstore_with_explicit_zlib(self, tmp_path):
        st = ObjectStore(tmp_path, codec=Codec(backend="zlib"))
        k1, s1 = st.put(b"hello world" * 1000)
        k2, _ = st.put(b"hello world" * 1000)
        assert k1 == k2  # content-addressed dedup
        assert st.get(k1) == b"hello world" * 1000
        assert s1 < 11000


class TestIncrementalCostGraph:
    def test_second_build_measures_nothing(self, tmp_path):
        store = VersionStore(tmp_path)
        build_linear_history(store, n=5)
        store.build_cost_graph()
        first = store.last_measured_edges
        assert first > 0
        g2, _ = store.build_cost_graph()
        assert store.last_measured_edges == 0
        assert g2.n == 5

    def test_new_commit_measures_only_the_delta(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, payload = build_linear_history(store, n=6)
        store.build_cost_graph()
        full = store.last_measured_edges
        rng = np.random.RandomState(42)
        store.commit(perturb(payload, rng), parents=[vids[-1]])
        store.build_cost_graph()
        incremental = store.last_measured_edges
        assert 0 < incremental < full

    def test_cache_survives_reopen(self, tmp_path):
        store = VersionStore(tmp_path)
        build_linear_history(store, n=4)
        store.build_cost_graph()
        del store
        store2 = VersionStore(tmp_path)
        store2.build_cost_graph()
        assert store2.last_measured_edges == 0

    def test_cached_graph_matches_fresh_measurement(self, tmp_path):
        store = VersionStore(tmp_path)
        build_linear_history(store, n=5)
        g1, _ = store.build_cost_graph()
        g2, _ = store.build_cost_graph()  # fully from cache
        e1 = {(s, d): (c.delta, c.phi) for s, d, c in g1.edges()}
        e2 = {(s, d): (c.delta, c.phi) for s, d, c in g2.edges()}
        assert e1 == e2


class TestRepackRoundtrip:
    @pytest.mark.parametrize("solver,kw", [
        ("mca", {}),
        ("spt", {}),
        ("last", {"alpha": 2.0}),
        ("gith", {"window": 5, "max_depth": 5}),
        ("lmg", {"budget_mult": 1.4}),
        ("mp", {"theta_mult": 1.5}),
    ])
    def test_repack_then_checkout_equals_original(self, tmp_path, solver, kw):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=6)
        originals = {v: store.checkout(v) for v in vids}
        kw = dict(kw)
        if "budget_mult" in kw:
            from repro.core import minimum_storage_tree

            g, _ = store.build_cost_graph()
            kw = {"budget": minimum_storage_tree(g).storage_cost()
                  * kw["budget_mult"]}
        elif "theta_mult" in kw:
            from repro.core import shortest_path_tree

            g, _ = store.build_cost_graph()
            kw = {"theta": shortest_path_tree(g).max_recreation()
                  * kw["theta_mult"]}
        store.repack(solver, **kw)
        for v in vids:
            rec = store.checkout(v)
            assert set(rec) == set(originals[v])
            for k in originals[v]:
                np.testing.assert_array_equal(rec[k], originals[v][k])

    def test_repack_idempotent(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=6)
        store.repack("mca")
        bytes_1 = store.storage_bytes()
        bases_1 = [m.stored_base for m in store.log()]
        stats = store.repack("mca")
        assert store.storage_bytes() == bytes_1
        assert [m.stored_base for m in store.log()] == bases_1
        assert stats["before"]["storage_bytes"] == stats["after"]["storage_bytes"]
        # second repack's cost graph came fully from the cache
        assert store.last_measured_edges == 0

    def test_fingerprints_recorded_on_commit(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=3)
        assert all(len(m.content_fp) == 64 for m in store.log())
