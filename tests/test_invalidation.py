"""Append-aware cache invalidation semantics, pinned end to end.

The load-bearing store change of the service tier: cache entries carry a
fingerprint of *their own* decode chain (the ``(vid, stored_base,
object_key)`` triples down to the full root) instead of one global
storage-graph epoch.  Contract under test:

* a **commit** appends versions but rewrites no existing chain, so it must
  not evict any warm entry it can't reach — on any branch, under any
  parent topology, with zero invalidation counters moving;
* a **repack** rewrites chains wholesale and must still purge everything;
* correctness is pinned against a stepwise zero-budget oracle store:
  whatever the cache discipline keeps or drops, served trees stay
  bit-identical to a from-scratch decode;
* **chain fingerprints** change exactly when the entry's own chain
  changes (metadata edits to *other* chains leave them fixed) and the
  stale entry is dropped lazily at lookup;
* the legacy ``cache_invalidation="global"`` mode still purges on every
  commit (the baseline the serving benchmark compares against);
* the byte-budget LRU keeps working unchanged underneath the tags.
"""

import numpy as np
import pytest

from repro.core import OptimizeSpec
from repro.store import VersionStore


def payload(seed: int, shape=(64, 48)):
    # large enough to span several delta blocks: a 2-row perturbation then
    # encodes smaller than full, so commits actually store delta chains
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(*shape).astype(np.float32)}


def perturbed(base, seed: int):
    rng = np.random.RandomState(seed)
    out = {"w": base["w"].copy()}
    out["w"][:2] += rng.randn(2, base["w"].shape[1]).astype(np.float32)
    return out


def build_branching(tmp_path, **kwargs):
    """Two branches off a shared root: A = 1->2->3, B = 1->4->5."""
    store = VersionStore(tmp_path, **kwargs)
    p1 = payload(0)
    v1 = store.commit(p1, message="root")
    pa = perturbed(p1, 1)
    va = store.commit(pa, parents=[v1], message="a1")
    pa2 = perturbed(pa, 2)
    va2 = store.commit(pa2, parents=[va], message="a2")
    pb = perturbed(p1, 3)
    vb = store.commit(pb, parents=[v1], message="b1")
    pb2 = perturbed(pb, 4)
    vb2 = store.commit(pb2, parents=[vb], message="b2")
    trees = {v1: p1, va: pa, va2: pa2, vb: pb, vb2: pb2}
    return store, trees, (v1, va, va2, vb, vb2)


class TestCommitKeepsWarmEntries:
    def test_commit_on_other_branch_keeps_entries_warm(self, tmp_path):
        store, trees, (v1, va, va2, vb, vb2) = build_branching(tmp_path)
        store.checkout_many([va2, vb2])  # warm both branch tips

        # commit onto branch B: branch A's warm tip is unreachable from it
        # (the commit itself materializes its parent, hence s0 comes after)
        store.commit(perturbed(trees[vb2], 9), parents=[vb2], message="b3")
        s0 = store.materializer.stats()

        t = store.checkout(va2)
        s1 = store.materializer.stats()
        assert np.array_equal(t["w"], trees[va2]["w"])
        assert s1["hits"] == s0["hits"] + 1  # served from cache
        assert s1["invalidations"] == s0["invalidations"] == 0
        assert s1["purges"] == 0
        assert s1["full_decodes"] == s0["full_decodes"]

    def test_fifty_commits_zero_invalidation(self, tmp_path):
        store, trees, vids = build_branching(tmp_path)
        hot = list(vids)
        store.checkout_many(hot)
        tip = vids[-1]
        last = trees[tip]
        for i in range(50):
            last = perturbed(last, 100 + i)
            tip = store.commit(last, parents=[tip], message=f"append {i}")
        s0 = store.materializer.stats()
        for v in hot:
            assert np.array_equal(store.checkout(v)["w"], trees[v]["w"])
        s1 = store.materializer.stats()
        assert s1["hits"] - s0["hits"] == len(hot)
        assert s1["invalidations"] == 0 and s1["purges"] == 0

    def test_global_epoch_still_rotates_underneath(self, tmp_path):
        """The fsck/audit epoch (storage_fingerprint) must keep rotating on
        commit even though the chain-mode cache no longer keys off it."""
        store, trees, vids = build_branching(tmp_path)
        fp0 = store.storage_fingerprint()
        chain_fps = {v: store.chain_fingerprint(v) for v in vids}
        store.commit(perturbed(trees[vids[-1]], 9), parents=[vids[-1]],
                     message="append")
        assert store.storage_fingerprint() != fp0
        for v in vids:
            assert store.chain_fingerprint(v) == chain_fps[v]

    def test_global_mode_purges_on_commit(self, tmp_path):
        store, trees, vids = build_branching(
            tmp_path, cache_invalidation="global"
        )
        store.checkout_many(list(vids))
        store.commit(perturbed(trees[vids[-1]], 9), parents=[vids[-1]],
                     message="append")
        s0 = store.materializer.stats()
        t = store.checkout(vids[0])
        s1 = store.materializer.stats()
        assert np.array_equal(t["w"], trees[vids[0]]["w"])
        assert s1["misses"] == s0["misses"] + 1  # cold again: epoch rotated
        # epoch rotation counts as an invalidation event (purges is repack's)
        assert s1["invalidations"] >= 1

    def test_invalid_mode_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="invalidation"):
            VersionStore(tmp_path, cache_invalidation="sometimes")


class TestRepackPurges:
    def test_repack_purges_wholesale_and_trees_survive(self, tmp_path):
        store, trees, vids = build_branching(tmp_path)
        store.checkout_many(list(vids))
        assert store.materializer.stats()["entries"] == len(vids)

        store.repack(OptimizeSpec.problem(2))

        s = store.materializer.stats()
        assert s["purges"] >= 1
        assert s["entries"] == 0
        for v, want in trees.items():
            assert np.array_equal(store.checkout(v)["w"], want["w"])

    def test_chain_fingerprints_rotate_exactly_with_chain_changes(
        self, tmp_path
    ):
        def triples(store, v):
            out = []
            while v is not None:
                m = store.versions[v]
                out.append((v, m.stored_base, m.object_key))
                v = m.stored_base
            return tuple(out)

        store, trees, vids = build_branching(tmp_path)
        before_fp = {v: store.chain_fingerprint(v) for v in vids}
        before_ch = {v: triples(store, v) for v in vids}
        # min-recreation (SPT): flattens chains, rewriting delta storage
        store.repack(OptimizeSpec.problem(2))
        changed = [v for v in vids if triples(store, v) != before_ch[v]]
        assert changed  # the branching build always stores some deltas
        for v in vids:
            rotated = store.chain_fingerprint(v) != before_fp[v]
            assert rotated == (v in changed), v


class TestOracleParity:
    def test_chain_mode_matches_stepwise_zero_budget_oracle(self, tmp_path):
        """Whatever the tagged cache keeps across commits, served bytes must
        equal a stepwise from-scratch decode of the same storage graph."""
        root = tmp_path / "store"
        store = VersionStore(root, cache_budget_bytes=64 << 20)
        rng = np.random.RandomState(7)
        tree = payload(7)
        tip = store.commit(tree, message="root")
        vids = [tip]
        for i in range(20):
            tree = perturbed(tree, 200 + i)
            if i % 5 == 4:  # occasional branch off an older version
                parent = vids[rng.randint(0, len(vids))]
                tip = store.commit(tree, parents=[int(parent)],
                                   message=f"branch {i}")
            else:
                tip = store.commit(tree, parents=[tip], message=f"c{i}")
            vids.append(tip)
            # interleave reads so the cache is warm while the graph grows
            store.checkout(vids[rng.randint(0, len(vids))])

        oracle = VersionStore(root, cache_budget_bytes=0, fuse_chains=False)
        for v in vids:
            got = store.checkout(v)
            want = oracle.checkout(v)
            assert set(got) == set(want)
            for k in want:
                assert np.array_equal(got[k], want[k]), (v, k)

    def test_stale_entry_dropped_lazily_on_lookup(self, tmp_path):
        store, trees, vids = build_branching(tmp_path)
        v = vids[2]
        store.checkout(v)  # warm
        m = store.materializer
        assert m.probe(v)
        # simulate an out-of-band chain rewrite: the entry's tag no longer
        # matches its chain fingerprint and must be dropped at lookup
        meta = store.versions[v]
        object.__setattr__(meta, "object_key", meta.object_key)
        with m.cache._lock:
            tree, nbytes, _ = m.cache._entries[v]
            m.cache._entries[v] = (tree, nbytes, "stale-tag")
        assert not m.probe(v)
        s0 = m.stats()
        t = store.checkout(v)
        s1 = m.stats()
        assert np.array_equal(t["w"], trees[v]["w"])
        assert s1["invalidations"] == s0["invalidations"] + 1
        assert m.probe(v)  # rebuilt and re-tagged

    def test_prefetch_rewarms_stale_tagged_entry(self, tmp_path):
        """Regression: prefetch skipped any vid already *present* in the
        cache, so a stale-tagged entry (which get() would reject) blocked
        re-warming that vid forever."""
        store, trees, vids = build_branching(tmp_path)
        v = vids[2]
        store.checkout(v)  # warm
        m = store.materializer
        with m.cache._lock:
            tree, nbytes, _ = m.cache._entries[v]
            m.cache._entries[v] = (tree, nbytes, "stale-tag")
        assert not m.probe(v)
        assert m.prefetch([v]) == 1  # was 0: bare containment skipped it
        assert m.probe(v)
        assert np.array_equal(store.checkout(v)["w"], trees[v]["w"])


class TestEvictionUnchanged:
    def test_lru_byte_budget_still_enforced(self, tmp_path):
        one_entry = 64 * 48 * 4  # payload bytes per version
        store, trees, vids = build_branching(
            tmp_path, cache_budget_bytes=int(one_entry * 2.5)
        )
        for v in vids:
            store.checkout(v)
        s = store.materializer.stats()
        assert s["evictions"] >= len(vids) - 2
        assert s["current_bytes"] <= int(one_entry * 2.5)
        # evicted versions still decode correctly
        for v, want in trees.items():
            assert np.array_equal(store.checkout(v)["w"], want["w"])

    def test_checkout_many_survives_eviction_between_plan_and_execute(
        self, tmp_path
    ):
        """A planned-cached vid whose entry vanished must rebuild, not
        assert: pin the fallback path by evicting mid-sequence."""
        store, trees, vids = build_branching(tmp_path)
        store.checkout_many(list(vids))
        # drop one entry behind the planner's back
        victim = vids[2]
        with store.materializer.cache._lock:
            ent = store.materializer.cache._entries.pop(victim)
            store.materializer.cache.current_bytes -= ent[1]
        out = store.checkout_many(list(vids))
        for t, v in zip(out, vids):
            assert np.array_equal(t["w"], trees[v]["w"])


class TestChainFingerprint:
    def test_fingerprint_depends_only_on_own_chain(self, tmp_path):
        store, trees, (v1, va, va2, vb, vb2) = build_branching(tmp_path)
        fa = store.chain_fingerprint(va2)
        fb = store.chain_fingerprint(vb2)
        assert fa != fb
        # recomputation is deterministic
        assert store.chain_fingerprint(va2) == fa

    def test_fingerprint_detects_cycle(self, tmp_path):
        store, trees, vids = build_branching(tmp_path)
        v = vids[2]
        store.versions[v].stored_base = v  # corrupt: self-cycle
        with pytest.raises(RuntimeError, match="cycle"):
            store.chain_fingerprint(v)

    def test_fingerprint_survives_reopen(self, tmp_path):
        store, trees, vids = build_branching(tmp_path)
        fps = {v: store.chain_fingerprint(v) for v in vids}
        store.close()
        reopened = VersionStore(tmp_path)
        for v, fp in fps.items():
            assert reopened.chain_fingerprint(v) == fp
