"""Data pipeline determinism + resumability (the fault-tolerance contract)."""

import numpy as np

from repro.data.pipeline import PipelineState, SyntheticTokenPipeline


class TestDeterminism:
    def test_same_seed_same_stream(self):
        a = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
        b = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=7)
        for _ in range(3):
            np.testing.assert_array_equal(a.next_batch()["tokens"],
                                          b.next_batch()["tokens"])

    def test_hosts_get_disjoint_shards(self):
        h0 = SyntheticTokenPipeline(vocab=1000, seq_len=32, global_batch=8,
                                    host_id=0, n_hosts=2, seed=0)
        h1 = SyntheticTokenPipeline(vocab=1000, seq_len=32, global_batch=8,
                                    host_id=1, n_hosts=2, seed=0)
        b0, b1 = h0.next_batch()["tokens"], h1.next_batch()["tokens"]
        assert b0.shape == (4, 32) and b1.shape == (4, 32)
        assert not np.array_equal(b0, b1)

    def test_snapshot_resume_exact(self):
        p = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=1)
        p.next_batch(); p.next_batch()
        snap = p.snapshot()
        want = p.next_batch()["tokens"]
        q = SyntheticTokenPipeline(vocab=100, seq_len=16, global_batch=4, seed=1)
        q.restore(snap)
        np.testing.assert_array_equal(q.next_batch()["tokens"], want)

    def test_state_roundtrip(self):
        s = PipelineState(step=5, epoch=2)
        assert PipelineState.from_dict(s.to_dict()) == s
