"""VersionStore thread-safety: concurrent checkout/commit interleavings.

The service tier runs checkouts on a reader thread pool concurrent with a
single writer thread; the store guarantees that depends on:

* cache insert/evict/get under the cache's own lock — concurrent readers
  hammering overlapping vids while the LRU evicts never corrupt the byte
  accounting or serve a torn tree;
* access-count bumps, vid allocation, metadata insertion and the atomic
  ``_save_meta`` under the store lock — counts reconcile exactly and the
  metadata file reloads cleanly after any interleaving;
* commits are pure appends: readers racing a committer always see either
  a complete old graph or a complete new one, and every tree served is
  bit-identical to its committed payload.

Threads + barriers only (no service layer here): this pins the *store*
contract the service builds on, tier-1 fast.
"""

import threading

import numpy as np

from repro.store import VersionStore


def payload(seed: int, shape=(64, 48)):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(*shape).astype(np.float32)}


def perturbed(base, seed: int):
    rng = np.random.RandomState(seed)
    out = {"w": base["w"].copy()}
    out["w"][:2] += rng.randn(2, base["w"].shape[1]).astype(np.float32)
    return out


def build_chain(tmp_path, n=8, **kwargs):
    store = VersionStore(tmp_path, **kwargs)
    trees = {}
    tree = payload(0)
    vid = store.commit(tree, message="root")
    trees[vid] = tree
    for i in range(n - 1):
        tree = perturbed(tree, i + 1)
        vid = store.commit(tree, parents=[vid], message=f"c{i}")
        trees[vid] = tree
    return store, trees


class TestConcurrentReadsDuringCommits:
    def test_readers_race_committer_trees_stay_correct(self, tmp_path):
        store, trees = build_chain(tmp_path, n=8)
        hot = sorted(trees)
        errors = []
        stop = threading.Event()
        barrier = threading.Barrier(5)

        def reader(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            while not stop.is_set():
                v = int(hot[rng.randint(0, len(hot))])
                t = store.checkout(v)
                if not np.array_equal(t["w"], trees[v]["w"]):
                    errors.append(("torn tree", v))
                    return

        readers = [
            threading.Thread(target=reader, args=(i,)) for i in range(4)
        ]
        for r in readers:
            r.start()
        barrier.wait()
        tip, tree = hot[-1], trees[hot[-1]]
        new = {}
        for i in range(30):  # writer: 30 appends racing the 4 readers
            tree = perturbed(tree, 1000 + i)
            tip = store.commit(tree, parents=[tip], message=f"race {i}")
            new[tip] = tree
        stop.set()
        for r in readers:
            r.join(timeout=30)
            assert not r.is_alive()
        assert not errors, errors
        # every racing commit landed intact too
        for v, want in new.items():
            assert np.array_equal(store.checkout(v)["w"], want["w"])
        assert store.materializer.stats()["invalidations"] == 0

    def test_concurrent_checkout_many_batches(self, tmp_path):
        store, trees = build_chain(tmp_path, n=10)
        hot = sorted(trees)
        errors = []
        barrier = threading.Barrier(4)

        def batch_reader(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            for _ in range(15):
                batch = [
                    int(hot[j])
                    for j in rng.randint(0, len(hot), size=4)
                ]
                out = store.checkout_many(batch)
                for t, v in zip(out, batch):
                    if not np.array_equal(t["w"], trees[v]["w"]):
                        errors.append(("torn batch tree", v))
                        return

        threads = [
            threading.Thread(target=batch_reader, args=(i,))
            for i in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()
        assert not errors, errors


class TestAccessCountConsistency:
    def test_counts_reconcile_exactly_across_threads(self, tmp_path):
        reads_per_thread, nthreads = 40, 4
        store, trees = build_chain(
            tmp_path, n=6, access_flush_every=1 << 30
        )
        hot = sorted(trees)
        base = {v: store.versions[v].access_count for v in hot}
        barrier = threading.Barrier(nthreads)

        def reader(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            for _ in range(reads_per_thread):
                store.checkout(int(hot[rng.randint(0, len(hot))]))

        threads = [
            threading.Thread(target=reader, args=(i,))
            for i in range(nthreads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        total = sum(
            store.versions[v].access_count - base[v] for v in hot
        )
        assert total == reads_per_thread * nthreads  # no lost updates
        store.flush_access_counts()
        reopened = VersionStore(tmp_path)
        assert (
            sum(reopened.versions[v].access_count - base[v] for v in hot)
            == total
        )

    def test_concurrent_flush_and_reads(self, tmp_path):
        store, trees = build_chain(tmp_path, n=6, access_flush_every=3)
        hot = sorted(trees)
        errors = []
        barrier = threading.Barrier(4)

        def reader(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            try:
                for _ in range(25):  # flush_every=3 -> flushes mid-race
                    store.checkout(int(hot[rng.randint(0, len(hot))]))
            except Exception as e:  # pragma: no cover - the failure mode
                errors.append(e)

        threads = [
            threading.Thread(target=reader, args=(i,)) for i in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        # the metadata written by racing flushes reloads cleanly
        reopened = VersionStore(tmp_path)
        assert sorted(reopened.versions) == hot


class TestMetaReloadsCleanAfterRace:
    def test_meta_consistent_after_racing_commits_and_reads(self, tmp_path):
        store, trees = build_chain(tmp_path, n=4)
        hot = sorted(trees)
        committed = {}
        lock = threading.Lock()
        barrier = threading.Barrier(3)

        def committer(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            tree = trees[hot[-1]]
            for i in range(15):
                tree = perturbed(tree, seed * 1000 + i)
                parent = int(hot[rng.randint(0, len(hot))])
                vid = store.commit(
                    tree, parents=[parent], message=f"t{seed}-{i}"
                )
                with lock:
                    committed[vid] = tree

        # two committer threads: vid allocation and meta writes must not
        # collide even though the service tier serializes writers itself
        threads = [
            threading.Thread(target=committer, args=(i,)) for i in range(2)
        ]

        def reader():
            barrier.wait()
            for _ in range(40):
                store.checkout(int(hot[0]))

        threads.append(threading.Thread(target=reader))
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()

        assert len(committed) == 30  # no vid collisions swallowed a commit
        for v, want in committed.items():
            assert np.array_equal(store.checkout(v)["w"], want["w"])
        store.flush_access_counts()
        reopened = VersionStore(tmp_path)
        assert sorted(reopened.versions) == sorted(store.versions)
        for v, want in committed.items():
            assert np.array_equal(reopened.checkout(v)["w"], want["w"])


class TestCacheUnderContention:
    def test_tiny_budget_evictions_race_reads(self, tmp_path):
        one_entry = 64 * 48 * 4
        store, trees = build_chain(
            tmp_path, n=8, cache_budget_bytes=int(one_entry * 2.5)
        )
        hot = sorted(trees)
        errors = []
        barrier = threading.Barrier(4)

        def reader(seed):
            rng = np.random.RandomState(seed)
            barrier.wait()
            for _ in range(20):
                v = int(hot[rng.randint(0, len(hot))])
                t = store.checkout(v)
                if not np.array_equal(t["w"], trees[v]["w"]):
                    errors.append(("torn under eviction", v))
                    return

        threads = [
            threading.Thread(target=reader, args=(i,)) for i in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        s = store.materializer.stats()
        assert s["current_bytes"] <= int(one_entry * 2.5)
        assert s["current_bytes"] >= 0  # accounting never went negative
