"""Kernel auditor tests: the repo audits clean, and each rule actually fires
on deliberately bad inputs.

* full-run regression: ``run_audit()`` over the real kernel/solver registry
  has zero non-baselined gating findings against the committed baseline
  (the CI gate, exercised as a test);
* per-rule unit tests on synthetic bad targets: 64-bit jaxpr values, host
  syncs inside loops, broken bucket functions, unaliased large pallas
  outputs;
* baseline framework semantics: keying, NOTE exemption, severity
  escalation re-gating, write/load round-trip.
"""

import ast

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import findings as F
from repro.analysis import kernel_audit as KA

S = jax.ShapeDtypeStruct


@pytest.fixture(scope="module")
def audit_report():
    return KA.run_audit()


class TestFullAudit:
    def test_zero_new_findings_vs_committed_baseline(self, audit_report):
        baseline = F.load_baseline("analysis_baseline.json")
        new, _ = F.partition(audit_report.findings, baseline)
        assert new == [], "\n".join(f.render() for f in new)

    def test_every_target_traced(self, audit_report):
        # audit.trace counts targets; a trace failure is itself a finding
        assert audit_report.checked["audit.trace"] == len(KA.audit_targets())
        assert audit_report.by_rule("audit.trace") == []

    def test_solver_targets_fully_32bit(self, audit_report):
        # the f32 flip deleted the jax_backend allowlist entries: solver
        # jaxprs must now be 64-bit-free outright, not downgraded to NOTE
        assert audit_report.by_rule("audit.dtype64") == []
        for f in audit_report.by_rule("audit.dtype64-source"):
            assert not f.subject.endswith("jax_backend"), f.render()


def _target(fn, *avals, name="test.target"):
    return KA.AuditTarget(name, lambda: (fn, avals))


class TestDtype64Rule:
    def test_explicit_astype_int64_flags(self):
        t = _target(lambda x: x.astype(jnp.int64), S((8,), jnp.int32))
        r = F.Report(tool="audit")
        KA.check_dtype64(r, t, KA.trace_target(t))
        (f,) = r.by_rule("audit.dtype64")
        assert f.severity == F.Severity.ERROR and "int64" in f.message

    def test_weak_scalars_and_i32_math_clean(self):
        t = _target(lambda x: (x + 1) * 2, S((8,), jnp.int32))
        r = F.Report(tool="audit")
        KA.check_dtype64(r, t, KA.trace_target(t))
        assert r.findings == []

    def test_default_argmin_under_x64_flags(self):
        # the exact hazard class segment_ops was fixed for
        t = _target(lambda x: jnp.argmin(x), S((32,), jnp.float32))
        r = F.Report(tool="audit")
        KA.check_dtype64(r, t, KA.trace_target(t))
        assert any("int64" in f.message for f in r.findings)


class TestHostSyncRule:
    def test_sync_in_loop_detected(self):
        src = (
            "def f(items):\n"
            "    out = []\n"
            "    for x in items:\n"
            "        out.append(np.asarray(x))\n"
            "        y = x.item()\n"
            "    return out\n"
        )
        fn = ast.parse(src).body[0]
        names = {n for _, n in KA._sync_calls_in_loops(fn)}
        assert names == {"np.asarray", ".item"}

    def test_sync_after_loop_clean(self):
        src = (
            "def f(items):\n"
            "    pending = []\n"
            "    for x in items:\n"
            "        pending.append(g(x))\n"
            "    return jax.device_get(pending)\n"
        )
        fn = ast.parse(src).body[0]
        assert KA._sync_calls_in_loops(fn) == []

    def test_hot_path_registry_matches_source(self):
        # config rot guard: every registered hot-path function must exist
        r = F.Report(tool="audit")
        for module, fns in KA.HOT_PATH_FUNCTIONS.items():
            KA.check_host_sync(r, module, fns)
        stale = [f for f in r.findings if "stale" in f.message]
        assert stale == [], "\n".join(f.render() for f in stale)
        # and the actual hot path is currently sync-free in loops
        assert r.findings == [], "\n".join(f.render() for f in r.findings)


class TestBucketRule:
    def test_identity_bucket_rejected(self):
        c = KA.BucketContract("test.identity", lambda k: k, "pow2",
                              max_check=64)
        r = F.Report(tool="audit")
        KA.check_bucket_contract(r, c)
        (f,) = r.by_rule("audit.shape-bucket")
        assert f.severity == F.Severity.ERROR

    def test_undersized_bucket_rejected(self):
        c = KA.BucketContract("test.halve", lambda k: max(8, k // 2), "pow2",
                              max_check=64)
        r = F.Report(tool="audit")
        KA.check_bucket_contract(r, c)
        assert any("dropped data" in f.message for f in r.findings)

    def test_real_buckets_pass(self):
        r = F.Report(tool="audit")
        for c in KA.bucket_contracts():
            KA.check_bucket_contract(r, c)
        assert r.findings == [], "\n".join(f.render() for f in r.findings)

    def test_probe_catches_unbucketed_shapes(self):
        # a probe whose "bucket" is the raw size must split signatures
        from repro.kernels import segment_ops

        def raw_trace(n):
            return KA._signature(jax.make_jaxpr(
                lambda x: segment_ops.segment_min_rows(x)
            )(S((n, 128), jnp.float32)))

        p = KA.BucketProbe("test.raw", raw_trace, (8, 16))
        r = F.Report(tool="audit")
        KA.check_bucket_probe(r, p)
        assert len(r.by_rule("audit.shape-bucket")) == 1


class TestIoAliasRule:
    def test_unaliased_large_output_flags(self):
        from jax.experimental import pallas as pl

        def copy(x):
            return pl.pallas_call(
                lambda x_ref, o_ref: o_ref.__setitem__(..., x_ref[...]),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                interpret=True,
            )(x)

        t = _target(copy, S((1024, 8, 128), jnp.int32))
        r = F.Report(tool="audit")
        KA.check_io_alias(r, t, KA.trace_target(t))
        (f,) = r.by_rule("audit.io-alias")
        assert f.severity == F.Severity.WARNING

    def test_small_output_exempt(self):
        from jax.experimental import pallas as pl

        def copy(x):
            return pl.pallas_call(
                lambda x_ref, o_ref: o_ref.__setitem__(..., x_ref[...]),
                out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
                interpret=True,
            )(x)

        t = _target(copy, S((4, 8, 128), jnp.int32))
        r = F.Report(tool="audit")
        KA.check_io_alias(r, t, KA.trace_target(t))
        assert r.findings == []


class TestBaselineFramework:
    def f(self, sev=F.Severity.ERROR, rule="r.x", subject="s"):
        return F.Finding(rule, sev, subject, "msg")

    def test_partition_new_vs_baselined(self, tmp_path):
        path = tmp_path / "b.json"
        f1, f2 = self.f(subject="a"), self.f(subject="b")
        F.write_baseline([f1], path)
        new, old = F.partition([f1, f2], F.load_baseline(path))
        assert [x.subject for x in new] == ["b"]
        assert [x.subject for x in old] == ["a"]

    def test_notes_never_gate_nor_baseline(self, tmp_path):
        path = tmp_path / "b.json"
        note = self.f(sev=F.Severity.NOTE)
        assert F.write_baseline([note], path) == 0
        new, old = F.partition([note], F.load_baseline(path))
        assert new == [] and old == []

    def test_severity_escalation_regates(self, tmp_path):
        path = tmp_path / "b.json"
        F.write_baseline([self.f(sev=F.Severity.WARNING)], path)
        escalated = self.f(sev=F.Severity.ERROR)
        new, old = F.partition([escalated], F.load_baseline(path))
        assert new == [escalated] and old == []

    def test_key_is_line_free_and_stable(self):
        a = F.Finding("r", F.Severity.ERROR, "subj", "message one")
        b = F.Finding("r", F.Severity.WARNING, "subj", "different text")
        assert a.key() == b.key() == "r::subj"

    def test_missing_baseline_is_empty(self, tmp_path):
        assert F.load_baseline(tmp_path / "absent.json") == {}

    def test_version_mismatch_rejected(self, tmp_path):
        p = tmp_path / "b.json"
        p.write_text('{"version": 99, "findings": {}}')
        with pytest.raises(ValueError, match="version"):
            F.load_baseline(p)


class TestAllowlist:
    def test_prefix_downgrades_to_note(self):
        r = F.Report(tool="audit")
        KA._emit(r, "audit.dtype64-source", F.Severity.ERROR,
                 "repro.kernels.block_diff", "m")
        (f,) = r.findings
        assert f.severity == F.Severity.NOTE and "allowlisted" in f.message

    def test_non_matching_subject_keeps_severity(self):
        r = F.Report(tool="audit")
        KA._emit(r, "audit.dtype64", F.Severity.ERROR,
                 "kernels.segment_ops.min_argmin_1d", "m")
        (f,) = r.findings
        assert f.severity == F.Severity.ERROR
