"""Seed (pre-array-refactor) solver implementations, kept as test oracles.

These are the original dict-based Python-loop solvers, verbatim except that
every iteration over an unordered container is canonicalized to sorted order
(the seed iterated Python sets/dicts whose order is arbitrary among
equal-cost ties; the vectorized solvers break ties to the smallest id, so the
oracles must too).  The property tests in ``test_array_refactor.py`` assert
the array-native solvers reproduce these trees/costs exactly on random
instances.
"""

from __future__ import annotations

import heapq
import sys
from typing import Dict, List, Optional, Set, Tuple

from repro.core.version_graph import StorageSolution, VersionGraph


# ------------------------------------------------------------------ Dijkstra
def ref_dijkstra(
    g: VersionGraph, *, weight: str = "phi", source: int = 0
) -> Tuple[Dict[int, float], Dict[int, int]]:
    dist: Dict[int, float] = {source: 0.0}
    parent: Dict[int, int] = {}
    done = set()
    pq: list = [(0.0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if u in done:
            continue
        done.add(u)
        for v, c in g.out_edges(u):
            w = c.phi if weight == "phi" else c.delta
            nd = d + w
            if v not in dist or nd < dist[v] - 1e-15:
                dist[v] = nd
                parent[v] = u
                heapq.heappush(pq, (nd, v))
    return dist, parent


def ref_shortest_path_tree(g: VersionGraph, *, weight: str = "phi") -> StorageSolution:
    dist, parent = ref_dijkstra(g, weight=weight)
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"versions unreachable from root: {missing[:8]}")
    return StorageSolution(parent={i: parent[i] for i in g.versions()}, graph=g)


# ------------------------------------------------------------------- MST/MCA
def ref_minimum_storage_tree(g: VersionGraph) -> StorageSolution:
    if g.directed:
        parent = _ref_edmonds_mca(g)
    else:
        parent = _ref_prim(g)
    return StorageSolution(parent=parent, graph=g)


def _ref_prim(g: VersionGraph) -> Dict[int, int]:
    parent: Dict[int, int] = {}
    best: Dict[int, float] = {0: 0.0}
    in_tree = set()
    pq: List[Tuple[float, int, int]] = [(0.0, 0, 0)]
    while pq:
        w, u, p = heapq.heappop(pq)
        if u in in_tree:
            continue
        in_tree.add(u)
        if u != 0:
            parent[u] = p
        for v, c in g.out_edges(u):
            if v in in_tree:
                continue
            if v not in best or c.delta < best[v]:
                best[v] = c.delta
                heapq.heappush(pq, (c.delta, v, u))
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return parent


def _ref_edmonds_mca(g: VersionGraph) -> Dict[int, int]:
    edges = [(u, v, c.delta) for u, v, c in g.edges()]
    nodes = list(g.vertices())
    parent_edges = _ref_edmonds(nodes, edges, root=0)
    parent = {v: u for (u, v) in parent_edges}
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"no arborescence: unreachable {missing[:8]}")
    return parent


def _ref_edmonds(
    nodes: List[int], edges: List[Tuple[int, int, float]], root: int
) -> List[Tuple[int, int]]:
    work = [(u, v, w, None) for (u, v, w) in edges if v != root and u != v]
    chosen = _ref_edmonds_rec(set(nodes), work, root)
    out = []
    for e in chosen:
        while e[3] is not None:  # unwind to the original edge
            e = e[3]
        out.append((e[0], e[1]))
    return out


def _ref_edmonds_rec(nodes, edges, root):
    # 1. cheapest incoming edge per node
    min_in: Dict[int, tuple] = {}
    for e in edges:
        u, v, w, _ = e
        if v == root:
            continue
        cur = min_in.get(v)
        if cur is None or w < cur[2]:
            min_in[v] = e
    for v in sorted(nodes):
        if v != root and v not in min_in:
            raise ValueError(f"vertex {v} unreachable from root")

    # 2. detect a cycle among chosen edges
    cycle = _ref_find_cycle(nodes, min_in, root)
    if cycle is None:
        return list(min_in.values())

    # 3. contract the cycle into a supernode
    cyc_set = set(cycle)
    super_node = max(nodes) + 1
    new_nodes = {n for n in nodes if n not in cyc_set} | {super_node}
    cyc_cost = {v: min_in[v][2] for v in cycle}
    new_edges = []
    for e in edges:
        u, v, w, _ = e
        iu, iv = u in cyc_set, v in cyc_set
        if iu and iv:
            continue
        if iv:
            new_edges.append((u, super_node, w - cyc_cost[v], e))
        elif iu:
            new_edges.append((super_node, v, w, e))
        else:
            new_edges.append((u, v, w, e))

    edges = None  # noqa: F841
    sub = _ref_edmonds_rec(new_nodes, new_edges, root)

    # 4. expand
    result = []
    enter_head = None
    for e in sub:
        u, v, w, payload = e
        this_level = payload
        result.append(this_level)
        if v == super_node:
            assert enter_head is None, "two edges entering one supernode"
            enter_head = this_level[1]
    assert enter_head is not None, "no edge entered the contracted cycle"
    for v in cycle:
        if v != enter_head:
            result.append(min_in[v])
    return result


def _ref_find_cycle(nodes, min_in, root):
    color: Dict[int, int] = {}
    for start in sorted(nodes):
        if start == root or color.get(start) == 2:
            continue
        path = []
        v = start
        while True:
            if v == root or color.get(v) == 2:
                break
            if color.get(v) == 1:
                idx = path.index(v)
                for p in path:
                    color[p] = 2
                return path[idx:]
            color[v] = 1
            path.append(v)
            v = min_in[v][0]
        for p in path:
            color[p] = 2
    return None


# ----------------------------------------------------------------------- LMG
def ref_local_move_greedy(
    g: VersionGraph,
    budget: float,
    *,
    weights: Optional[Dict[int, float]] = None,
    base: Optional[StorageSolution] = None,
    spt: Optional[StorageSolution] = None,
) -> StorageSolution:
    base = base or ref_minimum_storage_tree(g)
    spt = spt or ref_shortest_path_tree(g)
    parent = dict(base.parent)
    tree = StorageSolution(parent=parent, graph=g)

    w_total = tree.storage_cost()
    if w_total > budget + 1e-9:
        raise ValueError(
            f"budget {budget} below minimum storage {w_total}: infeasible"
        )

    children: Dict[int, Set[int]] = {v: set() for v in g.vertices()}
    for i, p in parent.items():
        children[p].add(i)
    d: Dict[int, float] = {0: 0.0}

    def _init_d(u: int) -> None:
        for v in children[u]:
            d[v] = d[u] + tree.edge_cost(v).phi
            _init_d(v)

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, g.n + 100))
    try:
        _init_d(0)
        mass: Dict[int, float] = {}

        def _init_mass(u: int) -> float:
            m = (1.0 if weights is None else weights.get(u, 0.0)) if u != 0 else 0.0
            for v in children[u]:
                m += _init_mass(v)
            mass[u] = m
            return m

        _init_mass(0)
    finally:
        sys.setrecursionlimit(old_limit)

    def in_subtree(node: int, root_v: int) -> bool:
        v = node
        while v != 0:
            if v == root_v:
                return True
            v = parent[v]
        return False

    candidates: Set[Tuple[int, int]] = {
        (spt.parent[v], v) for v in g.versions() if spt.parent[v] != parent[v]
    }

    while candidates:
        best_rho, best_edge = 0.0, None
        for (u, v) in sorted(candidates):
            if parent[v] == u:
                continue
            c_new = g.materialization_cost(v) if u == 0 else g.cost(u, v)
            assert c_new is not None
            c_old = tree.edge_cost(v)
            dw = c_new.delta - c_old.delta
            if w_total + dw > budget + 1e-9:
                continue
            if u != 0 and in_subtree(u, v):
                continue
            dd = (d[u] + c_new.phi) - d[v]
            reduction = -dd * mass[v]
            if reduction <= 0:
                continue
            rho = reduction / dw if dw > 0 else float("inf")
            if rho > best_rho:
                best_rho, best_edge = rho, (u, v, dw, dd)
        if best_edge is None:
            break
        u, v, dw, dd = best_edge
        old_u = parent[v]
        children[old_u].discard(v)
        children[u].add(v)
        parent[v] = u
        w_total += dw
        m = mass[v]
        a = old_u
        while a != 0:
            mass[a] -= m
            a = parent[a]
        a = u
        while a != 0:
            mass[a] += m
            a = parent[a]
        stack = [v]
        while stack:
            x = stack.pop()
            d[x] += dd
            stack.extend(children[x])
        candidates.discard((u, v))

    return tree


# ------------------------------------------------------------------------ MP
def _ref_is_ancestor(p: Dict[int, int], anc: int, node: int) -> bool:
    x = node
    while x != 0:
        if x == anc:
            return True
        x = p.get(x, 0)
    return False


def ref_modified_prim(g: VersionGraph, theta: float) -> StorageSolution:
    from repro.core.solvers.mp import InfeasibleError

    INF = float("inf")
    l: Dict[int, float] = {v: INF for v in g.vertices()}
    d: Dict[int, float] = {v: INF for v in g.vertices()}
    p: Dict[int, int] = {}
    l[0] = d[0] = 0.0
    in_tree = set()
    pq = [(0.0, 0)]
    while pq:
        li, vi = heapq.heappop(pq)
        if vi in in_tree or li > l[vi] + 1e-15:
            continue
        in_tree.add(vi)
        for vj, c in g.out_edges(vi):
            if vj in in_tree:
                if c.phi + d[vi] <= d[vj] + 1e-15 and c.delta <= l[vj] - 1e-15:
                    if _ref_is_ancestor(p, vj, vi):
                        continue
                    p[vj] = vi
                    d[vj] = c.phi + d[vi]
                    l[vj] = c.delta
            else:
                if c.phi + d[vi] <= theta + 1e-9 and c.delta < l[vj] - 1e-15:
                    d[vj] = c.phi + d[vi]
                    l[vj] = c.delta
                    p[vj] = vi
                    heapq.heappush(pq, (l[vj], vj))
    missing = [i for i in g.versions() if i not in in_tree]
    if missing:
        dist, sp_parent = ref_dijkstra(g, weight="phi")
        bad = [i for i in missing if dist.get(i, float("inf")) > theta + 1e-9]
        if bad:
            raise InfeasibleError(
                f"theta={theta} infeasible: versions {bad[:5]} have SPT "
                f"recreation above the bound"
            )
        for v in missing:
            path = [v]
            while path[-1] != 0:
                path.append(sp_parent[path[-1]])
            path.reverse()
            for u, x in zip(path, path[1:]):
                c = g.materialization_cost(x) if u == 0 else g.cost(u, x)
                cand = d[u] + c.phi
                if x not in in_tree or cand < d[x] - 1e-15:
                    p[x] = u
                    d[x] = cand
                    l[x] = c.delta
                    in_tree.add(x)
    return StorageSolution(parent={i: p[i] for i in g.versions()}, graph=g)
