"""Repository facade tests: ref semantics, persistence, diff, spec repack.

* branch/tag resolution and shadowing rules, commit advancing the right
  branch, merge commits with multi-ref parents;
* ref persistence: branches/tags/head survive a store close/reopen (they
  ride in the same atomic msgpack metadata as version metas);
* ``checkout(ref)`` byte-identical to ``checkout(vid)`` on the underlying
  store (same planner, same cache);
* leaf-level ``diff``;
* ``repack(spec)`` through the facade, including the
  ``use_access_frequencies`` workload routing and its refusal mode.
"""

import numpy as np
import pytest

from repro.core import OptimizeSpec
from repro.store import Repository, VersionStore


def payload(seed: int, shape=(48, 32)):
    rng = np.random.RandomState(seed)
    return {
        "w": rng.randn(*shape).astype(np.float32),
        "b": rng.randn(shape[1]).astype(np.float32),
    }


class TestRefs:
    def test_first_commit_creates_main(self, tmp_path):
        repo = Repository(tmp_path)
        assert repo.head == "main" and repo.branches() == {}
        v1 = repo.commit(payload(0), message="base")
        assert repo.branches() == {"main": v1}
        assert repo.resolve() == v1
        assert repo.resolve("main") == v1
        assert repo.resolve(v1) == v1

    def test_commit_advances_branch(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        v2 = repo.commit(payload(1))
        assert repo.branches() == {"main": v2}
        assert repo.store.versions[v2].parents == [v1]

    def test_branch_tag_resolution(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        v2 = repo.commit(payload(1))
        repo.branch("exp", at=v1)
        repo.tag("rel", at="main")
        assert repo.resolve("exp") == v1
        assert repo.resolve("rel") == v2
        v3 = repo.commit(payload(2), branch="exp")
        assert repo.resolve("exp") == v3
        assert repo.resolve("rel") == v2  # tags never move
        assert repo.store.versions[v3].parents == [v1]

    def test_duplicate_and_bad_refs(self, tmp_path):
        repo = Repository(tmp_path)
        repo.commit(payload(0))
        repo.branch("exp")
        repo.tag("rel")
        with pytest.raises(ValueError, match="already exists"):
            repo.branch("exp")
        with pytest.raises(ValueError, match="immutable"):
            repo.tag("rel")
        with pytest.raises(ValueError, match="unknown ref"):
            repo.resolve("nope")
        with pytest.raises(ValueError, match="unknown version id"):
            repo.resolve(999)
        with pytest.raises(ValueError, match="ref names"):
            repo.branch("123")

    def test_switch_changes_head(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        repo.branch("exp")
        assert repo.switch("exp") == v1
        v2 = repo.commit(payload(1))
        assert repo.branches() == {"main": v1, "exp": v2}
        with pytest.raises(ValueError, match="unknown branch"):
            repo.switch("ghost")

    def test_merge_commit_multi_ref_parents(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        repo.branch("a", at=v1)
        repo.branch("b", at=v1)
        va = repo.commit(payload(1), branch="a")
        vb = repo.commit(payload(2), branch="b")
        vm = repo.commit(payload(3), parent=["a", "b"], branch="main")
        assert repo.store.versions[vm].parents == [va, vb]
        assert repo.resolve("main") == vm

    def test_commit_to_unknown_branch_refuses_orphan(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        with pytest.raises(ValueError, match="does not exist.*orphan"):
            repo.commit(payload(1), branch="feture")  # typo'd branch name
        assert repo.branches() == {"main": v1}
        # explicit parent creates the branch there (git checkout -b style)
        v2 = repo.commit(payload(1), branch="feature", parent="main")
        assert repo.branches() == {"main": v1, "feature": v2}
        assert repo.store.versions[v2].parents == [v1]

    def test_commit_writes_metadata_once(self, tmp_path):
        repo = Repository(tmp_path)
        repo.commit(payload(0))
        store = repo.store
        writes = 0
        orig = type(store)._save_meta

        def counting(self_):
            nonlocal writes
            writes += 1
            return orig(self_)

        type(store)._save_meta = counting
        try:
            repo.commit(payload(1))
        finally:
            type(store)._save_meta = orig
        assert writes == 1  # ref advance rides the commit's own write

    def test_log_walks_ancestry(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0))
        v2 = repo.commit(payload(1))
        repo.branch("exp", at=v1)
        v3 = repo.commit(payload(2), branch="exp")
        assert [m.vid for m in repo.log("main")] == [v2, v1]
        assert [m.vid for m in repo.log("exp")] == [v3, v1]


class TestPersistence:
    def test_refs_survive_reopen(self, tmp_path):
        repo = Repository(tmp_path)
        v1 = repo.commit(payload(0), message="base")
        v2 = repo.commit(payload(1), message="second")
        repo.branch("exp", at=v1)
        v3 = repo.commit(payload(2), branch="exp")
        repo.tag("rel", at=v2)
        repo.switch("exp")
        repo.close()

        repo2 = Repository(tmp_path)
        assert repo2.branches() == {"main": v2, "exp": v3}
        assert repo2.tags() == {"rel": v2}
        assert repo2.head == "exp"
        # and the raw store handle sees the same ref table
        store = VersionStore(tmp_path)
        assert store.refs["branches"] == {"main": v2, "exp": v3}
        assert store.refs["tags"] == {"rel": v2}

    def test_pre_refs_metadata_loads(self, tmp_path):
        # stores written before the Repository facade have no refs block
        store = VersionStore(tmp_path)
        store.commit(payload(0), message="old-world")
        blob = (tmp_path / "meta.msgpack").read_bytes()
        import msgpack

        obj = msgpack.unpackb(blob, raw=False)
        obj.pop("refs", None)
        (tmp_path / "meta.msgpack").write_bytes(
            msgpack.packb(obj, use_bin_type=True)
        )
        repo = Repository(tmp_path)
        assert repo.branches() == {} and repo.head == "main"
        # refusing an implicit parentless commit: the store has history but
        # no branch to anchor it, so the lineage must be given explicitly
        with pytest.raises(ValueError, match="orphan"):
            repo.commit(payload(1), message="new-world")
        v2 = repo.commit(payload(1), message="new-world", parent=1)
        assert repo.branches() == {"main": v2}
        assert repo.store.versions[v2].parents == [1]


class TestCheckout:
    def test_checkout_ref_identical_to_vid(self, tmp_path):
        repo = Repository(tmp_path)
        vids = [repo.commit(payload(i)) for i in range(4)]
        repo.tag("rel", at=vids[2])
        by_ref = repo.checkout("rel")
        by_vid = repo.store.checkout(vids[2])
        assert set(by_ref) == set(by_vid)
        for k in by_ref:
            np.testing.assert_array_equal(by_ref[k], by_vid[k])

    def test_checkout_many_mixed_refs(self, tmp_path):
        repo = Repository(tmp_path)
        vids = [repo.commit(payload(i)) for i in range(3)]
        repo.tag("first", at=vids[0])
        trees = repo.checkout_many(["first", vids[1], "main"])
        singles = [repo.store.checkout(v) for v in vids]
        for t, s in zip(trees, singles):
            for k in s:
                np.testing.assert_array_equal(t[k], s[k])

    def test_diff(self, tmp_path):
        repo = Repository(tmp_path)
        p = payload(0)
        repo.commit(p)
        q = {k: v.copy() for k, v in p.items()}
        q["w"][:4] += 1.0
        del q["b"]
        q["extra"] = np.ones(7, np.float32)
        repo.commit(q)
        d = repo.diff(1, "main")
        assert d.added == ("extra",) and d.removed == ("b",)
        assert d.changed == ("w",) and d.unchanged == 0
        assert d.bytes_changed == q["w"].nbytes
        assert "v1..v2" in d.summary()
        # identical refs diff empty
        d0 = repo.diff("main", "main")
        assert d0.added == () and d0.changed == () and d0.unchanged == 2


class TestRepackThroughFacade:
    def test_spec_repack_preserves_contents(self, tmp_path):
        repo = Repository(tmp_path)
        vids = [repo.commit(payload(i)) for i in range(5)]
        repo.tag("rel", at=vids[-1])
        originals = {v: repo.store.checkout(v) for v in vids}
        stats = repo.repack(OptimizeSpec.problem(2))
        assert stats["optimize"]["problem"] == 2
        assert all(m.stored_base is None for m in repo.store.log())
        for v in vids:
            rec = repo.checkout(v)
            for k in originals[v]:
                np.testing.assert_array_equal(rec[k], originals[v][k])
        # refs unaffected by repack
        assert repo.resolve("rel") == vids[-1]

    def test_use_access_frequencies_routing(self, tmp_path):
        repo = Repository(tmp_path)
        for i in range(4):
            repo.commit(payload(i))
        for _ in range(5):
            repo.checkout("main")
        beta = repo.store.storage_bytes() * 2.0
        stats = repo.repack(
            OptimizeSpec.problem(3, beta=beta), use_access_frequencies=True
        )
        assert stats["optimize"]["solver"] == "lmg"
        # non-workload specs refuse instead of dropping the counts
        with pytest.raises(ValueError, match="workload-aware"):
            repo.repack(OptimizeSpec.problem(2), use_access_frequencies=True)
        with pytest.raises(ValueError, match="workload-aware"):
            repo.repack("mca", use_access_frequencies=True)

    def test_problem5_spec_honors_workload(self, tmp_path):
        # Problem 5 (min C s.t. Σ w_i R_i ≤ θ) was unreachable through the
        # legacy string registry; the spec surface reaches it and routes the
        # access-frequency workload into the bound
        repo = Repository(tmp_path)
        for i in range(5):
            repo.commit(payload(i))
        g, _ = repo.store.build_cost_graph()
        from repro.core import optimize

        spt_sum = optimize(g, OptimizeSpec.problem(2)).objective_values[
            "sum_recreation"
        ]
        stats = repo.repack(
            OptimizeSpec.problem(5, theta=spt_sum * 2.0),
            use_access_frequencies=True,
        )
        assert stats["optimize"]["problem"] == 5
        assert stats["optimize"]["solver"] == "lmg+binsearch"

    def test_stray_kwargs_with_spec_rejected(self, tmp_path):
        repo = Repository(tmp_path)
        repo.commit(payload(0))
        with pytest.raises(ValueError, match="stray"):
            repo.repack(OptimizeSpec.problem(2), theta=1.0)

    def test_constructor_arg_validation(self, tmp_path):
        with pytest.raises(ValueError, match="exactly one"):
            Repository()
        with pytest.raises(ValueError, match="exactly one"):
            Repository(tmp_path, store=VersionStore(tmp_path))
        store = VersionStore(tmp_path / "s")
        repo = Repository(store=store)
        assert repo.store is store
