"""Loop-aware HLO roofline accounting — unit tests on synthetic HLO text."""

from repro.launch.hlo_analysis import analyze_hlo

HLO = """
HloModule test

%cond.1 (p.0: (s32[], f32[8,8])) -> pred[] {
  %p.0 = (s32[], f32[8,8]) parameter(0)
  %gte.0 = s32[] get-tuple-element(%p.0), index=0
  %c.0 = s32[] constant(10)
  ROOT %lt = pred[] compare(%gte.0, %c.0), direction=LT
}

%body.1 (p.1: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p.1 = (s32[], f32[8,8]) parameter(0)
  %gte.1 = s32[] get-tuple-element(%p.1), index=0
  %gte.2 = f32[8,8] get-tuple-element(%p.1), index=1
  %dot.1 = f32[8,8]{1,0} dot(%gte.2, %gte.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c.1 = s32[] constant(1)
  %add.1 = s32[] add(%gte.1, %c.1)
  ROOT %t.1 = (s32[], f32[8,8]) tuple(%add.1, %dot.1)
}

ENTRY %main (a: f32[8,8], b: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %b = f32[8,8]{1,0} parameter(1)
  %dot.0 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c.2 = s32[] constant(0)
  %t.0 = (s32[], f32[8,8]) tuple(%c.2, %dot.0)
  %w.0 = (s32[], f32[8,8]) while(%t.0), condition=%cond.1, body=%body.1
  %gte.3 = f32[8,8] get-tuple-element(%w.0), index=1
  %ar.0 = f32[8,8]{1,0} all-reduce(%gte.3), replica_groups=[16,16]<=[256], to_apply=%body.1
  ROOT %out = f32[8,8]{1,0} copy(%ar.0)
}
"""


class TestAnalyzer:
    def test_while_body_weighted_by_trip_count(self):
        c = analyze_hlo(HLO)
        # one 8x8x8 dot outside (1024 flops) + 10 trips inside
        dot_flops = 2 * 8 * 8 * 8
        assert c.flops >= 11 * dot_flops
        assert c.flops < 11 * dot_flops + 2000  # small add-op slack

    def test_collective_counted_once_with_bytes(self):
        c = analyze_hlo(HLO)
        assert c.coll.get("all-reduce") == 8 * 8 * 4

    def test_empty_module(self):
        assert analyze_hlo("").flops == 0
