"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions; prefill→decode consistency checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.models import get_model

B, S = 2, 64


def make_batch(cfg, rng):
    if cfg.is_encdec:
        return {
            "frames": jnp.asarray(rng.randn(B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32),
        }
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (B, S)), jnp.int32)}


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    cfg = ARCHS[request.param].reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestForward:
    def test_train_logits_shape_and_finite(self, arch):
        cfg, model, params = arch
        batch = make_batch(cfg, np.random.RandomState(0))
        logits, aux = jax.jit(model.train_logits)(params, batch)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    def test_one_train_step_reduces_loss(self, arch):
        cfg, model, params = arch
        batch = make_batch(cfg, np.random.RandomState(1))
        tokens = batch["tokens"]

        def loss_fn(p):
            logits, _ = model.train_logits(p, batch)
            logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
            tgt = tokens[:, 1:]
            return -jnp.take_along_axis(logp, tgt[..., None], -1).mean()

        loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert bool(jnp.isfinite(loss0))
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
        params2 = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - 0.5 * g.astype(jnp.float32)).astype(p.dtype),
            params, grads)
        loss1 = jax.jit(loss_fn)(params2)
        assert bool(jnp.isfinite(loss1))

    def test_prefill_then_decode_matches_forward(self, arch):
        """Greedy next-token from (prefill + decode) must equal teacher
        forcing at the same positions: the cache path is consistent."""
        cfg, model, params = arch
        rng = np.random.RandomState(2)
        batch = make_batch(cfg, rng)
        tokens = batch["tokens"]
        max_len = S + 8
        cache = model.init_cache(B, max_len) if not cfg.is_encdec else model.init_cache(B, max_len, enc_len=S)

        # full forward logits at the last prefill position
        logits_full, _ = jax.jit(model.train_logits)(params, batch)
        logits_pre, cache = jax.jit(model.prefill)(params, batch, cache)
        np.testing.assert_allclose(
            np.asarray(logits_pre[:, -1], np.float32),
            np.asarray(logits_full[:, -1], np.float32),
            rtol=2e-2, atol=2e-2,
        )

        # one decode step: feed token S, compare against forward over S+1
        nxt = jnp.asarray(rng.randint(0, cfg.vocab, (B, 1)), jnp.int32)
        logits_dec, cache = jax.jit(model.decode_step)(params, nxt, cache, S)
        ext = jnp.concatenate([tokens, nxt], axis=1)
        batch_ext = dict(batch, tokens=ext)
        logits_ext, _ = jax.jit(model.train_logits)(params, batch_ext)
        np.testing.assert_allclose(
            np.asarray(logits_dec[:, -1], np.float32),
            np.asarray(logits_ext[:, -1], np.float32),
            rtol=5e-2, atol=5e-2,
        )


class TestShapeTable:
    def test_every_arch_declares_supported_shapes(self):
        for name, cfg in ARCHS.items():
            assert "train_4k" in cfg.supported_shapes
            if "long_500k" in cfg.supported_shapes:
                assert cfg.family in ("hybrid", "ssm"), name

    def test_long_context_only_subquadratic(self):
        subq = {n for n, c in ARCHS.items() if "long_500k" in c.supported_shapes}
        assert subq == {"recurrentgemma-9b", "rwkv6-3b"}
