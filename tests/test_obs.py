"""Observability tests: tracer concurrency, exporters, tradeoff telemetry.

* **tracer** — context-manager nesting and parent/trace ids; propagation
  across ``await`` within a task; spans started on the event loop and
  closed from a pool thread; ``attach`` bridging parenthood onto executor
  threads; ring-buffer wraparound with the ``dropped`` counter; the
  disabled tracer recording nothing and returning the falsy null span.
* **exporters** — Chrome trace structural validity (and the validator
  catching broken traces), multi-pid merge, Prometheus text over a service
  snapshot, JSONL round-trip.
* **tradeoff** — monitor samples on commit, the baseline flip on repack,
  drift ratios and the human drift line.
* **percentile** — floor-half-up pins (the banker's-rounding regression).
* **integration** — service traffic under ``obs.tracing()``: every layer's
  spans present, span totals reconciling with the ``ServiceMetrics``
  queue-wait/decode tracks within 5%, monitor attach/detach across the
  service lifecycle.

No pytest-asyncio in the image: async tests drive their own loop via
``asyncio.run``.
"""

import asyncio
import json
import math
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import (
    NULL_SPAN,
    Span,
    Tracer,
    TradeoffMonitor,
    chrome_trace,
    dump_spans_jsonl,
    load_spans_jsonl,
    prometheus_text,
    validate_chrome_trace,
)
from repro.service import DatasetService, percentile
from repro.store import Repository


def payload(seed: int, shape=(32, 24)):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(*shape).astype(np.float32)}


def build_repo(tmp_path, versions=5):
    repo = Repository(tmp_path)
    for i in range(versions):
        repo.commit(payload(i), message=f"v{i}")
    return repo


# --------------------------------------------------------------- tracer core
class TestTracer:
    def test_nesting_and_ids(self):
        tr = Tracer(enabled=True)
        with tr.span("outer") as o:
            with tr.span("inner") as i:
                assert i.parent_id == o.span_id
                assert i.trace_id == o.trace_id == o.span_id
        spans = tr.spans()
        assert [s.name for s in spans] == ["inner", "outer"]  # close order
        assert spans[1].t0 <= spans[0].t0
        assert all(s.t1 >= s.t0 for s in spans)

    def test_attrs_and_duration(self):
        tr = Tracer(enabled=True)
        with tr.span("op", a=1) as sp:
            sp.set(b=2)
        (got,) = tr.spans()
        assert got.attrs == {"a": 1, "b": 2}
        assert got.duration >= 0.0

    def test_context_propagates_across_await(self):
        tr = Tracer(enabled=True)

        async def inner():
            await asyncio.sleep(0)
            with tr.span("child"):
                await asyncio.sleep(0)

        async def main():
            with tr.span("root"):
                await asyncio.sleep(0.001)  # force a real suspension
                await inner()

        asyncio.run(main())
        by_name = {s.name: s for s in tr.spans()}
        assert by_name["child"].parent_id == by_name["root"].span_id

    def test_concurrent_tasks_get_separate_tracks(self):
        tr = Tracer(enabled=True)

        async def req(i):
            with tr.span("req"):
                await asyncio.sleep(0.001)

        async def main():
            await asyncio.gather(*(req(i) for i in range(3)))

        asyncio.run(main())
        tracks = {s.track for s in tr.spans()}
        assert len(tracks) == 3  # one per asyncio task
        assert all(t.startswith("task:") for t in tracks)

    def test_open_on_loop_close_on_thread(self):
        """A span started in one context may be ended from another thread;
        only the thread-safe idempotent end() records it."""
        tr = Tracer(enabled=True)
        sp = tr.start("crossing")
        done = threading.Event()

        def worker():
            sp.end()
            sp.end()  # idempotent: second end must not double-record
            done.set()

        threading.Thread(target=worker).start()
        assert done.wait(5.0)
        assert len(tr) == 1
        assert tr.spans()[0].name == "crossing"

    def test_attach_bridges_pool_threads(self):
        tr = Tracer(enabled=True)

        async def main():
            loop = asyncio.get_running_loop()
            parent = tr.start("dispatch")

            def work():
                # pool threads do NOT inherit the submitting context...
                with tr.attach(parent):
                    with tr.span("decode"):
                        pass

            await loop.run_in_executor(None, work)
            parent.end()

        asyncio.run(main())
        by_name = {s.name: s for s in tr.spans()}
        assert by_name["decode"].parent_id == by_name["dispatch"].span_id
        assert by_name["decode"].track.startswith("thread:")

    def test_ring_wraparound_counts_dropped(self):
        tr = Tracer(enabled=True, capacity=8)
        for i in range(20):
            with tr.span(f"s{i}"):
                pass
        assert len(tr) == 8
        assert tr.dropped == 12
        assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]

    def test_disabled_records_nothing(self):
        tr = Tracer(enabled=False)
        sp = tr.span("x")
        assert sp is NULL_SPAN and not sp
        with tr.span("y") as y:
            y.set(k=1)  # all no-ops
        tr.add_event("z", 0.0, 1.0)
        assert len(tr) == 0
        # and a null parent never poisons an enabled tracer's lineage
        tr.enable()
        real = tr.start("real", parent=NULL_SPAN)
        real.end()
        assert tr.spans()[0].parent_id is None

    def test_wrap_decorator_sync_and_async(self):
        tr = Tracer(enabled=True)

        @tr.wrap("sync_op")
        def f(x):
            return x + 1

        @tr.wrap()
        async def g(x):
            return x * 2

        assert f(1) == 2
        assert asyncio.run(g(3)) == 6
        names = {s.name for s in tr.spans()}
        assert "sync_op" in names
        assert any("g" in n for n in names - {"sync_op"})

    def test_retroactive_add_event(self):
        tr = Tracer(enabled=True)
        tr.add_event("queue_wait", 10.0, 10.5, vid=7)
        (sp,) = tr.spans()
        assert (sp.t0, sp.t1) == (10.0, 10.5)
        assert sp.attrs["vid"] == 7

    def test_tracing_contextmanager_restores_global(self):
        before = obs.get_tracer()
        with obs.tracing() as tr:
            assert obs.get_tracer() is tr and tr.enabled
            with obs.span("inside"):
                pass
        assert obs.get_tracer() is before
        assert {s.name for s in tr.spans()} == {"inside"}

    def test_summary_rollup(self):
        tr = Tracer(enabled=True)
        for _ in range(3):
            with tr.span("op"):
                pass
        s = tr.summary()["op"]
        assert s["count"] == 3
        assert s["total_s"] >= s["max_s"] >= s["mean_s"] >= 0


# ---------------------------------------------------------------- exporters
class TestExporters:
    def _traced(self):
        tr = Tracer(enabled=True)
        with tr.span("svc.request", vid=1):
            with tr.span("mat.decode"):
                pass
        return tr

    def test_chrome_trace_valid_and_loadable(self, tmp_path):
        tr = self._traced()
        path = tmp_path / "trace.json"
        chrome_trace(tr, path)
        assert validate_chrome_trace(path) == []
        doc = json.loads(path.read_text())
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert {e["name"] for e in xs} == {"svc.request", "mat.decode"}
        # child nests inside parent on the timeline
        by = {e["name"]: e for e in xs}
        parent, child = by["svc.request"], by["mat.decode"]
        assert parent["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1
        assert child["args"]["parent_id"] == parent["args"]["span_id"]
        assert "_origin_s" not in doc  # private keys stripped on write

    def test_chrome_trace_merges_pids(self, tmp_path):
        a, b = self._traced(), self._traced()
        merged = chrome_trace(a, pid=1, process_name="chain")
        path = tmp_path / "merged.json"
        chrome_trace(b, path, pid=2, process_name="global", base=merged)
        assert validate_chrome_trace(path) == []
        doc = json.loads(path.read_text())
        assert {e["pid"] for e in doc["traceEvents"]} == {1, 2}
        names = {
            (e["pid"], e["args"]["name"])
            for e in doc["traceEvents"]
            if e.get("name") == "process_name"
        }
        assert names == {(1, "chain"), (2, "global")}

    def test_validator_rejects_broken_traces(self):
        assert validate_chrome_trace({"nope": 1})
        assert validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "pid": 1, "tid": 1,
                              "ts": -5, "dur": 1}]}
        )
        # X events on a tid with no thread_name metadata
        probs = validate_chrome_trace(
            {"traceEvents": [{"ph": "X", "name": "a", "pid": 1, "tid": 3,
                              "ts": 0, "dur": 1}]}
        )
        assert any("thread_name" in p for p in probs)

    def test_jsonl_roundtrip_and_convert(self, tmp_path):
        tr = self._traced()
        jl = tmp_path / "spans.jsonl"
        assert dump_spans_jsonl(tr, jl) == 2
        rows = load_spans_jsonl(jl)
        direct = chrome_trace(tr)["traceEvents"]
        converted = chrome_trace(rows)["traceEvents"]
        assert direct == converted

    def test_prometheus_text(self):
        snapshot = {
            "counters": {"requests.checkout": 4},
            "tracks": {"latency.checkout":
                       {"count": 4, "mean_ms": 2.0, "p50_ms": 1.5,
                        "p99_ms": 3.0, "max_ms": 3.1}},
            "gauges": {"tradeoff.storage_ratio": 1.25},
            "store": {"hits": 3},
            "tradeoff": {"latest": {
                "storage_bytes_full": 100, "storage_bytes_delta": 40,
                "full_objects": 1, "delta_objects": 4,
                "recreation_p50_s": 0.1, "recreation_p99_s": 0.2,
                "recreation_max_s": 0.3, "recreation_sum_s": 0.7,
                "access_weighted_recreation_s": 0.5,
            }, "drift": {"storage_ratio": 1.25,
                         "access_weighted_recreation_ratio": 2.3}},
        }
        text = prometheus_text(snapshot)
        assert "repro_requests_checkout_total 4" in text
        assert 'repro_latency_checkout_seconds{quantile="0.5"} 0.0015' in text
        assert "repro_latency_checkout_seconds_count 4" in text
        assert "repro_latency_checkout_seconds_sum 0.008" in text
        assert 'repro_tradeoff_storage_bytes{kind="delta"} 40' in text
        assert (
            "repro_tradeoff_drift_access_weighted_recreation_ratio 2.3"
            in text
        )
        # every line is a comment or "name{labels} value"
        for line in text.strip().splitlines():
            assert line.startswith("# ") or len(line.rsplit(" ", 1)) == 2


# -------------------------------------------------------------- percentile
class TestPercentile:
    def test_half_ranks_round_up(self):
        # round() banker's rounding would give xs[0] here (0.5 -> 0)
        assert percentile([1.0, 2.0], 50) == 2.0
        # rank 2.5 -> index 3 (round() would give 2)
        assert percentile([1, 2, 3, 4, 5, 6], 50) == 4

    def test_edges(self):
        xs = list(range(1, 102))
        assert percentile(xs, 0) == 1
        assert percentile(xs, 100) == 101
        assert percentile(xs, 99) == 100
        assert percentile([7.0], 50) == 7.0
        with pytest.raises(ValueError):
            percentile([], 50)

    def test_matches_numpy_nearest_on_odd_windows(self):
        xs = [float(x) for x in np.random.RandomState(0).randn(101)]
        for q in (1, 25, 50, 75, 99):
            idx = int(math.floor(q / 100 * 100 + 0.5))
            assert percentile(xs, q) == sorted(xs)[idx]


# ---------------------------------------------------------------- tradeoff
class TestTradeoffMonitor:
    def test_samples_on_commit_and_repack_baseline(self, tmp_path):
        from repro.core import OptimizeSpec

        repo = build_repo(tmp_path, versions=3)
        mon = TradeoffMonitor(repo.store)
        repo.store.tradeoff_monitor = mon
        s0 = mon.sample("start")
        assert s0.versions == 3
        assert s0.storage_bytes_total == repo.store.storage_bytes()
        assert mon.baseline is not None and mon.baseline.event == "start"

        repo.commit(payload(10), message="more")
        assert mon.latest.event == "commit"
        assert mon.latest.versions == 4

        repo.repack(OptimizeSpec.problem(1))
        assert mon.latest.event == "repack"
        assert mon.baseline.event == "repack"  # baseline flipped

        d = mon.drift()
        assert d["baseline_event"] == "repack"
        assert d["storage_ratio"] == pytest.approx(1.0)
        assert d["versions_added"] == 0
        line = mon.describe_drift()
        assert "post-repack baseline" in line and "1.00x" in line

    def test_recreation_side_matches_store_model(self, tmp_path):
        repo = build_repo(tmp_path, versions=4)
        mon = TradeoffMonitor(repo.store)
        s = mon.sample()
        store = repo.store
        costs = [store.recreation_cost(v) for v in store.versions]
        assert s.recreation_max_s == pytest.approx(max(costs))
        assert s.recreation_sum_s == pytest.approx(sum(costs))
        w = store.access_weights()
        awr = sum(
            w[v] * store.recreation_cost(v) for v in store.versions
        )
        assert s.access_weighted_recreation_s == pytest.approx(awr)
        full = [m for m in store.versions.values() if m.stored_base is None]
        assert s.full_objects == len(full)
        assert s.storage_bytes_full == sum(m.stored_bytes for m in full)

    def test_bounded_history(self, tmp_path):
        repo = build_repo(tmp_path, versions=2)
        mon = TradeoffMonitor(repo.store, capacity=4)
        for _ in range(10):
            mon.sample()
        assert len(mon.history) == 4
        assert mon.snapshot()["samples"] == 4

    def test_empty_store(self, tmp_path):
        from repro.store.version_store import VersionStore

        mon = TradeoffMonitor(VersionStore(tmp_path))
        s = mon.sample()
        assert s.versions == 0 and s.storage_bytes_total == 0
        assert mon.drift()["storage_ratio"] is None
        assert "n/a" in mon.describe_drift()


# -------------------------------------------------------------- integration
class TestServiceIntegration:
    def test_spans_cover_layers_and_reconcile(self, tmp_path):
        repo = build_repo(tmp_path, versions=4)
        vids = sorted(repo.store.versions)

        async def go():
            async with DatasetService(
                repo, readers=2, batch_window_s=0.001
            ) as svc:
                await svc.checkout_many(vids)
                await svc.checkout_many([vids[0]] * 3)  # coalesced
                await svc.commit(payload(50), message="append")
                return svc.stats()

        with obs.tracing() as tr:
            stats = asyncio.run(go())

        names = {s.name for s in tr.spans()}
        assert {"svc.checkout", "svc.batch", "svc.queue_wait", "svc.decode",
                "svc.commit", "store.commit", "mat.checkout_many",
                "mat.plan"} <= names

        # span totals share the clock with the metrics tracks: within 5%
        summary = tr.summary()
        for span_name, track in (("svc.queue_wait", "queue_wait"),
                                 ("svc.decode", "decode")):
            tk = stats["tracks"][track]
            track_total_s = tk["mean_ms"] * tk["count"] / 1e3
            span_total_s = summary[span_name]["total_s"]
            assert summary[span_name]["count"] == tk["count"]
            assert span_total_s == pytest.approx(track_total_s, rel=0.05)

        # parenting: every queue_wait hangs off a request root span
        by_id = {s.span_id: s for s in tr.spans()}
        for s in tr.spans():
            if s.name == "svc.queue_wait":
                assert by_id[s.parent_id].name == "svc.checkout"
            if s.name == "mat.checkout_many" and s.parent_id in by_id:
                assert by_id[s.parent_id].name in ("svc.batch", "store.commit")

    def test_disabled_tracer_traffic_records_nothing(self, tmp_path):
        repo = build_repo(tmp_path, versions=3)

        async def go():
            async with DatasetService(repo, readers=2) as svc:
                await svc.checkout_many(sorted(repo.store.versions))
                await svc.commit(payload(9), message="x")

        assert not obs.get_tracer().enabled  # the default global
        before = len(obs.get_tracer())
        asyncio.run(go())
        assert len(obs.get_tracer()) == before

    def test_monitor_lifecycle_and_stats(self, tmp_path):
        repo = build_repo(tmp_path, versions=3)

        async def go():
            svc = DatasetService(repo, readers=1)
            await svc.start()
            assert repo.store.tradeoff_monitor is not None
            await svc.commit(payload(20), message="append")
            stats = svc.stats()
            await svc.stop()
            assert repo.store.tradeoff_monitor is None  # detached
            return stats, svc.stats()

        stats, after = asyncio.run(go())
        trade = stats["tradeoff"]
        assert trade["latest"]["event"] == "commit"
        assert trade["latest"]["versions"] == 4
        assert trade["drift"]["baseline_event"] == "start"
        assert trade["drift"]["versions_added"] == 1
        # history stays readable through stats() after stop
        assert after["tradeoff"]["samples"] == trade["samples"]

    def test_tradeoff_opt_out(self, tmp_path):
        repo = build_repo(tmp_path, versions=2)

        async def go():
            async with DatasetService(repo, tradeoff=False) as svc:
                assert repo.store.tradeoff_monitor is None
                return svc.stats()

        stats = asyncio.run(go())
        assert "tradeoff" not in stats

    def test_sweeper_publishes_drift_gauges(self, tmp_path):
        repo = build_repo(tmp_path, versions=3)

        async def go():
            async with DatasetService(repo, readers=1) as svc:
                await svc.commit(payload(30), message="drifty")
                await svc.fsck()
                return svc.stats()

        stats = asyncio.run(go())
        g = stats["gauges"]
        assert g["tradeoff.storage_ratio"] >= 1.0
        assert g["tradeoff.versions_added"] == 1
        assert "tradeoff.access_weighted_recreation_ratio" in g
        assert stats["tradeoff"]["latest"]["event"] == "sweep"


class TestMetricsGauges:
    def test_set_and_snapshot(self):
        from repro.service import ServiceMetrics

        m = ServiceMetrics()
        m.set_gauge("x", 1.5)
        m.set_gauge("x", 2.5)  # last write wins
        assert m.gauge("x") == 2.5
        assert m.gauge("missing", -1.0) == -1.0
        assert m.snapshot()["gauges"] == {"x": 2.5}
