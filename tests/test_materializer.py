"""Materialization-subsystem tests: planner/cache correctness, batch ≡
sequential checkouts, fingerprint invalidation on commit/repack, access-count
persistence, cycle guards, and repack gc accounting."""

import numpy as np
import pytest

from repro.store import VersionStore
from repro.store.materializer import (
    CheckoutPlanner,
    MaterializationCache,
    storage_fingerprint,
    tree_nbytes,
)

from test_store import build_linear_history, make_payload, perturb


def build_branching_store(tmp_path, *, n=12, branch_every=3, seed=0,
                          shape=(48, 64), **store_kw):
    """Random branching history: every ``branch_every``-th commit forks from
    a random earlier version instead of the tip."""
    rng = np.random.RandomState(seed)
    store = VersionStore(tmp_path, **store_kw)
    payloads = {}
    p = make_payload(rng, shape=shape)
    vids = [store.commit(p, message="root")]
    payloads[vids[0]] = p
    for i in range(n - 1):
        if i % branch_every == branch_every - 1:
            parent = int(rng.choice(vids))
        else:
            parent = vids[-1]
        p = perturb(payloads[parent], rng, frac=0.04)
        vid = store.commit(p, parents=[parent], message=f"c{i}")
        payloads[vid] = p
        vids.append(vid)
    return store, vids, payloads


def assert_trees_equal(a, b):
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


class TestCheckoutManyProperty:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_batch_identical_to_sequential(self, tmp_path, seed):
        # random branching store, random request subsets (with repeats):
        # checkout_many must be bit-identical to sequential checkouts
        store, vids, payloads = build_branching_store(
            tmp_path / "a", n=10, seed=seed
        )
        rng = np.random.RandomState(100 + seed)
        for _ in range(3):
            k = rng.randint(1, len(vids) + 1)
            batch = [int(v) for v in rng.choice(vids, size=k, replace=True)]
            fresh = VersionStore(tmp_path / "a")  # cold store: no cache state
            sequential = [fresh.checkout(v) for v in batch]
            batched = store.checkout_many(batch)
            for got, want, vid in zip(batched, sequential, batch):
                assert_trees_equal(got, want)
                assert_trees_equal(got, fresh.checkout(vid))

    def test_batch_matches_committed_payloads(self, tmp_path):
        from repro.store import flatten_payload

        store, vids, payloads = build_branching_store(tmp_path, n=8, seed=7)
        out = store.checkout_many(vids)
        for vid, tree in zip(vids, out):
            assert_trees_equal(tree, flatten_payload(payloads[vid]))

    def test_batch_decodes_shared_prefix_once(self, tmp_path):
        store = VersionStore(tmp_path, cache_budget_bytes=0)
        vids, _ = build_linear_history(store, n=6, shape=(64, 64))
        m = store.materializer
        d0, f0 = m.delta_applies, m.full_decodes
        store.checkout_many(vids)  # whole chain: 1 full + n-1 deltas
        assert m.full_decodes - f0 == 1
        assert m.delta_applies - d0 == len(vids) - 1
        # sequential cold checkouts on a zero-budget cache pay the chain walk
        # per request: strictly more decodes than the single batched plan
        d1, f1 = m.delta_applies, m.full_decodes
        for v in vids:
            store.checkout(v)
        assert (m.delta_applies - d1) + (m.full_decodes - f1) > len(vids)

    def test_checkout_many_empty_and_unknown(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=2)
        assert store.checkout_many([]) == []
        with pytest.raises(KeyError):
            store.checkout_many([999])
        # a failed batch must not inflate the workload signal
        with pytest.raises(KeyError):
            store.checkout_many([vids[0], 999])
        assert store.versions[vids[0]].access_count == 0


class TestMaterializationCache:
    def test_warm_checkout_hits_cache(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=5)
        store.checkout(vids[-1])
        m = store.materializer
        d0, f0 = m.delta_applies, m.full_decodes
        warm = store.checkout(vids[-1])
        assert (m.delta_applies, m.full_decodes) == (d0, f0)  # no decode
        assert m.cache.hits >= 1
        # intermediates on the chain are warm too
        store.checkout(vids[2])
        assert (m.delta_applies, m.full_decodes) == (d0, f0)

    def test_commit_keeps_warm_entries_chain_mode(self, tmp_path):
        # append-aware default: a commit appends one storage triple but
        # rewrites no existing chain, so warm entries survive and the next
        # checkout of an old version is a pure cache hit
        store = VersionStore(tmp_path)
        vids, payload = build_linear_history(store, n=4)
        fp1 = store.storage_fingerprint()
        chain_fp = store.chain_fingerprint(vids[-1])
        store.checkout(vids[-1])
        assert len(store.materializer.cache.vids()) > 0
        rng = np.random.RandomState(9)
        store.commit(perturb(payload, rng), parents=[vids[-1]])
        assert store.storage_fingerprint() != fp1  # global epoch rotates...
        assert store.chain_fingerprint(vids[-1]) == chain_fp  # ...chains don't
        m = store.materializer
        d0, f0 = m.delta_applies, m.full_decodes
        store.checkout(vids[0])
        assert (m.delta_applies, m.full_decodes) == (d0, f0)  # no decode
        assert m.cache.invalidations == 0

    def test_commit_invalidates_cache_global_mode(self, tmp_path):
        # legacy discipline stays available as the purge-everything baseline
        store = VersionStore(tmp_path, cache_invalidation="global")
        vids, payload = build_linear_history(store, n=4)
        fp1 = store.storage_fingerprint()
        store.checkout(vids[-1])
        assert len(store.materializer.cache.vids()) > 0
        rng = np.random.RandomState(9)
        store.commit(perturb(payload, rng), parents=[vids[-1]])
        fp2 = store.storage_fingerprint()
        assert fp1 != fp2
        store.checkout(vids[0])  # first op under the new fingerprint
        assert store.materializer.cache.invalidations >= 1

    def test_repack_invalidates_cache_and_serves_fresh(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=5)
        warm = {v: store.checkout(v) for v in vids}
        fp1 = store.storage_fingerprint()
        store.repack("spt")  # materializes everything: new storage graph
        assert store.storage_fingerprint() != fp1
        for v in vids:
            assert_trees_equal(store.checkout(v), warm[v])

    def test_fingerprint_pure_function_of_triples(self, tmp_path):
        store = VersionStore(tmp_path)
        build_linear_history(store, n=3)
        assert store.storage_fingerprint() == storage_fingerprint(store.versions)
        reopened = VersionStore(tmp_path)
        assert reopened.storage_fingerprint() == store.storage_fingerprint()

    def test_byte_budget_evicts_lru(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=4, shape=(64, 64))
        one_tree = tree_nbytes(store.checkout(vids[0]))
        # budget for ~2 trees: a full-chain checkout must evict
        store2 = VersionStore(tmp_path, cache_budget_bytes=int(one_tree * 2.5))
        store2.checkout(vids[-1])
        cache = store2.materializer.cache
        assert cache.evictions > 0
        assert cache.current_bytes <= cache.budget_bytes

    def test_zero_budget_cache_still_correct(self, tmp_path):
        store = VersionStore(tmp_path, cache_budget_bytes=0)
        vids, last_payload = build_linear_history(store, n=4)
        from repro.store import flatten_payload

        assert_trees_equal(
            store.checkout(vids[-1]), flatten_payload(last_payload)
        )
        assert list(store.materializer.cache.vids()) == []

    def test_cached_arrays_are_read_only(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=2)
        tree = store.checkout(vids[-1])
        with pytest.raises(ValueError):
            tree["w"][0, 0] = 1.0  # mutating would corrupt the shared cache

    def test_zero_budget_arrays_also_read_only(self, tmp_path):
        # regression: apply_delta shares unchanged leaves across a batch's
        # results, so even uncached trees must be frozen — a writable alias
        # would let one result's mutation corrupt another's
        store = VersionStore(tmp_path, cache_budget_bytes=0)
        vids, _ = build_linear_history(store, n=3)
        t1, t2 = store.checkout_many([vids[-2], vids[-1]])
        with pytest.raises(ValueError):
            t1["b"][0] = 1.0

    def test_prefetch_warms_hot_versions(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=6)
        for _ in range(5):
            store.checkout(vids[-1])
        store.checkout(vids[0])
        stats = store.repack("lmg", budget=store.storage_bytes() * 1.5,
                             use_access_frequencies=True)
        assert "gc_freed_bytes" in stats
        # hottest version is warm under the *new* storage graph
        m = store.materializer
        d0, f0 = m.delta_applies, m.full_decodes
        store.checkout(vids[-1])
        assert (m.delta_applies, m.full_decodes) == (d0, f0)


class TestAccessCountPersistence:
    def test_counts_flush_and_reload(self, tmp_path):
        # regression: checkout bumped access_count in memory only, so
        # repack(use_access_frequencies=True) saw all-zero counts on reload
        store = VersionStore(tmp_path, access_flush_every=4)
        vids, _ = build_linear_history(store, n=3)
        for _ in range(4):  # exactly the flush threshold
            store.checkout(vids[-1])
        del store
        reopened = VersionStore(tmp_path)
        assert reopened.versions[vids[-1]].access_count == 4

    def test_close_flushes_partial_counts(self, tmp_path):
        store = VersionStore(tmp_path, access_flush_every=1000)
        vids, _ = build_linear_history(store, n=3)
        store.checkout(vids[1])
        store.checkout(vids[1])
        store.close()
        reopened = VersionStore(tmp_path)
        assert reopened.versions[vids[1]].access_count == 2

    def test_repack_persists_counts(self, tmp_path):
        store = VersionStore(tmp_path, access_flush_every=1000)
        vids, _ = build_linear_history(store, n=4)
        for _ in range(3):
            store.checkout(vids[-1])
        store.repack("lmg", budget=store.storage_bytes() * 1.5,
                     use_access_frequencies=True)
        reopened = VersionStore(tmp_path)
        assert reopened.versions[vids[-1]].access_count == 3


class TestCycleGuards:
    def _corrupt(self, store, a, b):
        store.versions[a].stored_base = b
        store.versions[b].stored_base = a

    def test_recreation_cost_raises_on_cycle(self, tmp_path):
        # regression: recreation_cost walked stored_base with no bound and
        # looped forever on corrupted metadata
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=3)
        self._corrupt(store, vids[1], vids[2])
        with pytest.raises(RuntimeError, match="cycle"):
            store.recreation_cost(vids[2])

    def test_checkout_raises_on_cycle(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=3)
        self._corrupt(store, vids[1], vids[2])
        with pytest.raises(RuntimeError, match="cycle"):
            store.checkout(vids[2])

    def test_checkout_many_raises_on_cycle(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=3)
        self._corrupt(store, vids[1], vids[2])
        with pytest.raises(RuntimeError, match="cycle"):
            store.checkout_many([vids[0], vids[2]])


class TestRepackGC:
    def test_gc_freed_bytes_surfaced_and_no_dangling(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=5)
        stats = store.repack("spt")  # rewrites all deltas as fulls
        assert stats["gc_freed_bytes"] > 0  # old delta objects reclaimed
        live = {m.object_key for m in store.log()}
        assert set(store.objects.keys()) == live  # nothing dangling
        # idempotent second repack frees nothing
        stats2 = store.repack("spt")
        assert stats2["gc_freed_bytes"] == 0
        assert set(store.objects.keys()) == {
            m.object_key for m in store.log()
        }


class TestPlanner:
    def test_plan_topological_and_deduplicated(self, tmp_path):
        store, vids, _ = build_branching_store(tmp_path, n=9, seed=3)
        planner = CheckoutPlanner(store)
        plan = planner.plan(vids)
        seen = set()
        for step in plan.steps:
            if step.base is not None:
                assert step.base in seen  # bases strictly before dependents
            assert step.vid not in seen  # each vid decoded at most once
            seen.add(step.vid)
        assert set(plan.requested) <= seen

    def test_plan_stops_at_cached_vids(self, tmp_path):
        store = VersionStore(tmp_path)
        vids, _ = build_linear_history(store, n=5)
        planner = CheckoutPlanner(store)
        full_plan = planner.plan([vids[-1]])
        short_plan = planner.plan([vids[-1]], cached=[vids[2]])
        assert short_plan.decode_count < full_plan.decode_count
        assert vids[2] in short_plan.from_cache
        assert all(s.vid not in (vids[0], vids[1], vids[2])
                   for s in short_plan.steps)

    def test_cache_standalone_lru_order(self):
        cache = MaterializationCache(budget_bytes=100)
        t = lambda: {"x": np.zeros(10, np.float32)}  # 40 bytes
        cache.ensure_fingerprint("fp")
        cache.put(1, t())
        cache.put(2, t())
        cache.get(1)  # refresh 1: now 2 is LRU
        cache.put(3, t())  # over budget: evicts 2
        assert 1 in cache and 3 in cache and 2 not in cache


class TestFusedPipeline:
    """The fused device-resident chain path must be invisible semantically:
    bit-identical trees, stepwise-equivalent decode counters, same fallback
    behavior when a planned-cached base is evicted mid-flight."""

    @pytest.mark.parametrize("budget", [0, 256 << 20])
    def test_fused_equals_stepwise(self, tmp_path, budget):
        store, vids, _ = build_branching_store(
            tmp_path / "a", n=10, seed=11, cache_budget_bytes=budget
        )
        fused = VersionStore(
            tmp_path / "a", cache_budget_bytes=budget, fuse_chains=True
        )
        stepwise = VersionStore(
            tmp_path / "a", cache_budget_bytes=budget, fuse_chains=False
        )
        for got, want in zip(
            fused.checkout_many(vids), stepwise.checkout_many(vids)
        ):
            assert_trees_equal(got, want)
        # fusion must not change what the accounting reports
        f, s = fused.materializer.stats(), stepwise.materializer.stats()
        for key in ("full_decodes", "delta_applies", "hits", "misses"):
            assert f[key] == s[key], key

    def test_whole_chain_fuses_at_zero_budget(self, tmp_path):
        # budget 0 and a single requested tip: the whole delta chain is one
        # segment — one fused launch wave, counters still stepwise-equivalent
        store = VersionStore(tmp_path, cache_budget_bytes=0)
        vids, _ = build_linear_history(store, n=6, shape=(64, 64))
        m = store.materializer
        d0, seg0 = m.delta_applies, m.fused_segments
        store.checkout(vids[-1])
        assert m.delta_applies - d0 == len(vids) - 1
        assert m.fused_segments - seg0 == 1

    @pytest.mark.parametrize("fuse", [True, False])
    def test_evicted_base_fallback(self, tmp_path, fuse):
        # a vid the planner saw as cached can be evicted before _execute
        # runs (concurrent checkouts sharing one cache); both paths must
        # rebuild it via the stepwise _materialize_chain fallback
        store = VersionStore(tmp_path, fuse_chains=fuse)
        vids, _ = build_linear_history(store, n=6, shape=(64, 64))
        m = store.materializer
        m.cache.ensure_fingerprint(store.storage_fingerprint())
        store.checkout(vids[2])  # warms the chain prefix through vids[2]
        plan = m.planner.plan([vids[-1]], cached=m.cache.vids())
        assert vids[2] in plan.from_cache
        m.cache._entries.clear()  # evict everything between plan and execute
        m.cache.current_bytes = 0
        trees = m._execute(plan)
        want = VersionStore(tmp_path, cache_budget_bytes=0).checkout(vids[-1])
        assert_trees_equal(trees[vids[-1]], want)

    def test_fused_trees_frozen_and_cached(self, tmp_path):
        store = VersionStore(tmp_path, fuse_chains=True)
        vids, _ = build_linear_history(store, n=4, shape=(64, 64))
        tree = store.checkout(vids[-1])
        with pytest.raises(ValueError):
            tree["w"][0, 0] = 1.0
        # warm-cache semantics unchanged under fusion: intermediates cached
        m = store.materializer
        d0, f0 = m.delta_applies, m.full_decodes
        store.checkout(vids[2])
        assert (m.delta_applies, m.full_decodes) == (d0, f0)
