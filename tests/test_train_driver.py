"""End-to-end train driver: loss decreases, preemption + resume is seamless,
repack holds the restore SLA. (The examples demo this; the test pins it.)"""

import numpy as np

from repro.checkpoint import PreemptionGuard
from repro.launch.train import RunConfig, train


class PreemptAt(PreemptionGuard):
    def __init__(self, at):
        super().__init__()
        self.at = at
        self.count = 0

    @property
    def preempted(self):
        self.count += 1
        return self.count >= self.at


def test_train_preempt_resume_repack(tmp_path):
    common = dict(
        arch="minicpm-2b", reduced=True, steps=16, seq_len=64,
        global_batch=4, save_every=4, ckpt_dir=str(tmp_path),
        max_restore_cost_s=30.0,
    )
    out1 = train(RunConfig(**common), guard=PreemptAt(at=8), log_every=100)
    assert out1["preempted"]
    assert 0 < out1["steps_done"] < 16

    out2 = train(RunConfig(**common), log_every=100)
    assert not out2["preempted"]
    losses = out1["losses"] + out2["losses"]
    assert len(losses) == 16  # no step repeated or skipped
    assert losses[-1] < losses[0]

    stats = out2["manager"].repack()
    assert stats["after"]["max_recreation_s"] <= 30.0
    out2["manager"].close()


def test_train_grad_accum_and_compression_run(tmp_path):
    out = train(RunConfig(
        arch="minitron-4b", reduced=True, steps=4, seq_len=32,
        global_batch=4, save_every=0, ckpt_dir=str(tmp_path),
        grad_accum=2, compress_grads=True,
    ), log_every=100)
    assert out["steps_done"] == 4
    assert np.isfinite(out["losses"]).all()
    out["manager"].close()
