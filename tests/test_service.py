"""DatasetService tests: coalescing, batching, write coordination, sweeps.

* **coalescing** — N concurrent checkouts of one ref perform exactly one
  materialization (counter-asserted: ``checkout.coalesced == N-1`` and one
  full decode at the store);
* **batching** — distinct refs inside one batching window fold into a
  single ``checkout_many`` dispatch; ``max_batch`` forces early dispatch;
* **commit visibility** — a commit through the service is immediately
  resolvable and checkout-able, and concurrent reads of old refs during
  commits return correct trees;
* **write exclusion** — repack drains readers (RW lock) and subsequent
  checkouts still verify bit-identical; cancellation while acquiring the
  write lock must not leak the writer flag (regression: stop() deadlock);
* **fsck sweep** — periodic and on-demand sweeps record metrics and keep
  the last report; error paths set error counters and propagate.

No pytest-asyncio in the image: each test drives its own event loop via
``asyncio.run``.
"""

import asyncio

import numpy as np
import pytest

from repro.core import OptimizeSpec
from repro.service import DatasetService, ServiceMetrics, percentile
from repro.service.service import _AsyncRWLock
from repro.store import Repository


def payload(seed: int, shape=(32, 24)):
    rng = np.random.RandomState(seed)
    return {"w": rng.randn(*shape).astype(np.float32)}


def build_repo(tmp_path, versions=6):
    repo = Repository(tmp_path)
    trees = {}
    for i in range(versions):
        vid = repo.commit(payload(i), message=f"v{i}")
        trees[vid] = payload(i)
    return repo, trees


class TestCoalescing:
    def test_concurrent_same_ref_single_materialization(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        tip = repo.resolve("main")

        async def go():
            async with repo.serve(readers=4) as svc:
                out = await asyncio.gather(
                    *(svc.checkout("main") for _ in range(10))
                )
                return out, svc.stats()

        out, stats = asyncio.run(go())
        for t in out:
            assert np.array_equal(t["w"], trees[tip]["w"])
        c = stats["counters"]
        # 10 requests, 9 coalesced onto the first's in-flight future
        assert c["requests.checkout"] == 10
        assert c["checkout.coalesced"] == 9
        assert c["checkout.batched_refs"] == 1
        # the store decoded the chain exactly once
        assert stats["store"]["full_decodes"] + stats["store"]["misses"] >= 1
        assert stats["store"]["hits"] == 0

    def test_each_request_gets_private_tree_dict(self, tmp_path):
        repo, _ = build_repo(tmp_path)

        async def go():
            async with repo.serve() as svc:
                a, b = await asyncio.gather(
                    svc.checkout("main"), svc.checkout("main")
                )
                return a, b

        a, b = asyncio.run(go())
        assert a is not b  # top-level dict is per-request
        a["extra"] = 1
        assert "extra" not in b

    def test_coalescing_keyed_by_vid_not_ref_spelling(self, tmp_path):
        repo, _ = build_repo(tmp_path)
        tip = repo.resolve("main")
        repo.tag("rel", at=tip)

        async def go():
            async with repo.serve() as svc:
                await asyncio.gather(
                    svc.checkout("main"), svc.checkout("rel"),
                    svc.checkout(tip),
                )
                return svc.stats()["counters"]

        c = asyncio.run(go())
        # three spellings of one vid: one materialization, two coalesced
        assert c["checkout.coalesced"] == 2
        assert c["checkout.batched_refs"] == 1


class TestBatching:
    def test_window_folds_distinct_refs_into_one_dispatch(self, tmp_path):
        repo, trees = build_repo(tmp_path, versions=8)
        vids = sorted(trees)[:6]

        async def go():
            async with repo.serve(batch_window_s=0.05) as svc:
                out = await svc.checkout_many(vids)
                return out, svc.stats()["counters"]

        out, c = asyncio.run(go())
        for t, v in zip(out, vids):
            assert np.array_equal(t["w"], trees[v]["w"])
        assert c["checkout.batches"] == 1
        assert c["checkout.batched_refs"] == len(vids)

    def test_max_batch_forces_early_dispatch(self, tmp_path):
        repo, trees = build_repo(tmp_path, versions=8)
        vids = sorted(trees)

        async def go():
            # window far longer than the test: only max_batch can dispatch
            async with repo.serve(batch_window_s=30.0, max_batch=4) as svc:
                await svc.checkout_many(vids[:4])
                return svc.stats()["counters"]

        c = asyncio.run(go())
        assert c["checkout.batches"] == 1
        assert c["checkout.batched_refs"] == 4

    def test_unknown_ref_rejects_without_poisoning_batch(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        good = sorted(trees)[0]

        async def go():
            async with repo.serve(batch_window_s=0.02) as svc:
                ok, bad = await asyncio.gather(
                    svc.checkout(good),
                    svc.checkout("no-such-branch"),
                    return_exceptions=True,
                )
                return ok, bad, svc.stats()["counters"]

        ok, bad, c = asyncio.run(go())
        assert np.array_equal(ok["w"], trees[good]["w"])
        assert isinstance(bad, ValueError)
        assert c["errors.checkout"] == 1


class TestWriteCoordination:
    def test_commit_visible_to_subsequent_checkout(self, tmp_path):
        repo, _ = build_repo(tmp_path)
        fresh = payload(99)

        async def go():
            async with repo.serve() as svc:
                vid = await svc.commit(fresh, message="via service")
                tree = await svc.checkout(vid)
                tip = await svc.checkout("main")
                return vid, tree, tip

        vid, tree, tip = asyncio.run(go())
        assert np.array_equal(tree["w"], fresh["w"])
        assert np.array_equal(tip["w"], fresh["w"])
        assert repo.resolve("main") == vid

    def test_reads_interleaved_with_commits_stay_correct(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        hot = sorted(trees)

        async def go():
            async with repo.serve(readers=3) as svc:
                for i in range(4):
                    out = await asyncio.gather(
                        svc.commit(payload(100 + i), message=f"a{i}"),
                        *(svc.checkout(v) for v in hot),
                    )
                    for t, v in zip(out[1:], hot):
                        assert np.array_equal(t["w"], trees[v]["w"])
                return svc.stats()["counters"]

        c = asyncio.run(go())
        assert c["requests.commit"] == 4
        assert c["requests.checkout"] == 4 * len(hot)
        assert c.get("errors.checkout", 0) == 0

    def test_repack_quiesces_and_trees_survive(self, tmp_path):
        repo, trees = build_repo(tmp_path, versions=8)
        vids = sorted(trees)

        async def go():
            async with repo.serve(readers=3) as svc:
                await svc.checkout_many(vids)  # warm
                stats = await svc.repack(OptimizeSpec.problem(2))
                out = await svc.checkout_many(vids)
                return stats, out, svc.stats()["store"]["purges"]

        stats, out, purges = asyncio.run(go())
        assert purges >= 1  # repack rewrites chains -> wholesale purge
        for t, v in zip(out, vids):
            assert np.array_equal(t["w"], trees[v]["w"])

    def test_log_and_diff_through_service(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        vids = sorted(trees)

        async def go():
            async with repo.serve() as svc:
                lg = await svc.log("main")
                d = await svc.diff(vids[0], vids[-1])
                return lg, d

        lg, d = asyncio.run(go())
        assert [m.vid for m in lg][0] == vids[-1]
        assert "w" in d.changed

    def test_requests_refuse_before_start_and_after_stop(self, tmp_path):
        repo, _ = build_repo(tmp_path)
        svc = DatasetService(repo)

        async def before():
            with pytest.raises(RuntimeError, match="not started"):
                await svc.checkout("main")

        asyncio.run(before())

        async def after():
            async with repo.serve() as svc2:
                pass
            with pytest.raises(RuntimeError, match="not started"):
                await svc2.checkout("main")

        asyncio.run(after())


class TestCancellation:
    def test_cancelled_waiter_does_not_poison_coalesced_future(
        self, tmp_path
    ):
        """Regression: waiters awaited the shared in-flight future
        directly, so cancelling one coalesced request cancelled the future
        under every other waiter (and left a cancelled future in _inflight
        for later arrivals)."""
        repo, trees = build_repo(tmp_path)
        tip = repo.resolve("main")

        async def go():
            async with repo.serve(batch_window_s=0.05) as svc:
                tasks = [
                    asyncio.create_task(svc.checkout("main"))
                    for _ in range(4)
                ]
                await asyncio.sleep(0.01)  # all coalesced onto one future
                tasks[0].cancel()
                done = await asyncio.gather(*tasks, return_exceptions=True)
                # a new request for the same vid must still be servable
                late = await svc.checkout("main")
                return done, late, svc.stats()["counters"]

        done, late, c = asyncio.run(go())
        assert isinstance(done[0], asyncio.CancelledError)
        for t in done[1:]:
            assert not isinstance(t, BaseException)
            assert np.array_equal(t["w"], trees[tip]["w"])
        assert np.array_equal(late["w"], trees[tip]["w"])
        assert c.get("errors.checkout", 0) == 0

    def test_cancelled_requester_does_not_let_repack_race_batch(
        self, tmp_path
    ):
        """Regression: a cancelled requester released its read claim while
        its _PendingCheckout stayed queued, so a repack could rewrite the
        storage graph concurrently with the window-timer dispatch.  The
        batch now parks one claim per entry until it settles: by the time
        the repack body runs, the orphaned batch must already be done."""
        repo, trees = build_repo(tmp_path, versions=8)
        vid = sorted(trees)[0]

        async def go():
            async with repo.serve(batch_window_s=0.05) as svc:
                t = asyncio.create_task(svc.checkout(vid))
                await asyncio.sleep(0.01)  # enqueued; claim parked on batch
                pend = list(svc._pending)
                assert pend and pend[0].vid == vid
                t.cancel()
                with pytest.raises(asyncio.CancelledError):
                    await t

                orig_repack = repo.repack
                settled_at_repack = {}

                def spying_repack(spec, **kw):
                    # runs on the writer thread once the write lock is held
                    settled_at_repack["done"] = pend[0].future.done()
                    return orig_repack(spec, **kw)

                repo.repack = spying_repack
                try:
                    await svc.repack(OptimizeSpec.problem(2))
                finally:
                    repo.repack = orig_repack
                tree = await svc.checkout(vid)
                return settled_at_repack, pend[0], tree

        settled, pend0, tree = asyncio.run(go())
        assert settled["done"] is True  # batch drained before the rewrite
        assert not pend0.future.cancelled()
        assert np.array_equal(tree["w"], trees[vid]["w"])


class TestRWLock:
    def test_writer_excludes_readers_and_vice_versa(self):
        async def go():
            lock = _AsyncRWLock()
            order = []

            async def reader(i):
                async with lock.read():
                    order.append(f"r{i}")
                    await asyncio.sleep(0.01)

            async def writer():
                async with lock.write():
                    order.append("w")

            # readers overlap each other; writer runs after both drain
            await asyncio.gather(reader(0), reader(1), writer())
            return order

        order = asyncio.run(go())
        assert order[-1] == "w"

    def test_cancel_during_write_acquire_releases_claim(self):
        """Regression: a writer cancelled while waiting for readers to
        drain must drop the writer flag, or every later acquire hangs."""

        async def go():
            lock = _AsyncRWLock()
            release = asyncio.Event()

            async def reader():
                async with lock.read():
                    await release.wait()

            r = asyncio.create_task(reader())
            await asyncio.sleep(0)  # reader holds the lock

            async def writer():
                async with lock.write():
                    pass

            w = asyncio.create_task(writer())
            await asyncio.sleep(0.01)  # writer now waiting on readers==0
            w.cancel()
            with pytest.raises(asyncio.CancelledError):
                await w
            release.set()
            await r
            # the lock must still be acquirable in both modes
            async with asyncio.timeout(1):
                async with lock.write():
                    pass
                async with lock.read():
                    pass

        try:
            asyncio.timeout  # py3.11+
        except AttributeError:
            pytest.skip("asyncio.timeout unavailable")
        asyncio.run(go())

    def test_cancel_during_write_acquire_releases_claim_py310(self):
        """Same regression without asyncio.timeout (runs on 3.10)."""

        async def go():
            lock = _AsyncRWLock()
            release = asyncio.Event()

            async def reader():
                async with lock.read():
                    await release.wait()

            r = asyncio.create_task(reader())
            await asyncio.sleep(0)

            async def writer():
                async with lock.write():
                    pass

            w = asyncio.create_task(writer())
            await asyncio.sleep(0.01)
            w.cancel()
            with pytest.raises(asyncio.CancelledError):
                await w
            release.set()
            await r

            async def reacquire():
                async with lock.write():
                    pass
                async with lock.read():
                    pass
                return True

            return await asyncio.wait_for(reacquire(), timeout=2.0)

        assert asyncio.run(go()) is True


class TestFsckSweep:
    def test_periodic_sweep_records_metrics(self, tmp_path):
        repo, _ = build_repo(tmp_path)

        async def go():
            async with repo.serve(fsck_interval_s=0.03) as svc:
                # wait on the counter, not wall time: CI boxes stall
                for _ in range(100):
                    if svc.metrics.counter("fsck.sweeps") >= 2:
                        break
                    await asyncio.sleep(0.02)
                return svc.stats(), svc.last_fsck

        stats, report = asyncio.run(go())
        c = stats["counters"]
        assert c["fsck.sweeps"] >= 2
        assert report is not None and not report.findings
        assert stats["fsck"]["checked"] > 0

    def test_on_demand_fsck(self, tmp_path):
        repo, _ = build_repo(tmp_path)

        async def go():
            async with repo.serve() as svc:  # no periodic sweeper
                report = await svc.fsck()
                return report, svc.stats()["counters"]

        report, c = asyncio.run(go())
        assert not report.findings
        assert c["fsck.sweeps"] == 1
        assert c["fsck.findings"] == 0

    def test_sweep_overlapping_traffic(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        hot = sorted(trees)

        async def go():
            async with repo.serve(readers=3, fsck_interval_s=0.02) as svc:
                for _ in range(6):
                    out = await svc.checkout_many(hot)
                    for t, v in zip(out, hot):
                        assert np.array_equal(t["w"], trees[v]["w"])
                    await asyncio.sleep(0.01)
                return svc.stats()["counters"]

        c = asyncio.run(go())
        assert c["fsck.sweeps"] >= 1
        assert c.get("errors.fsck", 0) == 0


class TestMetrics:
    def test_percentile_nearest_rank(self):
        xs = list(range(1, 101))
        assert percentile(xs, 50) in (50, 51)
        assert percentile(xs, 99) in (99, 100)
        assert percentile([7.0], 99) == 7.0
        with pytest.raises(ValueError):
            percentile([], 50)

    def test_track_window_bounded_but_totals_lifetime(self):
        m = ServiceMetrics(track_cap=10)
        for i in range(100):
            m.observe("lat", 0.001 * (i + 1))
        s = m.track("lat")
        assert s["count"] == 100  # lifetime
        # window holds only the last 10 samples (91ms..100ms)
        assert s["p50_ms"] >= 90.0
        assert s["max_ms"] == 100.0

    def test_snapshot_includes_counters_and_tracks(self):
        m = ServiceMetrics()
        m.inc("a")
        m.inc("a", 2)
        m.observe("t", 0.5)
        snap = m.snapshot()
        assert snap["counters"]["a"] == 3
        assert snap["tracks"]["t"]["count"] == 1

    def test_service_latency_tracks_populated(self, tmp_path):
        repo, _ = build_repo(tmp_path)

        async def go():
            async with repo.serve() as svc:
                await svc.checkout("main")
                await svc.commit(payload(50), message="x")
                return svc.stats()["tracks"]

        tracks = asyncio.run(go())
        assert tracks["latency.checkout"]["count"] == 1
        assert tracks["latency.commit"]["count"] == 1
        assert tracks["queue_wait"]["count"] == 1
        assert tracks["decode"]["count"] == 1
        assert tracks["latency.checkout"]["p99_ms"] > 0

    def test_stop_flushes_access_counts(self, tmp_path):
        repo, trees = build_repo(tmp_path)
        vids = sorted(trees)

        async def go():
            async with repo.serve() as svc:
                await svc.checkout_many(vids)

        asyncio.run(go())
        store = repo.store
        assert sum(store.versions[v].access_count for v in vids) >= len(vids)
