"""Render EXPERIMENTS.md tables from dryrun_results.jsonl."""
import json, sys

recs = {}
for l in open("dryrun_results.jsonl"):
    r = json.loads(l)
    recs[(r["arch"], r["shape"], r.get("mesh", "skip"))] = r

def fmt_s(x):
    if x is None: return "-"
    if x >= 1: return f"{x:.2f}s"
    if x >= 1e-3: return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"

ARCH_ORDER = ["qwen3-32b","starcoder2-7b","minitron-4b","minicpm-2b",
              "phi3.5-moe-42b-a6.6b","deepseek-v3-671b","seamless-m4t-medium",
              "recurrentgemma-9b","chameleon-34b","rwkv6-3b"]
SHAPE_ORDER = ["train_4k","prefill_32k","decode_32k","long_500k"]

print("| arch | shape | compile | mem/dev (args+temp) | compute | memory | collective | bound | useful-FLOPs | MFU-UB |")
print("|---|---|---|---|---|---|---|---|---|---|")
for a in ARCH_ORDER:
    for s in SHAPE_ORDER:
        r = recs.get((a, s, "16x16"))
        if r is None:
            r2 = recs.get((a, s, "skip")) or next((v for (aa,ss,mm),v in recs.items() if aa==a and ss==s and v.get("skipped")), None)
            if r2 and r2.get("skipped"):
                print(f"| {a} | {s} | — | per-spec skip | | | | | | |")
            continue
        if "error" in r: 
            print(f"| {a} | {s} | ERROR | | | | | | | |")
            continue
        m = r["memory"]; rf = r["roofline"]; mf = r["model_flops"]
        gb = (m["args_bytes_per_dev"] + m["temp_bytes_per_dev"]) / 1e9
        ufr = mf["useful_flops_ratio"]; mfu = mf["mfu_upper_bound"]
        print(f"| {a} | {s} | {r['compile_s']:.0f}s | {gb:.1f} GB | "
              f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
              f"{rf['dominant']} | {ufr:.2f} | {mfu*100:.1f}% |" if ufr else
              f"| {a} | {s} | {r['compile_s']:.0f}s | {gb:.1f} GB | - | - | - | - | - | - |")

print()
print("### Multi-pod (2×16×16 = 512 chips) — compile proof + memory")
print()
print("| arch | shape | compile | mem/dev | bound | collective Δ vs single-pod |")
print("|---|---|---|---|---|---|")
for a in ARCH_ORDER:
    for s in SHAPE_ORDER:
        r = recs.get((a, s, "2x16x16"))
        r1 = recs.get((a, s, "16x16"))
        if r is None or "error" in r: continue
        m = r["memory"]; rf = r["roofline"]
        gb = (m["args_bytes_per_dev"] + m["temp_bytes_per_dev"]) / 1e9
        dc = ""
        if r1 and "roofline" in r1:
            c0, c1 = r1["roofline"]["collective_s"], rf["collective_s"]
            dc = f"{(c1/c0-1)*100:+.0f}%" if c0 > 0 else "-"
        print(f"| {a} | {s} | {r['compile_s']:.0f}s | {gb:.1f} GB | {rf['dominant']} | {dc} |")
