"""Recompute roofline memory terms with the Pallas flash-attention kernel
substituted for the unfused XLA attention chain (EXPERIMENTS §Perf cell 3).

The CPU harness cannot *measure* the kernel's effect (interpret mode
re-expands the chain into the same HLO ops), so this derives the optimized
term analytically and auditable-y:

  memory'_s = memory_s − (unfused attention bytes)/BW + (flash bytes)/BW

Unfused attention bytes per layer are estimated from the same traffic model
hlo_analysis uses (score-chain ops × f32 score tensor size); flash bytes come
from kernels.flash_attention.flash_hbm_bytes (Q+K+V+O + K/V per q-wave).

Usage: python tools/flash_substitution.py [dryrun_results.jsonl]
"""

import json
import sys

sys.path.insert(0, "src")

from repro.configs import ARCHS, SHAPES  # noqa: E402
from repro.kernels.flash_attention import flash_hbm_bytes  # noqa: E402

HBM_BW = 819e9
# ops touching the f32 score tensor in the unfused online-softmax chain
# (mask-select, max, sub, exp, sum, correction mul, pv-cast, carry r/w)
SCORE_CHAIN_OPS = 8


def unfused_attention_bytes(cfg, shape, chips: int, passes: int) -> float:
    """Per-device f32 score-chain traffic for one step (all layers)."""
    B, S = shape.global_batch, shape.seq_len
    H = cfg.n_heads
    attn_layers = sum(1 for k in cfg.blocks() if k in ("attn", "mla"))
    # scores (B·H·S·S) f32 streamed SCORE_CHAIN_OPS times, sharded over chips
    per_layer = B * H * S * S * 4.0 * SCORE_CHAIN_OPS / chips
    return per_layer * attn_layers * passes


def flash_bytes(cfg, shape, chips: int, passes: int) -> float:
    B, S = shape.global_batch, shape.seq_len
    attn_layers = sum(1 for k in cfg.blocks() if k == "attn")
    per_layer = flash_hbm_bytes(
        B, S, S, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ) / chips
    return per_layer * attn_layers * passes


def main(path: str = "dryrun_results.jsonl") -> None:
    recs = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("skipped") or "error" in r:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r

    print(f"{'cell':44s} {'memory_s':>9s} {'attn_unfused':>12s} "
          f"{'attn_flash':>10s} {'memory_s(flash)':>15s}")
    for (arch, shape_name, mesh), r in sorted(recs.items()):
        if mesh != "16x16" or shape_name != "prefill_32k":
            continue
        cfg = ARCHS[arch]
        if cfg.q_lora_rank or cfg.is_encdec or not any(
            k == "attn" for k in cfg.blocks()
        ):
            continue  # MLA / enc-dec / attention-free: kernel variant N/A
        shape = SHAPES[shape_name]
        chips = r["chips"]
        mem_s = r["roofline"]["memory_s"]
        passes = 1  # prefill: forward only
        un = unfused_attention_bytes(cfg, shape, chips, passes)
        fl = flash_bytes(cfg, shape, chips, passes)
        new = max(0.0, mem_s - un / HBM_BW + fl / HBM_BW)
        print(f"{arch+' × '+shape_name:44s} {mem_s:9.2f} {un/HBM_BW:12.2f} "
              f"{fl/HBM_BW:10.3f} {new:15.2f}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.jsonl")
