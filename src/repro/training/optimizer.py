"""Optimizer + LR schedules (pure JAX, no optax dependency).

AdamW with bf16 params / f32 moments; schedules include **WSD**
(warmup-stable-decay — MiniCPM's schedule, arXiv:2404.06395) next to cosine
and constant.  All state is a plain pytree, so it versions/checkpoints
through the store like everything else.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    schedule: str = "cosine"          # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_frac: float = 0.8          # WSD: fraction of post-warmup at peak
    final_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def learning_rate(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / jnp.maximum(1.0, cfg.warmup_steps))
    t = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    if cfg.schedule == "cosine":
        frac = cfg.final_lr_frac + (1 - cfg.final_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t)
        )
    elif cfg.schedule == "wsd":
        # stable at peak for stable_frac, then exponential-style decay
        decay_t = jnp.clip((t - cfg.stable_frac) / max(1e-6, 1 - cfg.stable_frac), 0, 1)
        frac = jnp.where(
            t < cfg.stable_frac,
            1.0,
            cfg.final_lr_frac ** decay_t,
        )
    else:
        frac = jnp.asarray(1.0)
    return cfg.peak_lr * warm * frac


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/scalars (1-D leaves)."""
    return True


def adamw_update(
    cfg: OptimizerConfig, params: Any, grads: Any, opt_state: Dict[str, Any]
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    lr = learning_rate(cfg, step)

    # global-norm clip (f32)
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * gf
        nu = b2 * nu + (1 - b2) * gf * gf
        update = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices only
            update = update + cfg.weight_decay * pf
        return (pf - lr * update).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "mu": jax.tree.unflatten(treedef, new_mu),
        "nu": jax.tree.unflatten(treedef, new_nu),
        "step": step,
    }
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params, new_state, metrics
