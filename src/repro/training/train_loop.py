"""Train step factory: loss, remat, microbatching, gradient compression.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function ready for ``jax.jit`` with donated state.  Features:

* causal-LM cross entropy in f32 (+ DeepSeek MTP auxiliary loss);
* per-layer remat is inside the model (scan body checkpointing);
* **microbatching**: grad accumulation over ``grad_accum`` slices via
  ``lax.scan`` — global batch stays fixed while peak activation memory
  drops by the accumulation factor;
* optional **int8 gradient compression with error feedback** — the
  distributed-optimization knob: quantize per-tensor-block, keep the
  quantization residual host-side in state and re-inject next step
  (error feedback keeps convergence; see distributed/compression.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.compression import compress_grads
from ..models.registry import ModelBundle
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    grad_accum: int = 1
    compress_grads: bool = False
    mtp_weight: float = 0.3
    z_loss: float = 1e-4


def init_train_state(bundle: ModelBundle, rng) -> Dict[str, Any]:
    params = bundle.init(rng)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "error_fb": None,  # created lazily when compression is on
    }


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0] - lse
    loss = -ll.mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


CE_CHUNK = 512


def chunked_cross_entropy(head_fn, params, hidden: jnp.ndarray,
                          targets: jnp.ndarray, z_loss: float = 0.0):
    """CE over (B, S, D) hidden without materializing (B, S, V) logits.

    Scans the sequence in CE_CHUNK slices; each chunk computes its logits,
    loss contribution, and is rematerialized in the backward pass
    (``jax.checkpoint`` on the body).  This is the difference between
    ~150 GB and ~2 GB of temp at train_4k × 123k vocab.
    """
    B, S, D = hidden.shape
    n = -(-S // CE_CHUNK)
    pad = n * CE_CHUNK - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    hc = hidden.reshape(B, n, CE_CHUNK, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, CE_CHUNK).transpose(1, 0, 2)
    valid_per_chunk = jnp.clip(
        S - jnp.arange(n) * CE_CHUNK, 0, CE_CHUNK
    )

    @jax.checkpoint
    def body(carry, inp):
        h, t, nvalid = inp
        lf = head_fn(params, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, t[..., None], axis=-1)[..., 0] - lse
        mask = jnp.arange(CE_CHUNK)[None, :] < nvalid
        loss_sum = -(ll * mask).sum()
        z_sum = (jnp.square(lse) * mask).sum()
        return (carry[0] + loss_sum, carry[1] + z_sum), None

    (loss_sum, z_sum), _ = jax.lax.scan(
        body, (0.0, 0.0), (hc, tc, valid_per_chunk)
    )
    denom = B * S
    return loss_sum / denom + z_loss * z_sum / denom


def make_loss_fn(bundle: ModelBundle, tcfg: TrainConfig):
    cfg = bundle.cfg

    def loss_fn(params, batch):
        hidden, aux = bundle.train_hidden(params, batch)
        tokens = batch["tokens"]
        loss = chunked_cross_entropy(
            bundle.head, params, hidden[:, :-1], tokens[:, 1:], tcfg.z_loss
        )
        if "mtp_hidden" in aux:
            # mtp hidden[s] predicts token s+2 (built from h_s and emb_{s+1})
            mtp = aux["mtp_hidden"]
            loss = loss + tcfg.mtp_weight * chunked_cross_entropy(
                bundle.head, params, mtp[:, :-1], tokens[:, 2:], 0.0
            )
        return loss

    return loss_fn


def make_train_step(
    bundle: ModelBundle, tcfg: TrainConfig
) -> Callable[[Dict, Dict], Tuple[Dict, Dict]]:
    loss_fn = make_loss_fn(bundle, tcfg)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        if tcfg.grad_accum > 1:
            def slice_batch(b, i):
                return jax.tree.map(
                    lambda x: x.reshape((tcfg.grad_accum, -1) + x.shape[1:])[i], b
                )

            def accum(carry, i):
                gsum, lsum = carry
                mb = slice_batch(batch, i)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(
                accum, (g0, 0.0), jnp.arange(tcfg.grad_accum)
            )
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, gsum)
            loss = lsum / tcfg.grad_accum
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        error_fb = state.get("error_fb")
        if tcfg.compress_grads:
            if error_fb is None:
                error_fb = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
            grads, error_fb = compress_grads(grads, error_fb)

        params, opt, opt_metrics = adamw_update(
            tcfg.optimizer, params, grads, state["opt"]
        )
        metrics = {"loss": loss, **opt_metrics}
        return {"params": params, "opt": opt, "error_fb": error_fb}, metrics

    return train_step
