"""Elastic scaling + straggler handling (control-plane side).

On a 1000+-node fleet, hosts fail and get replaced mid-run.  The data-plane
elasticity (re-sharding checkpoints onto a different mesh) lives in
``checkpoint.restore_to_template``; this module is the control plane that
pairs with it:

* :class:`HostTopology` — the current set of healthy hosts and the
  deterministic assignment of data-pipeline shards to hosts.  On failure or
  join, ``rebalance`` produces a new assignment **and** the pipeline state
  every host should resume from, so the global token stream stays exactly-
  once (batch `i` is a pure function of (seed, i), so shard reassignment is
  just a host_id/n_hosts change).
* :class:`StragglerPolicy` — per-step wall-time tracking with a robust
  deadline (median × tolerance).  The launcher consults it to decide when a
  host should be treated as failed (checkpoint-restore-drop cycle) rather
  than waited on; on synchronous SPMD fleets this is *the* availability
  knob, since one slow host stalls every collective.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    host_id: int
    n_hosts: int
    resume_step: int


class HostTopology:
    def __init__(self, hosts: Sequence[str]) -> None:
        if not hosts:
            raise ValueError("need at least one host")
        self.hosts: List[str] = sorted(hosts)

    def assignment(self, host: str, *, resume_step: int = 0) -> ShardAssignment:
        return ShardAssignment(
            host_id=self.hosts.index(host),
            n_hosts=len(self.hosts),
            resume_step=resume_step,
        )

    def rebalance(
        self, *, failed: Sequence[str] = (), joined: Sequence[str] = (),
        resume_step: int = 0,
    ) -> Dict[str, ShardAssignment]:
        """New deterministic assignment after membership changes.

        All hosts restart their pipelines at ``resume_step`` (the step of the
        checkpoint being restored) under the new (host_id, n_hosts): since
        batches are pure functions of (seed, step, host_id, n_hosts), the
        global stream after the change is exactly the one a fresh job of the
        new size would produce from that step — no token is lost or doubled
        within the new epoch regime.
        """
        survivors = [h for h in self.hosts if h not in set(failed)]
        for h in joined:
            if h not in survivors:
                survivors.append(h)
        if not survivors:
            raise ValueError("no hosts left after rebalance")
        self.hosts = sorted(survivors)
        return {
            h: self.assignment(h, resume_step=resume_step) for h in self.hosts
        }


class StragglerPolicy:
    """Flag hosts whose step times exceed median × tolerance persistently."""

    def __init__(self, *, tolerance: float = 2.0, patience: int = 3,
                 window: int = 32) -> None:
        self.tolerance = tolerance
        self.patience = patience
        self.window = window
        self._times: Dict[str, List[float]] = {}
        self._strikes: Dict[str, int] = {}

    def record(self, host: str, step_time_s: float) -> None:
        buf = self._times.setdefault(host, [])
        buf.append(step_time_s)
        if len(buf) > self.window:
            buf.pop(0)

    def deadline_s(self) -> Optional[float]:
        latest = [t[-1] for t in self._times.values() if t]
        if len(latest) < 2:
            return None
        return statistics.median(latest) * self.tolerance

    def update_strikes(self) -> None:
        dl = self.deadline_s()
        if dl is None:
            return
        for host, buf in self._times.items():
            if buf and buf[-1] > dl:
                self._strikes[host] = self._strikes.get(host, 0) + 1
            else:
                self._strikes[host] = 0

    def stragglers(self) -> List[str]:
        return sorted(
            h for h, s in self._strikes.items() if s >= self.patience
        )
