"""Logical-axis sharding (MaxText-style rules table).

Model code never names mesh axes: tensors are annotated with *logical* axes
("batch", "embed", "heads", "experts", ...) and a rules table maps those to
mesh axes.  One code path therefore lowers every (arch × shape × mesh) cell;
switching the parallelism layout = switching the rules table — which is how
the §Perf hillclimb iterates sharding without touching model code.

Divisibility fallback: a logical axis whose dimension does not divide the
mapped mesh axes is silently replicated (e.g. kv_heads=8 on a 16-way model
axis, or batch=1 on the long-context cell) — matching what production
frameworks do rather than erroring.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# data-parallel submesh: "pod" is the slowest axis so DP gradients reduce
# hierarchically (intra-pod reduce-scatter, inter-pod all-reduce).
DP_AXES = ("pod", "data")

DEFAULT_RULES: Rules = {
    "batch": DP_AXES,
    "seq": None,
    "kv_seq": None,
    "embed": DP_AXES,        # weights' d_model dim => FSDP (ZeRO-3 style)
    "act_embed": None,       # activations' d_model dim stays unsharded
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",
    "experts_ep": ("data", "model"),  # EP: experts stationary over the mesh
    "expert_fsdp": ("pod",),          # EP weights' inner dim: ZeRO-3 over pod
    "expert_mlp": None,
    "lru": "model",
    "lora": None,
    "conv": None,
    "stack": None,           # the scanned layer axis
    None: None,
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def logical_sharding(mesh: Mesh, rules: Optional[Rules] = None):
    """Activate sharding annotations inside model code."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def mesh_axis_size(mesh: Mesh, axes: Union[None, str, Tuple[str, ...]]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a] if a in mesh.shape else 1
    return size


def spec_for(
    logical_axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    *,
    mesh: Optional[Mesh] = None,
    rules: Optional[Rules] = None,
    strict: bool = True,
) -> P:
    """PartitionSpec for a tensor given its logical axes.

    ``strict=True`` (jit argument shardings) requires even divisibility —
    pjit rejects uneven input shards.  ``strict=False`` (activation
    constraints) only requires dim ≥ mesh extent: GSPMD pads uneven
    *intermediate* shardings, which is how 36-head attention still runs
    16-way TP (ceil(36/16)=3 heads/device).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    out = []
    used: set = set()
    for i, ax in enumerate(logical_axes):
        mapped = rules.get(ax, None)
        if mapped is None:
            out.append(None)
            continue
        axes_t = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        axes_t = tuple(a for a in axes_t if mesh is None or a in mesh.shape)
        if not axes_t or any(a in used for a in axes_t):
            out.append(None)
            continue
        if shape is not None and mesh is not None:
            # degrade gracefully: drop leading axes until the dim divides
            # (e.g. experts=16 on ("data","model") -> ("model",))
            while axes_t:
                size = mesh_axis_size(mesh, axes_t)
                bad = (shape[i] % size != 0) if strict else (shape[i] < size)
                if not bad:
                    break
                axes_t = axes_t[1:]
            if not axes_t:
                out.append(None)
                continue
        used.update(axes_t)
        out.append(axes_t if len(axes_t) > 1 else axes_t[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation inside jit; no-op outside logical_sharding()."""
    if _CTX.mesh is None:
        return x
    spec = spec_for(logical_axes, x.shape, strict=False)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec)
    )


def tree_spec(
    logical_tree: Any,
    shape_tree: Any,
    *,
    mesh: Mesh,
    rules: Optional[Rules] = None,
) -> Any:
    """Map a pytree of logical-axis tuples (+ matching ShapeDtypeStructs) to
    NamedShardings — this builds jit's in_shardings/out_shardings."""
    rules = rules or DEFAULT_RULES

    def one(axes, sds):
        return NamedSharding(
            mesh, spec_for(axes, sds.shape, mesh=mesh, rules=rules)
        )

    return jax.tree.map(
        one, logical_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )
