"""Gradient compression: blockwise int8 quantization with error feedback.

The distributed-optimization trick for bandwidth-bound DP meshes: gradients
are quantized to int8 with a per-block f32 scale before they cross the
data-parallel axis, and the quantization residual is carried into the next
step (error feedback — Seide et al. '14, Karimireddy et al. '19 — keeps
SGD/Adam convergence despite biased rounding).

In SPMD JAX the DP all-reduce is implicit in the backward pass, so the
baseline path applies quantize→dequantize to the *reduced* gradient (models
the information loss; bytes-on-wire savings are realized on real pods by
pairing this with an explicit ``shard_map`` reduce-scatter in int8 — see
``compressed_psum`` below, which the multi-pod launcher can enable).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, shape, size) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def quant_dequant(x: jnp.ndarray) -> jnp.ndarray:
    q, s = _quantize(x.astype(jnp.float32))
    return _dequantize(q, s, x.shape, x.size)


def compress_grads(grads: Any, error_fb: Any) -> Tuple[Any, Any]:
    """Apply error-feedback int8 compression leaf-wise.

    Returns (compressed_grads, new_error_fb): g' = Q(g + e); e' = (g + e) - g'.
    """

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        gq = quant_dequant(gf)
        return gq, gf - gq

    pairs = jax.tree.map(one, grads, error_fb)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_e


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-on-the-wire psum for use inside shard_map on real pods:
    quantize locally, sum int32 across the axis, rescale by the max scale.

    The wire format is 1 byte/elem + 4 bytes/block ≈ 4× reduction vs f32.
    """
    q, s = _quantize(x.astype(jnp.float32))
    s_max = jax.lax.pmax(s, axis_name)
    # renormalize local blocks to the shared scale so the int32 sum is exact
    q32 = jnp.round(
        q.astype(jnp.float32) * (s / jnp.maximum(s_max, 1e-12))
    ).astype(jnp.int32)
    total = jax.lax.psum(q32, axis_name)
    return _dequantize(total.astype(jnp.float32), s_max, x.shape, x.size)
