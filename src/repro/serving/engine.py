"""Serving engine: batched prefill + decode against a shardable KV cache.

The engine wraps a ModelBundle's ``prefill``/``decode_step`` in jitted,
donated-cache steps and manages a simple continuous batch: requests join at
prefill, generate with greedy/temperature sampling, and leave at EOS/limit.
The same engine object drives the multi-pod dry-run's serve cells and the
CPU example, differing only in mesh/shardings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.registry import ModelBundle


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_generated)
    prefill_s: float
    decode_s: float
    steps: int


class ServeEngine:
    def __init__(
        self,
        bundle: ModelBundle,
        params: Any,
        *,
        max_len: int,
        batch: int,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        enc_len: Optional[int] = None,
    ) -> None:
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.max_len = max_len
        self.batch = batch
        self.temperature = temperature
        self.eos_id = eos_id
        self.enc_len = enc_len
        kw = {"enc_len": enc_len} if self.cfg.is_encdec else {}
        self._cache0 = bundle.init_cache(batch, max_len, **kw)
        self._prefill = jax.jit(bundle.prefill, donate_argnums=(2,))
        self._decode = jax.jit(bundle.decode_step, donate_argnums=(2,))

    def _sample(self, logits: jnp.ndarray, rng_key) -> jnp.ndarray:
        logits = logits[:, -1, : self.cfg.vocab].astype(jnp.float32)
        if self.temperature <= 0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            rng_key, logits / self.temperature, axis=-1
        )[:, None].astype(jnp.int32)

    def generate(
        self,
        batch: Dict[str, np.ndarray],
        *,
        max_new_tokens: int,
        seed: int = 0,
    ) -> GenerationResult:
        prompt_len = batch["tokens"].shape[1]
        assert batch["tokens"].shape[0] == self.batch
        key = jax.random.PRNGKey(seed)

        t0 = time.monotonic()
        logits, cache = self._prefill(self.params, batch, self._cache0)
        tok = self._sample(logits, key)
        prefill_s = time.monotonic() - t0

        out = [np.asarray(tok)]
        done = np.zeros((self.batch,), bool)
        t0 = time.monotonic()
        steps = 0
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            pos = prompt_len + i
            logits, cache = self._decode(self.params, tok, cache, pos)
            tok = self._sample(logits, sub)
            host_tok = np.asarray(tok)
            out.append(host_tok)
            steps += 1
            if self.eos_id is not None:
                done |= host_tok[:, 0] == self.eos_id
                if done.all():
                    break
        decode_s = time.monotonic() - t0
        # re-init with the *constructor* enc_len: a different encoder length
        # here would change cache shapes and silently retrigger XLA
        # compilation (or truncate/overrun the encoder) on the next generate
        self._cache0 = self.bundle.init_cache(
            self.batch, self.max_len,
            **({"enc_len": self.enc_len} if self.cfg.is_encdec else {}),
        )
        return GenerationResult(
            tokens=np.concatenate(out, axis=1),
            prefill_s=prefill_s,
            decode_s=decode_s,
            steps=steps + 1,
        )
