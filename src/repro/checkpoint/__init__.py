from .emergency import PreemptionGuard
from .versioned import VersionedCheckpointManager, restore_to_template

__all__ = ["VersionedCheckpointManager", "PreemptionGuard", "restore_to_template"]
