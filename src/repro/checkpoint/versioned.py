"""Versioned distributed checkpointing on top of the VersionStore.

The checkpoint manager is where the paper's storage/recreation tradeoff
becomes a *fault-tolerance* control: Problem 6's θ (max recreation cost) is
the restore-latency SLA that bounds MTTR on node failure, and ``repack``
enforces it while shrinking bytes at rest.

Production posture:

* **async saves** — the train step donates nothing to the checkpoint path;
  device→host transfer happens synchronously (cheap), serialization +
  delta encode + store I/O run on a background thread;
* **branch/merge aware** — fine-tune forks and model merges pass explicit
  parent version ids, building the DAG the solvers optimize;
* **elastic restore** — checkpoints are stored mesh-agnostic (logical path →
  host array); ``restore`` re-shards onto whatever mesh/sharding the new job
  runs, so a 512-chip run can resume on 256 chips (or vice versa);
* **emergency saves** — a synchronous path invoked from preemption handlers.
"""

from __future__ import annotations

import concurrent.futures
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Sequence

import jax
import numpy as np

from ..core import OptimizeSpec
from ..store import VersionStore
from ..store.delta import FlatTree, flatten_payload


class VersionedCheckpointManager:
    def __init__(
        self,
        directory: str | Path,
        *,
        store: Optional[VersionStore] = None,
        max_restore_cost_s: Optional[float] = None,
        repack_every: int = 0,
    ) -> None:
        self.store = store or VersionStore(directory)
        self.max_restore_cost_s = max_restore_cost_s
        self.repack_every = repack_every
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="ckpt"
        )
        self._pending: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()
        self.step_to_vid: Dict[int, int] = {}
        self._saves_since_repack = 0
        # recover the step map from persisted commit messages (restart path)
        for meta in self.store.log():
            for part in meta.message.split():
                if part.startswith("step="):
                    try:
                        self.step_to_vid[int(part[5:])] = meta.vid
                    except ValueError:
                        pass

    # ---------------------------------------------------------------- save
    def save(
        self,
        step: int,
        state: Any,
        *,
        parent_steps: Optional[Sequence[int]] = None,
        message: str = "",
        blocking: bool = False,
    ) -> None:
        """Commit ``state`` (pytree) as a child of the previous checkpoint."""
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        if parent_steps is None:
            parents = [self.latest_vid()] if self.step_to_vid else []
        else:
            parents = [self.step_to_vid[s] for s in parent_steps]
        parents = [p for p in parents if p is not None]

        def _commit():
            vid = self.store.commit(
                host_state, parents=parents, message=message or f"step={step}"
            )
            with self._lock:
                self.step_to_vid[step] = vid
                self._saves_since_repack += 1
                if self.repack_every and self._saves_since_repack >= self.repack_every:
                    self._saves_since_repack = 0
                    self._auto_repack()
            return vid

        self.wait()  # one outstanding save at a time
        fut = self._pool.submit(_commit)
        self._pending = fut
        if blocking:
            fut.result()

    def emergency_save(self, step: int, state: Any) -> int:
        """Synchronous save for preemption handlers (always blocking)."""
        self.save(step, state, message=f"EMERGENCY step={step}", blocking=True)
        return self.step_to_vid[step]

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -------------------------------------------------------------- restore
    def latest_vid(self) -> Optional[int]:
        if not self.step_to_vid:
            return None
        return self.step_to_vid[max(self.step_to_vid)]

    def latest_step(self) -> Optional[int]:
        return max(self.step_to_vid) if self.step_to_vid else None

    def restore(
        self,
        template: Any,
        *,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Any:
        """Rebuild ``template``-shaped state from the store.

        ``template`` is a pytree of arrays or ShapeDtypeStructs giving the
        logical structure; ``shardings`` (same structure or a single sharding)
        re-shards each leaf — this is the **elastic restore** path: the target
        mesh may differ from the one that saved the checkpoint.
        """
        self.wait()
        vid = self.step_to_vid[step] if step is not None else self.latest_vid()
        if vid is None:
            raise FileNotFoundError("no checkpoints saved")
        flat = self.store.checkout(vid)
        return restore_to_template(flat, template, shardings)

    def restore_cost_s(self, step: Optional[int] = None) -> float:
        vid = self.step_to_vid[step] if step is not None else self.latest_vid()
        return self.store.recreation_cost(vid)

    # --------------------------------------------------------------- repack
    def sla_spec(self, theta: Optional[float] = None) -> OptimizeSpec:
        """The restore-latency SLA as a declarative spec: Problem 6
        (min storage s.t. max R_i ≤ θ) with θ = ``max_restore_cost_s``."""
        theta = theta if theta is not None else self.max_restore_cost_s
        if theta is None:
            raise ValueError("set max_restore_cost_s or pass theta=")
        return OptimizeSpec.problem(6, theta=theta)

    def repack(self, spec: "OptimizeSpec | str | None" = None, **kw) -> Dict:
        """Re-optimize storage against an OptimizeSpec; the default enforces
        the restore-latency SLA (:meth:`sla_spec`).  A string solver name is
        the deprecated legacy surface, forwarded to ``VersionStore.repack``'s
        shim (with the SLA θ injected for ``"mp"``)."""
        self.wait()
        if spec is None:
            spec = self.sla_spec(kw.pop("theta", None))
        elif isinstance(spec, str) and spec == "mp" and "theta" not in kw:
            if self.max_restore_cost_s is None:
                raise ValueError("set max_restore_cost_s or pass theta=")
            kw["theta"] = self.max_restore_cost_s
        return self.store.repack(spec, **kw)

    def _auto_repack(self):
        try:
            self.repack()
        except Exception:
            pass  # repack is best-effort in the background

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)
        # persist access counts accumulated by restores, so a later
        # repack(use_access_frequencies=True) sees the real workload
        self.store.close()


def restore_to_template(flat: FlatTree, template: Any, shardings: Any = None) -> Any:
    """Match store paths onto ``template``'s structure, device_put per leaf."""
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    shard_list = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )[0]
        if len(shard_flat) == 1:
            shard_list = shard_flat * len(paths_leaves)
        else:
            shard_list = shard_flat
    leaves = []
    for i, (path, leaf) in enumerate(paths_leaves):
        key = "/".join(_p(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        if shard_list is not None:
            arr = jax.device_put(arr, shard_list[i])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _p(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)
