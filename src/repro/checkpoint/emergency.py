"""Preemption / emergency checkpoint handling.

On real fleets the maintenance notice arrives as SIGTERM (or a runtime
callback) a few seconds before eviction.  `PreemptionGuard` registers a
handler that flips a flag the train loop polls each step; the loop then
takes the *synchronous* emergency-save path and exits cleanly.  The guard is
also directly triggerable (`guard.trigger()`) so tests and simulated-failure
drills exercise the identical code path.
"""

from __future__ import annotations

import signal
import threading
from typing import Callable, Optional


class PreemptionGuard:
    def __init__(self, *, install_signal_handlers: bool = False) -> None:
        self._event = threading.Event()
        self._prev = {}
        if install_signal_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                self._prev[sig] = signal.signal(sig, self._handler)

    def _handler(self, signum, frame):
        self._event.set()

    def trigger(self) -> None:
        """Simulate a preemption notice (tests / failure drills)."""
        self._event.set()

    @property
    def preempted(self) -> bool:
        return self._event.is_set()

    def reset(self) -> None:
        self._event.clear()

    def uninstall(self) -> None:
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)
        self._prev.clear()
