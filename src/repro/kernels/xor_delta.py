"""Pallas TPU kernel: XOR delta encode/apply (paper's XOR delta variant).

Pure bandwidth kernel: reads two HBM streams, writes one.  Tiled so each
program instance moves ``rows_per_program`` 4 KiB storage blocks through
VMEM; the (8, 128) minor dims are exactly one int32 VMEM tile, so the MXU is
idle and the VPU runs at line rate — the roofline is HBM bandwidth
(3 streams × N bytes / 819 GB/s on v5e).

The kernel is its own inverse (a ^ (a ^ b) == b), so encode and apply share
the implementation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import PALLAS_INTERPRET

DEFAULT_ROWS_PER_PROGRAM = 256  # 256 blocks × 4 KiB × 3 streams = 3 MiB VMEM


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.bitwise_xor(a_ref[...], b_ref[...])


@functools.partial(jax.jit, static_argnames=("rows_per_program", "interpret"))
def xor_delta(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    rows_per_program: int = DEFAULT_ROWS_PER_PROGRAM,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """a ^ b over (num_blocks, 8, 128) int32 block arrays."""
    assert a.shape == b.shape and a.dtype == b.dtype == jnp.int32, (a.shape, a.dtype)
    nb = a.shape[0]
    rows = min(rows_per_program, nb)
    grid = (pl.cdiv(nb, rows),)
    spec = pl.BlockSpec((rows,) + a.shape[1:], lambda i: (i, 0, 0))
    return pl.pallas_call(
        _xor_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        # alias `a` to the output: each program reads its tile before the
        # (same-placed) write, so the delta can be built in place instead of
        # allocating a third full-shard HBM buffer
        input_output_aliases={0: 0},
        interpret=interpret,
    )(a, b)
