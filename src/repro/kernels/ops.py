"""Public jit'd wrappers around the delta-path kernels.

Converts arbitrary tensors (any dtype/shape) to and from the canonical
``(num_blocks, 8, 128)`` int32 block layout, and exposes the encode/apply
operations the store uses:

* :func:`to_blocks` / :func:`from_blocks` — byte-preserving (bitcast + pad)
  layout conversion;
* :func:`xor_encode` / :func:`xor_apply` — the paper's XOR delta variant;
* :func:`sparse_encode` / :func:`sparse_apply` — block-sparse delta:
  changed-block mask (Pallas), compaction to (idx, blocks), scattered apply
  (Pallas).  Capacity is rounded up to a power of two so jit recompiles stay
  bounded when the number of changed blocks varies between commits.

Interpret mode is governed by the package-level
:data:`repro.kernels.PALLAS_INTERPRET` knob (``REPRO_PALLAS_INTERPRET`` env
var): interpret on this CPU container, compiled Mosaic on real TPU backends.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import PALLAS_INTERPRET
from .block_diff import block_hash, changed_block_mask, hash_coefficients
from .chain_apply import chain_delta_apply, chain_delta_apply_batched
from .ref import BLOCK_BYTES, BLOCK_ELEMS
from .sparse_apply import sparse_delta_apply
from .xor_delta import xor_delta

INTERPRET = PALLAS_INTERPRET  # single env-controlled knob for all kernels


@dataclasses.dataclass(frozen=True)
class BlockMeta:
    """Everything needed to reverse :func:`to_blocks`."""

    dtype: str
    shape: Tuple[int, ...]
    nbytes: int
    num_blocks: int


def to_blocks(x: jnp.ndarray) -> Tuple[jnp.ndarray, BlockMeta]:
    """View a tensor's bytes as (num_blocks, 8, 128) int32, zero-padded."""
    nbytes = x.size * x.dtype.itemsize
    flat_u8 = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-nbytes) % BLOCK_BYTES
    if pad:
        flat_u8 = jnp.concatenate([flat_u8, jnp.zeros((pad,), jnp.uint8)])
    num_blocks = (nbytes + pad) // BLOCK_BYTES
    as_i32 = jax.lax.bitcast_convert_type(
        flat_u8.reshape(-1, 4), jnp.int32
    ).reshape(num_blocks, 8, 128)
    meta = BlockMeta(str(x.dtype), tuple(x.shape), nbytes, num_blocks)
    return as_i32, meta


def from_blocks(blocks: jnp.ndarray, meta: BlockMeta) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`."""
    flat_u8 = jax.lax.bitcast_convert_type(
        blocks.reshape(-1), jnp.uint8
    ).reshape(-1)[: meta.nbytes]
    dtype = jnp.dtype(meta.dtype)
    itemsize = dtype.itemsize
    if itemsize > 1:
        flat = jax.lax.bitcast_convert_type(flat_u8.reshape(-1, itemsize), dtype)
    else:
        flat = jax.lax.bitcast_convert_type(flat_u8, dtype)
    return flat.reshape(meta.shape)


# ------------------------------------------------------------------ XOR delta
def xor_encode(base_blocks: jnp.ndarray, new_blocks: jnp.ndarray) -> jnp.ndarray:
    return xor_delta(base_blocks, new_blocks, interpret=INTERPRET)


def xor_apply(base_blocks: jnp.ndarray, delta_blocks: jnp.ndarray) -> jnp.ndarray:
    return xor_delta(base_blocks, delta_blocks, interpret=INTERPRET)


# ---------------------------------------------------------------- block hash
# initialized at import: the concurrent serving tier calls block_hashes from
# multiple threads, and a lazily-assigned global would race on first use
_COEF = jnp.asarray(hash_coefficients())


def block_hashes(blocks: jnp.ndarray) -> jnp.ndarray:
    return block_hash(blocks, _COEF, interpret=INTERPRET)[:, 0]


# --------------------------------------------------------- block-sparse delta
def _round_capacity(k: int) -> int:
    cap = 8
    while cap < k:
        cap *= 2
    return cap


@functools.partial(jax.jit, static_argnames=("capacity",))
def _compact(mask: jnp.ndarray, new_blocks: jnp.ndarray, capacity: int):
    """Pack changed block rows into (idx[capacity], blocks[capacity]).

    Padding slots are *collision-free by construction*: they point at the
    first unchanged row (where new == base, so a redundant write is a no-op),
    or — when every row changed — at row 0 carrying row 0's new content (a
    redundant write of correct data).  The apply kernel therefore never needs
    conditional stores for slots emitted by this function.
    """
    m = mask[:, 0].astype(jnp.int32)
    nb = m.shape[0]
    order = jnp.cumsum(m) - 1  # destination slot per changed row
    slots = jnp.where(m == 1, order, capacity)  # unchanged -> dropped
    # explicit index_dtype: int32 whether or not jax_enable_x64 is active
    pad_row = jax.lax.argmin(m, 0, jnp.int32)  # first unchanged row (0 if none)
    idx = jnp.full((capacity,), -1, jnp.int32)
    idx = idx.at[slots].set(jnp.arange(nb, dtype=jnp.int32), mode="drop")
    idx = jnp.where(idx >= 0, idx, pad_row)
    gathered = new_blocks[idx]
    # NB the returned count is the *true* number of changed rows: when it
    # exceeds ``capacity`` the drop-mode scatter above has discarded the
    # overflow and the packed delta is incomplete — callers must check
    # (sparse_encode raises host-side; fully-traced callers branch on it).
    return idx, gathered, jnp.sum(m, dtype=jnp.int32)


def sparse_encode(
    base_blocks: jnp.ndarray, new_blocks: jnp.ndarray, *, capacity: int | None = None
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Return (idx, packed_blocks, n_changed) for the block-sparse delta.

    With ``capacity=None`` the exact changed count is materialized host-side
    (store/commit path, off the step-critical path) and capacity grows to
    fit.  An explicit ``capacity`` keeps jit recompiles bounded, but must
    cover the changed count: an undersized capacity would silently drop
    changed blocks in ``_compact`` (producing a delta that ``sparse_apply``
    cannot detect as corrupt), so this host-side wrapper raises instead.
    Fully-traced callers should use ``_compact`` directly and branch on the
    returned count.
    """
    mask = changed_block_mask(base_blocks, new_blocks, interpret=INTERPRET)
    if capacity is None:
        # one device→host sync on the commit path: the mask sum both sizes
        # the capacity and *is* the changed count, so _compact's (identical)
        # device-side count is never materialized host-side
        n = int(jnp.sum(mask[:, 0]))
        capacity = _round_capacity(max(1, n))
        idx, blocks, _ = _compact(mask, new_blocks, capacity)
        return idx, blocks, n
    idx, blocks, n_dev = _compact(mask, new_blocks, capacity)
    n = int(n_dev)
    if n > capacity:
        raise ValueError(
            f"sparse_encode capacity overflow: {n} changed blocks exceed "
            f"capacity={capacity}; pass capacity>={_round_capacity(n)} (or "
            f"capacity=None to size automatically)"
        )
    return idx, blocks, n


def sparse_apply(
    base_blocks: jnp.ndarray, packed_blocks: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    return sparse_delta_apply(base_blocks, packed_blocks, idx, interpret=INTERPRET)


# ------------------------------------------------------------- chain apply
def chain_apply(
    base_blocks: jnp.ndarray, packed_blocks: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Fused K-step chain application (see :mod:`.chain_apply`): ``idx`` /
    ``packed_blocks`` stack K packed sparse deltas in chain order, flat or
    ``(K, capacity)``-shaped, padding ``idx < 0``."""
    return chain_delta_apply(base_blocks, packed_blocks, idx, interpret=INTERPRET)


def chain_apply_batched(
    base_stack: jnp.ndarray, packed_blocks: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Fused chain application for L same-sized leaves in one launch."""
    return chain_delta_apply_batched(
        base_stack, packed_blocks, idx, interpret=INTERPRET
    )
