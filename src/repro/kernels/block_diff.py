"""Pallas TPU kernels: changed-block detection + block content hashing.

These feed the store's block-sparse delta encoder (DESIGN.md §5): the
changed-block mask selects which 4 KiB blocks of a new checkpoint shard
actually differ from the delta base, and the block hash provides dedup hints
for content addressing.  Both are single-pass VMEM reductions over the
(num_blocks, 8, 128) int32 block layout; outputs are (num_blocks, 1) so the
minor dim stays TPU-friendly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import PALLAS_INTERPRET

DEFAULT_ROWS_PER_PROGRAM = 256


def _mask_kernel(a_ref, b_ref, o_ref):
    diff = a_ref[...] != b_ref[...]
    o_ref[...] = jnp.any(diff, axis=(1, 2))[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "interpret"))
def changed_block_mask(
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    rows_per_program: int = DEFAULT_ROWS_PER_PROGRAM,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """(num_blocks, 1) int32 mask of blocks where ``a`` and ``b`` differ."""
    assert a.shape == b.shape and a.dtype == b.dtype == jnp.int32
    nb = a.shape[0]
    rows = min(rows_per_program, nb)
    grid = (pl.cdiv(nb, rows),)
    in_spec = pl.BlockSpec((rows,) + a.shape[1:], lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        interpret=interpret,
    )(a, b)


def _hash_kernel(x_ref, coef_ref, o_ref):
    prod = x_ref[...] * coef_ref[...]
    o_ref[...] = jnp.sum(prod, axis=(1, 2), dtype=jnp.int32)[:, None]


def hash_coefficients(seed: int = 0x9E3779B9) -> np.ndarray:
    """Deterministic odd per-position multipliers for the block hash."""
    rng = np.random.RandomState(seed & 0x7FFFFFFF)
    coef = rng.randint(0, 2**31, size=(8, 128), dtype=np.int64)
    coef = (coef * 2 + 1).astype(np.int64)  # odd => position-bijective
    return coef.astype(np.uint32).view(np.int32).reshape(8, 128)


@functools.partial(jax.jit, static_argnames=("rows_per_program", "interpret"))
def block_hash(
    x: jnp.ndarray,
    coef: jnp.ndarray,
    *,
    rows_per_program: int = DEFAULT_ROWS_PER_PROGRAM,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """(num_blocks, 1) int32 position-weighted hash per 4 KiB block."""
    assert x.dtype == jnp.int32 and coef.shape == x.shape[1:]
    nb = x.shape[0]
    rows = min(rows_per_program, nb)
    grid = (pl.cdiv(nb, rows),)
    in_spec = pl.BlockSpec((rows,) + x.shape[1:], lambda i: (i, 0, 0))
    coef_spec = pl.BlockSpec(coef.shape, lambda i: (0, 0))
    out_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    return pl.pallas_call(
        _hash_kernel,
        grid=grid,
        in_specs=[in_spec, coef_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        interpret=interpret,
    )(x, coef)
