"""Pallas TPU kernel: block-sparse delta *apply* — the recreation hot path.

Recreating version ``V_j`` from ``V_i`` along the storage tree applies a
packed set of changed 4 KiB blocks onto the base shard.  The kernel uses a
scalar-prefetched index vector so the output BlockSpec can place each delta
block at its dynamic destination row — the TPU analogue of a scattered
memcpy, one VMEM tile per grid step.  ``input_output_aliases`` keeps the
base in place: unchanged blocks are never touched, so the cost is
O(changed bytes), matching the Φ model of DESIGN.md §5.

Padding rows (idx < 0) are redirected to row 0 and masked by writing the
base tile back (`jnp.where` on the prefetched index).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import PALLAS_INTERPRET


def _apply_kernel(idx_ref, base_ref, blocks_ref, o_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    o_ref[...] = jnp.where(valid, blocks_ref[...], base_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparse_delta_apply(
    base: jnp.ndarray,
    blocks: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """Scatter ``blocks[k]`` into ``base[idx[k]]``; idx<0 rows are padding.

    base   : (num_blocks, 8, 128) int32
    blocks : (k, 8, 128) int32
    idx    : (k,) int32
    """
    assert base.dtype == blocks.dtype == jnp.int32
    assert base.shape[1:] == blocks.shape[1:] == (8, 128)
    assert idx.shape == (blocks.shape[0],)
    k = blocks.shape[0]

    def dest_row(i, idx_ref):
        return (jnp.maximum(idx_ref[i], 0), 0, 0)

    return pl.pallas_call(
        _apply_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, 8, 128), dest_row),  # base tile at the dest
                pl.BlockSpec((1, 8, 128), lambda i, idx_ref: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 8, 128), dest_row),
        ),
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={1: 0},  # alias `base` (arg after prefetch) to out
        interpret=interpret,
    )(idx, base, blocks)
