# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Pallas kernel package for the delta/storage hot paths.

Interpret-mode policy
---------------------
Every Pallas kernel in this package (``xor_delta``, ``block_diff``,
``sparse_apply``, ``chain_apply``, ``segment_ops``, ``flash_attention``)
defaults its ``interpret`` flag to the single package-level constant
:data:`PALLAS_INTERPRET`, read **once at import** from the
``REPRO_PALLAS_INTERPRET`` environment variable:

* unset / ``1`` / ``true`` → interpret mode (the kernel body runs under the
  Pallas interpreter — correct everywhere, required on this CPU container);
* ``0`` / ``false`` / ``no`` / ``off`` → compiled Mosaic lowering for real
  TPU backends.

A real-TPU run therefore flips one knob (``REPRO_PALLAS_INTERPRET=0``)
instead of editing per-module ``INTERPRET`` constants.  Call sites may still
pass ``interpret=`` explicitly (tests exercising both modes do).
"""

import os


def interpret_from_env(value: "str | None") -> bool:
    """Parse the ``REPRO_PALLAS_INTERPRET`` setting (None = unset → True)."""
    if value is None:
        return True
    return value.strip().lower() not in ("0", "false", "no", "off")


PALLAS_INTERPRET = interpret_from_env(os.environ.get("REPRO_PALLAS_INTERPRET"))
