"""Pallas TPU flash-attention (forward) — the §Perf structural fix.

The dry-run's dominant memory term for every attention arch is the unfused
online-softmax chain: XLA materializes each (B, Sq, KV, g, chunk) f32
score/probability tensor in HBM (~15 round trips per layer; e.g. 928 × 0.5 GB
at deepseek train_4k).  This kernel keeps the whole chain in VMEM: HBM
traffic collapses to Q + K + V + O (+ the tiny m/l carries), i.e.

    bytes ≈ 2·B·S·(H + 2·KV)·hd·bf16   per layer
    vs    ≳ 12·B·S²/chunk-scaled f32 score traffic for the unfused chain.

Grid: (batch·kv_head, q_blocks) with an inner fori_loop over KV blocks —
one (block_q × block_k) f32 score tile lives in registers/VMEM at a time.
Blocks default to 512×512 (q-tile 512×128 bf16 = 128 KiB; score tile
512×512 f32 = 1 MiB — comfortably inside v5e's 128 MiB VMEM with double
buffering).  MXU dims (block_q, hd, block_k) are all multiples of 128.

Causal masking is positional (global offsets), so the same kernel serves
prefill (Sq == Sk) and chunked-prefill.  GQA folds the group into the
q-block rows.  Forward-only: serving paths use it directly; the train
backward would pair it with dq/dk/dv kernels (future work, noted in
EXPERIMENTS §Perf).  Validated against layers.chunked_attention in
interpret mode (tests/test_flash_attention.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import PALLAS_INTERPRET

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  scale: float, seq_k: int):
    # q_ref: (block_q, g, hd) for one (b, kv_head, q_block); k/v: (seq_k, hd)
    qi = pl.program_id(1)
    block_q = q_ref.shape[0]
    g, hd = q_ref.shape[1], q_ref.shape[2]
    q = (q_ref[...].astype(jnp.float32) * scale).reshape(block_q * g, hd)
    q = q.astype(q_ref.dtype)

    n_kb = seq_k // block_k
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, g), 0
    ).reshape(block_q * g)

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[pl.ds(kb * block_k, block_k), :]          # (block_k, hd)
        v = v_ref[pl.ds(kb * block_k, block_k), :]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # (bq*g, block_k)
        if causal:
            k_pos = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            s = jnp.where(k_pos <= q_pos[:, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc = acc * corr[:, None] + pv
        return m_new, l_new, acc

    m0 = jnp.full((block_q * g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q * g,), jnp.float32)
    a0 = jnp.zeros((block_q * g, hd), jnp.float32)
    if causal:
        # only blocks up to the diagonal contribute
        last = jnp.minimum(n_kb, (qi + 1) * block_q // block_k + 1)
    else:
        last = n_kb
    m, l, acc = jax.lax.fori_loop(0, last, body, (m0, l0, a0))
    out = acc / jnp.maximum(l[:, None], 1e-30)
    o_ref[...] = out.reshape(block_q, g, hd).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "softmax_scale"),
)
def flash_attention(
    q: jnp.ndarray,   # (B, Sq, H, hd)
    k: jnp.ndarray,   # (B, Sk, KV, hd)
    v: jnp.ndarray,   # (B, Sk, KV, hd)
    *,
    causal: bool = True,
    softmax_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    _, Sk, KV, hd_v = v.shape
    assert H % KV == 0 and hd == k.shape[-1] and hd_v == hd, \
        "flash kernel requires uniform head dims (MLA uses the XLA path)"
    g = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    # layout: (B, KV, Sq, g, hd) so one grid step owns one (b, kv) pair
    qt = q.reshape(B, Sq, KV, g, hd).transpose(0, 2, 1, 3, 4)
    kt = k.transpose(0, 2, 1, 3)  # (B, KV, Sk, hd)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B * KV, Sq // block_q)
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal, scale=scale, seq_k=Sk
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, g, hd),
                         lambda bh, qi: (bh // KV, bh % KV, qi, 0, 0)),
            pl.BlockSpec((None, None, Sk, hd),
                         lambda bh, qi: (bh // KV, bh % KV, 0, 0)),
            pl.BlockSpec((None, None, Sk, hd),
                         lambda bh, qi: (bh // KV, bh % KV, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, g, hd),
                               lambda bh, qi: (bh // KV, bh % KV, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, Sq, g, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd)


def flash_hbm_bytes(B: int, Sq: int, Sk: int, H: int, KV: int, hd: int,
                    dtype_bytes: int = 2) -> int:
    """Analytic HBM traffic of the kernel (the §Perf substitution term):
    Q and O once; K/V once per q-block wave (VMEM-resident within a wave)."""
    q_o = 2 * B * Sq * H * hd * dtype_bytes
    kv = 2 * B * Sk * KV * hd * dtype_bytes * max(1, Sq // DEFAULT_BLOCK_Q)
    return q_o + kv
