"""Pallas kernels: segment-min / segment-argmin over padded CSR rows.

The jitted solver backend (:mod:`repro.core.solvers.jax_backend`) reshapes
the version graph's CSR rows into a dense ``(rows, width)`` matrix padded
with ``+inf`` — in-edges per vertex for the SSSP relaxation, flat candidate
vectors for the LMG scoring round.  The reductions over that layout are the
solver inner loops, and they compile to single-pass VMEM row reductions:

* :func:`segment_min_rows`    — per-row minimum (the Bellman-Ford relaxation);
* :func:`segment_argmin_rows` — per-row first-minimum index (parent/candidate
  selection; first occurrence matches NumPy tie-breaking);
* :func:`min_argmin_1d`       — global ``(min, argmin)`` of a flat vector via
  the row kernels (vertex selection in Prim/MP, ρ-argmax in LMG).

Each wrapper takes ``use_pallas``: ``True`` routes through the Pallas kernels
(``interpret=True`` on this CPU container, matching the idiom of
``kernels/ops.py``; flipped to compiled mode on real TPU backends), ``False``
lowers the same reduction through plain XLA ops — the fast path on CPU, where
the Pallas interpreter adds per-call overhead.  Both paths are bit-identical:
the reductions are order-insensitive min/first-argmin over the same floats.

NOTE: solver costs are float64; the interpreter handles that everywhere, but
real TPU lowering would need a float32 (or split hi/lo) variant.  Index math,
by contrast, is int32 end to end: argmins use an explicit ``index_dtype`` and
the flat-index reconstruction in :func:`min_argmin_1d` guards its int32
capacity host-side instead of relying on ``jax_enable_x64`` widening (which
silently does not happen in the default production mode).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import PALLAS_INTERPRET

DEFAULT_ROWS_PER_PROGRAM = 256
LANE = 128  # pad the minor dim to the TPU lane width

# largest flat vector min_argmin_1d can index with int32 math (padded length
# r * LANE + col must not wrap); guarded host-side because shapes are static
MAX_INT32_ELEMS = (1 << 31) - 1 - LANE

INTERPRET = PALLAS_INTERPRET  # REPRO_PALLAS_INTERPRET env knob (kernels pkg)


def _min_kernel(x_ref, o_ref):
    o_ref[...] = jnp.min(x_ref[...], axis=1)[:, None]


def _argmin_kernel(x_ref, o_ref):
    # explicit index_dtype: jnp.argmin would emit int64 under jax_enable_x64
    # (and silently int32 without it) — int32 is the contract either way
    o_ref[...] = lax.argmin(x_ref[...], 1, jnp.int32)[:, None]


def _row_call(kernel, x: jnp.ndarray, out_dtype, *, rows_per_program: int,
              interpret: bool) -> jnp.ndarray:
    nr, nc = x.shape
    rows = min(rows_per_program, nr)
    grid = (pl.cdiv(nr, rows),)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, nc), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, 1), out_dtype),
        interpret=interpret,
    )(x)[:, 0]


def segment_min_rows(
    x: jnp.ndarray,
    *,
    use_pallas: bool = True,
    rows_per_program: int = DEFAULT_ROWS_PER_PROGRAM,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-row minimum of a padded ``(rows, width)`` segment matrix."""
    if not use_pallas:
        return jnp.min(x, axis=1)
    interpret = INTERPRET if interpret is None else interpret
    return _row_call(_min_kernel, x, x.dtype,
                     rows_per_program=rows_per_program, interpret=interpret)


def segment_argmin_rows(
    x: jnp.ndarray,
    *,
    use_pallas: bool = True,
    rows_per_program: int = DEFAULT_ROWS_PER_PROGRAM,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-row index of the first minimum (NumPy ``argmin`` tie-breaking)."""
    if not use_pallas:
        return lax.argmin(x, 1, jnp.int32)
    interpret = INTERPRET if interpret is None else interpret
    return _row_call(_argmin_kernel, x, jnp.int32,
                     rows_per_program=rows_per_program, interpret=interpret)


def pad_to_rows(x: jnp.ndarray, fill) -> jnp.ndarray:
    """Reshape a flat vector to ``(rows, LANE)``, padding the tail with
    ``fill`` — the layout the row kernels reduce over."""
    n = x.shape[0]
    pad = (-n) % LANE
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x.reshape(-1, LANE)


def min_argmin_1d(
    x: jnp.ndarray, *, use_pallas: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global ``(min, first argmin)`` of a flat float vector.

    Two-stage: per-row kernel reduction, then a (tiny) reduction over row
    minima.  First-occurrence semantics survive both stages — the first row
    attaining the global min is picked, then the first column within it.

    Flat indices are int32 (``r * LANE + col`` never widens): vectors longer
    than :data:`MAX_INT32_ELEMS` are refused host-side rather than silently
    wrapping — without ``jax_enable_x64`` an ``astype(int64)`` would have
    been a silent int32 downcast anyway.
    """
    if x.shape[0] > MAX_INT32_ELEMS:
        raise ValueError(
            f"min_argmin_1d int32 index capacity exceeded: {x.shape[0]} "
            f"elements > {MAX_INT32_ELEMS}"
        )
    if not use_pallas:
        i = lax.argmin(x, 0, jnp.int32)
        return x[i], i
    x2 = pad_to_rows(x, jnp.inf)
    row_min = segment_min_rows(x2, use_pallas=True)
    r = lax.argmin(row_min, 0, jnp.int32)
    # argmin only the winning row — a full per-row argmin pass would double
    # the kernel work for a single consumed lane
    col = segment_argmin_rows(x2[r][None, :], use_pallas=True)[0]
    i = r * LANE + col
    return row_min[r], jnp.minimum(i, x.shape[0] - 1)
