"""Pure-jnp oracles for the delta-path Pallas kernels.

Everything operates on the canonical *block layout*: a tensor's backing bytes
are viewed as ``(num_blocks, 8, 128)`` int32 — one storage block is exactly
one TPU VMEM tile (8 sublanes × 128 lanes × 4 B = 4 KiB).  The paper's delta
variants map onto this layout:

* XOR delta (paper §2.1 "an XOR between the two versions can be an
  appropriate delta");
* block-sparse delta (cell/line-level differences at block granularity):
  a changed-block mask, the packed changed blocks, and their indices.
"""

from __future__ import annotations

import jax.numpy as jnp

BLOCK_SHAPE = (8, 128)
BLOCK_ELEMS = BLOCK_SHAPE[0] * BLOCK_SHAPE[1]
BLOCK_BYTES = BLOCK_ELEMS * 4


def xor_delta_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Bitwise delta between two same-shape int32 block arrays.

    Self-inverse: ``xor_delta_ref(a, xor_delta_ref(a, b)) == b``.
    """
    return jnp.bitwise_xor(a, b)


def changed_block_mask_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(num_blocks, 1) int32 mask: 1 where a block differs anywhere."""
    diff = a != b
    return jnp.any(diff, axis=(1, 2), keepdims=False)[:, None].astype(jnp.int32)


def block_hash_ref(x: jnp.ndarray, coef: jnp.ndarray) -> jnp.ndarray:
    """(num_blocks, 1) int32 position-weighted multiplicative hash.

    ``coef`` is an (8, 128) int32 array of odd per-position multipliers; the
    hash is Σ x[s,l]·coef[s,l] with int32 wraparound.  Used as a dedup *hint*
    (the store verifies candidate matches bytewise).
    """
    prod = x * coef[None, :, :]
    return jnp.sum(prod, axis=(1, 2), dtype=jnp.int32)[:, None]


def sparse_delta_apply_ref(
    base: jnp.ndarray, blocks: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Scatter packed delta blocks into a copy of ``base``.

    base   : (num_blocks, 8, 128) int32
    blocks : (k, 8, 128) int32 packed changed blocks
    idx    : (k,) int32 destination block rows; negative = padding (dropped)
    """
    # negative indices would *wrap* under numpy semantics; remap padding to an
    # out-of-bounds row so mode="drop" actually drops it.
    safe = jnp.where(idx < 0, base.shape[0], idx)
    return base.at[safe].set(blocks, mode="drop")


def chain_delta_apply_ref(
    base: jnp.ndarray, blocks: jnp.ndarray, idx: jnp.ndarray
) -> jnp.ndarray:
    """Stepwise oracle for the fused chain kernel: apply each step's packed
    delta in chain order (later steps overwrite earlier — the sparse deltas
    carry new block *content*, so composition is last-writer-wins).

    base   : (num_blocks, 8, 128) int32
    blocks : (K, capacity, 8, 128) int32 (or flat (S, 8, 128))
    idx    : (K, capacity) int32 (or flat (S,)); negative = padding
    """
    idx2 = idx.reshape(-1, 1) if idx.ndim == 1 else idx
    blocks2 = blocks.reshape(idx2.shape + (8, 128))
    out = base
    for k in range(idx2.shape[0]):
        out = sparse_delta_apply_ref(out, blocks2[k], idx2[k])
    return out
