"""Pallas TPU kernel: fused delta-*chain* application — whole-chain checkout.

Recreating ``V_k`` along a K-step storage chain used to pay K sequential
:func:`~repro.kernels.sparse_apply.sparse_delta_apply` dispatches, each
bracketed by a host↔device round trip.  This kernel applies the *entire*
chain in one dispatch: the K packed sparse deltas are first folded into one
effective write set without touching the base, then scattered in a single
pass.

Folding (``_fold_dest``, plain XLA inside the jit): the chain's sparse
deltas carry *new block content* (not XOR), so chain composition is
last-writer-wins per block row.  Flattening the ``(K, capacity)`` delta
stack in chain order makes "last writer" simply the **maximum flat slot id**
writing a row — a single deterministic scatter-max builds the winner map,
and a gather marks every slot as winner / loser.  Losers and padding slots
(``idx < 0``) are redirected to a trash row appended past the base, so the
scatter kernel needs **no conditional stores**: every grid step
unconditionally copies its 4 KiB block to its destination row, exactly one
winner lands on every changed row, and everything else lands on the trash
row that is sliced off afterwards.  (``_compact``'s collision-free padding
contract makes its padding slots redundant self-writes; the redirect handles
them and plain ``-1`` wire padding uniformly.)

The scatter itself mirrors ``sparse_apply``: a scalar-prefetched destination
vector drives the output BlockSpec, ``input_output_aliases`` keeps the base
in place, and the cost is O(total changed bytes + base copy), independent of
chain depth K.

``chain_delta_apply_batched`` fuses *many leaves* (same block count, same
padded slot count — the shape-bucketing contract of
``store/delta.py``) into one launch by offsetting each leaf's block rows
into a concatenated row space with a single shared trash row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import PALLAS_INTERPRET


def _fold_dest(idx_flat: jnp.ndarray, num_rows: int) -> jnp.ndarray:
    """Destination row per flat slot: its block row if the slot is the chain's
    last writer of that row, else the trash row ``num_rows``.

    ``idx_flat`` is the chain's packed block-row indices flattened in chain
    order; negative entries are padding.  Later slots win (scatter-max over
    flat slot ids — deterministic, unlike duplicate-index scatter-set).
    """
    s = idx_flat.shape[0]
    slot_ids = jnp.arange(s, dtype=jnp.int32)
    valid = idx_flat >= 0
    rows = jnp.where(valid, idx_flat, num_rows)
    winner = (
        jnp.full((num_rows,), -1, jnp.int32)
        .at[rows]
        .max(slot_ids, mode="drop")  # drop: padding rows point out of bounds
    )
    win_of_slot = winner[jnp.where(valid, idx_flat, 0)]
    is_winner = valid & (win_of_slot == slot_ids)
    return jnp.where(is_winner, idx_flat, num_rows).astype(jnp.int32)


def _chain_kernel(dest_ref, base_ref, blocks_ref, o_ref):
    # dest redirection already resolved winners host/XLA-side: every grid
    # step writes its block unconditionally (losers land on the trash row)
    del dest_ref, base_ref
    o_ref[...] = blocks_ref[...]


def _chain_call(
    base: jnp.ndarray, blocks: jnp.ndarray, idx: jnp.ndarray, interpret: bool
) -> jnp.ndarray:
    """One fused scatter over ``(num_rows + 1)`` rows (last row = trash)."""
    nb = base.shape[0]
    s = idx.shape[0]
    dest = _fold_dest(idx, nb)
    base_p = jnp.concatenate([base, jnp.zeros((1, 8, 128), jnp.int32)], axis=0)

    def dest_row(i, dest_ref):
        return (dest_ref[i], 0, 0)

    out = pl.pallas_call(
        _chain_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(s,),
            in_specs=[
                pl.BlockSpec((1, 8, 128), dest_row),  # base tile (aliased out)
                pl.BlockSpec((1, 8, 128), lambda i, dest_ref: (i, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 8, 128), dest_row),
        ),
        out_shape=jax.ShapeDtypeStruct(base_p.shape, base_p.dtype),
        input_output_aliases={1: 0},  # alias `base_p` (arg after prefetch)
        interpret=interpret,
    )(dest, base_p, blocks)
    return out[:nb]


@functools.partial(jax.jit, static_argnames=("interpret",))
def chain_delta_apply(
    base: jnp.ndarray,
    blocks: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """Apply a whole K-step sparse-delta chain to one blocked leaf.

    base   : (num_blocks, 8, 128) int32
    blocks : (K, capacity, 8, 128) or flat (S, 8, 128) int32 packed content
    idx    : (K, capacity) or flat (S,) int32 block rows, -1 = padding;
             flat order IS chain order (step 0 first) — later slots win.

    Bit-identical to folding ``sparse_delta_apply`` over the K steps, in one
    jitted dispatch.
    """
    assert base.dtype == jnp.int32 and base.shape[1:] == (8, 128)
    idx = idx.reshape(-1)
    blocks = blocks.reshape(-1, 8, 128)
    assert blocks.dtype == jnp.int32 and idx.shape[0] == blocks.shape[0]
    if idx.shape[0] == 0:
        return base
    return _chain_call(base, blocks, idx, interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chain_delta_apply_batched(
    bases: jnp.ndarray,
    blocks: jnp.ndarray,
    idx: jnp.ndarray,
    *,
    interpret: bool = PALLAS_INTERPRET,
) -> jnp.ndarray:
    """Apply L independent delta chains to L same-sized leaves in ONE launch.

    bases  : (L, num_blocks, 8, 128) int32
    blocks : (L, S, 8, 128) int32 — each leaf's chain flattened+padded to S
    idx    : (L, S) int32 block rows within the leaf, -1 = padding

    Leaves' rows are offset into one concatenated row space (rows are
    disjoint across leaves, so the per-row chain order is preserved) and
    share a single trash row.
    """
    l, nb = bases.shape[0], bases.shape[1]
    s = idx.shape[1]
    assert blocks.shape[:2] == (l, s)
    if s == 0 or l == 0:
        return bases
    offs = (jnp.arange(l, dtype=jnp.int32) * nb)[:, None]
    idx_flat = jnp.where(idx >= 0, idx + offs, -1).reshape(-1)
    out = _chain_call(
        bases.reshape(l * nb, 8, 128), blocks.reshape(l * s, 8, 128),
        idx_flat, interpret,
    )
    return out.reshape(l, nb, 8, 128)
