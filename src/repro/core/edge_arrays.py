"""Flat, array-native edge store for version graphs.

``EdgeArrays`` is the canonical in-memory representation of the augmented
graph ``G`` (paper §2.2): four contiguous NumPy arrays ``src`` / ``dst`` /
``delta`` / ``phi`` holding one edge per slot, plus CSR-style offsets for
O(1) row slicing in both directions.  Vertex ids are ``0..n`` where ``0`` is
the dummy root; edges out of ``0`` are materializations (``Δ_ii``/``Φ_ii``).

Layout
------
* edges are sorted by ``(src, dst)`` — each out-row is a contiguous slice
  ``[row_ptr[u], row_ptr[u + 1])`` with ``dst`` ascending, so point lookups
  are a binary search and whole-row relaxations are single masked array ops;
* ``rperm`` permutes edge ids into ``dst``-grouped order with
  ``rrow_ptr`` as the reverse-CSR offsets (in-edges of ``v`` are
  ``rperm[rrow_ptr[v]:rrow_ptr[v + 1]]``);
* ``key = src * (n + 2) + dst`` is the sorted composite key used by the
  vectorized batch lookup (``lookup_many``).

Duplicate ``(src, dst)`` pairs passed to :meth:`from_edges` keep the *last*
occurrence — matching the overwrite semantics of the old dict-of-dicts
adjacency.  The arrays convert losslessly to JAX via :meth:`to_jax` for
jitted solver inner loops (ROADMAP follow-on).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class EdgeArrays:
    """Immutable flat edge store; build with :meth:`from_edges`."""

    n: int                  # number of versions; vertex ids are 0..n
    src: np.ndarray         # int64 [m], sorted by (src, dst)
    dst: np.ndarray         # int64 [m]
    delta: np.ndarray       # float64 [m], storage bytes Δ
    phi: np.ndarray         # float64 [m], recreation cost Φ
    row_ptr: np.ndarray     # int64 [n + 2], CSR offsets over src
    rrow_ptr: np.ndarray    # int64 [n + 2], CSR offsets over dst
    rperm: np.ndarray       # int64 [m], edge ids grouped by dst
    key: np.ndarray         # int64 [m] = src * (n + 2) + dst, ascending

    # ------------------------------------------------------------------ build
    @classmethod
    def from_edges(
        cls,
        n: int,
        src: np.ndarray,
        dst: np.ndarray,
        delta: np.ndarray,
        phi: np.ndarray,
    ) -> "EdgeArrays":
        """Canonicalize raw edge buffers: sort by ``(src, dst)``, dedup
        keeping the last occurrence of each pair, and build both CSRs."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        delta = np.asarray(delta, dtype=np.float64)
        phi = np.asarray(phi, dtype=np.float64)
        m = src.shape[0]
        stride = n + 2
        if m:
            composite = src * stride + dst
            # stable sort by composite key; within equal keys insertion order
            # is preserved, so the *last* element of each run is the latest
            # write — the dict-overwrite semantics of the old adjacency.
            order = np.argsort(composite, kind="stable")
            composite = composite[order]
            last = np.ones(m, dtype=bool)
            last[:-1] = composite[1:] != composite[:-1]
            keep = order[last]
            src, dst = src[keep], dst[keep]
            delta, phi = delta[keep], phi[keep]
            key = composite[last]
        else:
            key = np.empty(0, dtype=np.int64)
        row_ptr = np.searchsorted(src, np.arange(stride, dtype=np.int64))
        rperm = np.lexsort((src, dst))
        rrow_ptr = np.searchsorted(dst[rperm], np.arange(stride, dtype=np.int64))
        return cls(
            n=n, src=src, dst=dst, delta=delta, phi=phi,
            row_ptr=row_ptr.astype(np.int64),
            rrow_ptr=rrow_ptr.astype(np.int64),
            rperm=rperm.astype(np.int64),
            key=key,
        )

    # ------------------------------------------------------------------ shape
    @property
    def m(self) -> int:
        """Number of (deduplicated) edges."""
        return int(self.src.shape[0])

    # ----------------------------------------------------------------- access
    def out_range(self, u: int) -> Tuple[int, int]:
        """Edge-id range ``[s, e)`` of ``u``'s out-edges."""
        return int(self.row_ptr[u]), int(self.row_ptr[u + 1])

    def out_degree(self, u: int) -> int:
        return int(self.row_ptr[u + 1] - self.row_ptr[u])

    def in_edge_ids(self, v: int) -> np.ndarray:
        """Edge ids of ``v``'s in-edges (ascending ``src``)."""
        return self.rperm[self.rrow_ptr[v]:self.rrow_ptr[v + 1]]

    def lookup(self, i: int, j: int) -> int:
        """Edge id of ``(i, j)`` or ``-1`` — binary search within row ``i``."""
        s, e = int(self.row_ptr[i]), int(self.row_ptr[i + 1])
        k = s + int(np.searchsorted(self.dst[s:e], j))
        if k < e and self.dst[k] == j:
            return k
        return -1

    def lookup_many(self, src_ids: np.ndarray, dst_ids: np.ndarray) -> np.ndarray:
        """Vectorized edge-id lookup; ``-1`` marks unrevealed pairs."""
        src_ids = np.asarray(src_ids, dtype=np.int64)
        dst_ids = np.asarray(dst_ids, dtype=np.int64)
        q = src_ids * (self.n + 2) + dst_ids
        if self.m == 0:
            return np.full(q.shape, -1, dtype=np.int64)
        pos = np.minimum(np.searchsorted(self.key, q), self.m - 1)
        out = np.where(self.key[pos] == q, pos, np.int64(-1))
        return out.astype(np.int64)

    # ---------------------------------------------------------------- exports
    def iter_edges(self) -> Iterator[Tuple[int, int, float, float]]:
        for e in range(self.m):
            yield (
                int(self.src[e]), int(self.dst[e]),
                float(self.delta[e]), float(self.phi[e]),
            )

    def to_jax(self) -> Dict[str, "object"]:
        """Device arrays for jitted solver kernels (lazy jax import)."""
        import jax.numpy as jnp

        return {
            "src": jnp.asarray(self.src),
            "dst": jnp.asarray(self.dst),
            "delta": jnp.asarray(self.delta),
            "phi": jnp.asarray(self.phi),
            "row_ptr": jnp.asarray(self.row_ptr),
            "rrow_ptr": jnp.asarray(self.rrow_ptr),
            "rperm": jnp.asarray(self.rperm),
        }
