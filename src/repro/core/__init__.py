"""Paper core: dataset-versioning storage/recreation tradeoff.

Implements "Principles of Dataset Versioning: Exploring the Recreation/
Storage Tradeoff" (Bhattacherjee et al., 2015): version graphs, the Δ/Φ cost
matrices, the six optimization problems, and the LMG / MP / LAST / GitH /
MCA / SPT / exact solvers.
"""

from .problems import (
    SOLVERS,
    ConstraintViolation,
    optimize,
    run_solver,
    spec_from_solver,
    solve_problem1,
    solve_problem2,
    solve_problem3,
    solve_problem4,
    solve_problem5,
    solve_problem6,
)
from .spec import Constraint, Objective, OptimizeResult, OptimizeSpec
from .solvers.exact import ExactResult, exact_min_storage
from .solvers.gith import git_heuristic
from .solvers.last import last_tree
from .solvers.lmg import local_move_greedy, minimize_storage_sum_recreation
from .solvers.mp import InfeasibleError, min_max_recreation_under_budget, modified_prim
from .solvers.mst import minimum_storage_tree
from .solvers.spt import dijkstra, dijkstra_arrays, shortest_path_tree
from .edge_arrays import EdgeArrays
from .synthetic import (
    SyntheticWorkload,
    WorkloadSpec,
    dc_like,
    generate,
    generate_flat,
    lc_like,
    zipf_weights,
)
from .version_graph import EdgeCost, StorageSolution, VersionGraph

__all__ = [
    "VersionGraph",
    "StorageSolution",
    "EdgeCost",
    "EdgeArrays",
    "dijkstra_arrays",
    "generate_flat",
    "minimum_storage_tree",
    "shortest_path_tree",
    "dijkstra",
    "local_move_greedy",
    "minimize_storage_sum_recreation",
    "modified_prim",
    "min_max_recreation_under_budget",
    "InfeasibleError",
    "last_tree",
    "git_heuristic",
    "exact_min_storage",
    "ExactResult",
    "solve_problem1",
    "solve_problem2",
    "solve_problem3",
    "solve_problem4",
    "solve_problem5",
    "solve_problem6",
    "SOLVERS",
    "Objective",
    "Constraint",
    "OptimizeSpec",
    "OptimizeResult",
    "optimize",
    "run_solver",
    "spec_from_solver",
    "ConstraintViolation",
    "WorkloadSpec",
    "SyntheticWorkload",
    "generate",
    "dc_like",
    "lc_like",
    "zipf_weights",
]
