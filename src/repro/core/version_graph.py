"""Version graphs and storage/recreation cost matrices (paper §2.1).

A :class:`VersionGraph` holds ``n`` versions ``V_1..V_n`` plus the dummy
source ``V_0`` (index 0).  Edges carry ``(delta, phi)`` pairs:

* edge ``(0, i)``  — materialization:  ``delta = Δ_ii`` (full storage bytes),
  ``phi = Φ_ii`` (full retrieval cost);
* edge ``(i, j)``, ``i != 0`` — delta storage: ``delta = Δ_ij`` bytes of the
  diff recreating ``V_j`` from ``V_i``, ``phi = Φ_ij`` cost of applying it.

The matrices are *sparse*: entries never revealed (paper's "—") are simply
absent.  ``directed=False`` means every revealed off-diagonal entry is usable
in both directions (symmetric deltas, paper Scenario 1).

Representation
--------------
``VersionGraph`` is a thin facade over :class:`~repro.core.edge_arrays.EdgeArrays`:
mutations (``set_delta`` / ``set_materialization`` / ``add_edges_bulk``)
append to builder buffers; the first query finalizes them into flat
``src``/``dst``/``delta``/``phi`` arrays with CSR offsets (cached until the
next mutation).  Per-edge accessors (``cost``, ``out_edges``...) are kept for
compatibility and small-graph code; the vectorized solvers work on
``arrays()`` directly.  Re-setting an existing ``(i, j)`` pair overwrites it,
exactly like the old dict-of-dicts adjacency.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from .edge_arrays import EdgeArrays

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class EdgeCost:
    """Storage bytes and recreation cost of one edge of ``G``."""

    delta: float
    phi: float


class VersionGraph:
    """The augmented graph ``G`` of paper §2.2 (versions + dummy root)."""

    def __init__(self, n_versions: int, *, directed: bool = True) -> None:
        if n_versions <= 0:
            raise ValueError("need at least one version")
        self.n = n_versions
        self.directed = directed
        # builder buffers: scalar appends + bulk numpy chunks
        self._pend: List[Tuple[int, int, float, float]] = []
        self._chunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
        self._ea: Optional[EdgeArrays] = None

    # ------------------------------------------------------------------ build
    def set_materialization(self, i: int, delta: float, phi: float) -> None:
        """Record ``Δ_ii``/``Φ_ii`` (edge from the dummy root)."""
        self._check_version(i)
        self._put(0, i, float(delta), float(phi))

    def set_delta(self, i: int, j: int, delta: float, phi: float) -> None:
        """Record ``Δ_ij``/``Φ_ij`` — recreate ``V_j`` from ``V_i``."""
        self._check_version(i)
        self._check_version(j)
        if i == j:
            raise ValueError("use set_materialization for the diagonal")
        self._put(i, j, float(delta), float(phi))
        if not self.directed:
            self._put(j, i, float(delta), float(phi))

    def add_edges_bulk(
        self,
        src: np.ndarray,
        dst: np.ndarray,
        delta: np.ndarray,
        phi: np.ndarray,
        *,
        mirror: bool = False,
    ) -> None:
        """Append a whole batch of edges without per-edge Python overhead.

        ``src == 0`` rows are materializations.  With ``mirror=True`` every
        off-root edge is also added reversed (undirected instances).  Ids are
        range-checked vectorized; the ``i == j`` diagonal is rejected like
        ``set_delta``.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        delta = np.asarray(delta, dtype=np.float64)
        phi = np.asarray(phi, dtype=np.float64)
        if not (src.shape == dst.shape == delta.shape == phi.shape):
            raise ValueError("bulk edge arrays must share one shape")
        if src.size == 0:
            return
        if (src < 0).any() or (src > self.n).any():
            raise ValueError("bulk src ids out of range")
        if (dst < 1).any() or (dst > self.n).any():
            raise ValueError(f"bulk dst ids out of range 1..{self.n}")
        if (src == dst).any():
            raise ValueError("use set_materialization for the diagonal")
        self._chunks.append((src, dst, delta, phi))
        if mirror:
            off = src != 0
            if off.any():
                self._chunks.append(
                    (dst[off], src[off], delta[off], phi[off])
                )
        self._ea = None

    def _put(self, i: int, j: int, delta: float, phi: float) -> None:
        self._pend.append((i, j, delta, phi))
        self._ea = None

    def _check_version(self, i: int) -> None:
        if not 1 <= i <= self.n:
            raise ValueError(f"version id {i} out of range 1..{self.n}")

    # ----------------------------------------------------------------- arrays
    def arrays(self) -> EdgeArrays:
        """The flat array-native view; built lazily, cached until mutation."""
        if self._ea is None:
            parts = list(self._chunks)
            if self._pend:
                p = np.asarray(self._pend, dtype=np.float64).reshape(-1, 4)
                parts.append(
                    (
                        p[:, 0].astype(np.int64),
                        p[:, 1].astype(np.int64),
                        p[:, 2],
                        p[:, 3],
                    )
                )
            if parts:
                src = np.concatenate([c[0] for c in parts])
                dst = np.concatenate([c[1] for c in parts])
                delta = np.concatenate([c[2] for c in parts])
                phi = np.concatenate([c[3] for c in parts])
            else:
                src = np.empty(0, dtype=np.int64)
                dst = np.empty(0, dtype=np.int64)
                delta = np.empty(0, dtype=np.float64)
                phi = np.empty(0, dtype=np.float64)
            self._ea = EdgeArrays.from_edges(self.n, src, dst, delta, phi)
        return self._ea

    # ------------------------------------------------------------------ query
    def cost(self, i: int, j: int) -> Optional[EdgeCost]:
        ea = self.arrays()
        e = ea.lookup(i, j)
        if e < 0:
            return None
        return EdgeCost(float(ea.delta[e]), float(ea.phi[e]))

    def materialization_cost(self, i: int) -> Optional[EdgeCost]:
        return self.cost(0, i)

    def out_edges(self, i: int) -> Iterator[Tuple[int, EdgeCost]]:
        ea = self.arrays()
        s, e = ea.out_range(i)
        for k in range(s, e):
            yield int(ea.dst[k]), EdgeCost(float(ea.delta[k]), float(ea.phi[k]))

    def in_edges(self, j: int) -> Iterator[Tuple[int, EdgeCost]]:
        ea = self.arrays()
        for k in ea.in_edge_ids(j):
            yield int(ea.src[k]), EdgeCost(float(ea.delta[k]), float(ea.phi[k]))

    def edges(self) -> Iterator[Tuple[int, int, EdgeCost]]:
        ea = self.arrays()
        for k in range(ea.m):
            yield (
                int(ea.src[k]),
                int(ea.dst[k]),
                EdgeCost(float(ea.delta[k]), float(ea.phi[k])),
            )

    @property
    def n_edges(self) -> int:
        return self.arrays().m

    def vertices(self) -> range:
        """All vertex ids including the dummy root 0."""
        return range(self.n + 1)

    def versions(self) -> range:
        return range(1, self.n + 1)

    def has_all_materializations(self) -> bool:
        ea = self.arrays()
        s, e = ea.out_range(0)
        return e - s == self.n  # root row is deduped and dst-unique

    # -------------------------------------------------------------- validation
    def check_triangle_inequality(self, *, tol: float = 1e-9) -> List[str]:
        """Best-effort check of the paper §3 triangle inequalities on revealed
        entries (only meaningful for symmetric Δ=Φ instances).  Returns a list
        of human-readable violations (empty = consistent)."""
        bad: List[str] = []
        ea = self.arrays()
        s0, e0 = ea.out_range(0)
        diag = {int(ea.dst[k]): float(ea.delta[k]) for k in range(s0, e0)}
        for p, q, cpq in self.edges():
            if p == 0:
                continue
            # |Δpp - Δpq| <= Δqq <= Δpp + Δpq
            dp, dq = diag.get(p), diag.get(q)
            if dp is not None and dq is not None:
                if not (abs(dp - cpq.delta) - tol <= dq <= dp + cpq.delta + tol):
                    bad.append(f"diag triangle violated at ({p},{q})")
            for w, cqw in self.out_edges(q):
                if w in (0, p):
                    continue
                cpw = self.cost(p, w)
                if cpw is None:
                    continue
                if cpw.delta > cpq.delta + cqw.delta + tol:
                    bad.append(f"edge triangle violated at ({p},{q},{w})")
        return bad


# ---------------------------------------------------------------------- trees
@dataclasses.dataclass
class StorageSolution:
    """A storage graph: ``parent[i]`` for every version ``i`` (paper's P).

    ``parent[i] == 0`` means ``V_i`` is materialized; otherwise ``V_i`` is
    stored as a delta from ``V_{parent[i]}``.  Lemma 1: any valid solution is a
    spanning tree of ``G`` rooted at the dummy vertex 0.
    """

    parent: Dict[int, int]
    graph: VersionGraph

    # -- structure ---------------------------------------------------------
    def validate(self) -> None:
        g = self.graph
        if set(self.parent) != set(g.versions()):
            raise ValueError("solution must assign a parent to every version")
        for i, p in self.parent.items():
            if p != 0 and g.cost(p, i) is None:
                raise ValueError(f"edge ({p},{i}) not revealed in graph")
            if p == 0 and g.materialization_cost(i) is None:
                raise ValueError(f"no materialization cost for {i}")
        # acyclicity / reachability from root
        seen = set()
        for i in g.versions():
            path = []
            v = i
            while v != 0 and v not in seen:
                path.append(v)
                v = self.parent[v]
                if len(path) > g.n:
                    raise ValueError("cycle detected in storage solution")
            seen.update(path)

    def children(self) -> Dict[int, List[int]]:
        ch: Dict[int, List[int]] = {v: [] for v in self.graph.vertices()}
        for i, p in self.parent.items():
            ch[p].append(i)
        return ch

    def depth(self, i: int) -> int:
        d = 0
        while i != 0:
            i = self.parent[i]
            d += 1
        return d

    # -- costs (paper §2.1) --------------------------------------------------
    def edge_cost(self, i: int) -> EdgeCost:
        p = self.parent[i]
        c = self.graph.materialization_cost(i) if p == 0 else self.graph.cost(p, i)
        assert c is not None
        return c

    def _edge_ids(self) -> np.ndarray:
        ea = self.graph.arrays()
        n = self.graph.n
        pa = np.zeros(n + 1, dtype=np.int64)
        for i, p in self.parent.items():
            pa[i] = p
        vs = np.arange(1, n + 1, dtype=np.int64)
        eid = ea.lookup_many(pa[vs], vs)
        assert (eid >= 0).all(), "solution edge not revealed in graph"
        return eid

    def storage_cost(self) -> float:
        """Total storage C = Σ Δ over edges of the storage tree."""
        ea = self.graph.arrays()
        # sequential left-fold, matching a per-edge Python summation exactly
        total = 0.0
        for x in ea.delta[self._edge_ids()].tolist():
            total += x
        return total

    def recreation_costs(self) -> Dict[int, float]:
        """R_i for every version — Φ summed along the path from the root.

        Iterative (explicit chain walk with memoization) so deep delta chains
        never hit the interpreter recursion limit.
        """
        memo: Dict[int, float] = {0: 0.0}
        for i in self.graph.versions():
            chain: List[int] = []
            v = i
            while v not in memo:
                chain.append(v)
                v = self.parent[v]
            r = memo[v]
            for x in reversed(chain):
                r = r + self.edge_cost(x).phi
                memo[x] = r
        return {i: memo[i] for i in self.graph.versions()}

    def sum_recreation(self, weights: Optional[Dict[int, float]] = None) -> float:
        rc = self.recreation_costs()
        if weights is None:
            return sum(rc.values())
        return sum(rc[i] * weights.get(i, 0.0) for i in rc)

    def max_recreation(self) -> float:
        return max(self.recreation_costs().values())

    def materialized(self) -> List[int]:
        return sorted(i for i, p in self.parent.items() if p == 0)

    def edges(self) -> Iterable[Tuple[int, int]]:
        return ((p, i) for i, p in self.parent.items())

    def summary(self) -> str:
        return (
            f"storage={self.storage_cost():.6g} "
            f"sum_rec={self.sum_recreation():.6g} "
            f"max_rec={self.max_recreation():.6g} "
            f"materialized={len(self.materialized())}/{self.graph.n}"
        )
