"""Version graphs and storage/recreation cost matrices (paper §2.1).

A :class:`VersionGraph` holds ``n`` versions ``V_1..V_n`` plus the dummy
source ``V_0`` (index 0).  Edges carry ``(delta, phi)`` pairs:

* edge ``(0, i)``  — materialization:  ``delta = Δ_ii`` (full storage bytes),
  ``phi = Φ_ii`` (full retrieval cost);
* edge ``(i, j)``, ``i != 0`` — delta storage: ``delta = Δ_ij`` bytes of the
  diff recreating ``V_j`` from ``V_i``, ``phi = Φ_ij`` cost of applying it.

The matrices are *sparse*: entries never revealed (paper's "—") are simply
absent.  ``directed=False`` means every revealed off-diagonal entry is usable
in both directions (symmetric deltas, paper Scenario 1).
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, Iterable, Iterator, List, Optional, Tuple

Edge = Tuple[int, int]


@dataclasses.dataclass(frozen=True)
class EdgeCost:
    """Storage bytes and recreation cost of one edge of ``G``."""

    delta: float
    phi: float


class VersionGraph:
    """The augmented graph ``G`` of paper §2.2 (versions + dummy root)."""

    def __init__(self, n_versions: int, *, directed: bool = True) -> None:
        if n_versions <= 0:
            raise ValueError("need at least one version")
        self.n = n_versions
        self.directed = directed
        # adjacency: src -> {dst: EdgeCost}; vertex ids 0..n (0 = dummy root)
        self._adj: List[Dict[int, EdgeCost]] = [dict() for _ in range(n_versions + 1)]
        self._radj: List[Dict[int, EdgeCost]] = [dict() for _ in range(n_versions + 1)]

    # ------------------------------------------------------------------ build
    def set_materialization(self, i: int, delta: float, phi: float) -> None:
        """Record ``Δ_ii``/``Φ_ii`` (edge from the dummy root)."""
        self._check_version(i)
        self._put(0, i, EdgeCost(float(delta), float(phi)))

    def set_delta(self, i: int, j: int, delta: float, phi: float) -> None:
        """Record ``Δ_ij``/``Φ_ij`` — recreate ``V_j`` from ``V_i``."""
        self._check_version(i)
        self._check_version(j)
        if i == j:
            raise ValueError("use set_materialization for the diagonal")
        self._put(i, j, EdgeCost(float(delta), float(phi)))
        if not self.directed:
            self._put(j, i, EdgeCost(float(delta), float(phi)))

    def _put(self, i: int, j: int, c: EdgeCost) -> None:
        self._adj[i][j] = c
        self._radj[j][i] = c

    def _check_version(self, i: int) -> None:
        if not 1 <= i <= self.n:
            raise ValueError(f"version id {i} out of range 1..{self.n}")

    # ------------------------------------------------------------------ query
    def cost(self, i: int, j: int) -> Optional[EdgeCost]:
        return self._adj[i].get(j)

    def materialization_cost(self, i: int) -> Optional[EdgeCost]:
        return self._adj[0].get(i)

    def out_edges(self, i: int) -> Iterator[Tuple[int, EdgeCost]]:
        return iter(self._adj[i].items())

    def in_edges(self, j: int) -> Iterator[Tuple[int, EdgeCost]]:
        return iter(self._radj[j].items())

    def edges(self) -> Iterator[Tuple[int, int, EdgeCost]]:
        for i, nbrs in enumerate(self._adj):
            for j, c in nbrs.items():
                yield i, j, c

    @property
    def n_edges(self) -> int:
        return sum(len(nbrs) for nbrs in self._adj)

    def vertices(self) -> range:
        """All vertex ids including the dummy root 0."""
        return range(self.n + 1)

    def versions(self) -> range:
        return range(1, self.n + 1)

    def has_all_materializations(self) -> bool:
        return all(i in self._adj[0] for i in self.versions())

    # -------------------------------------------------------------- validation
    def check_triangle_inequality(self, *, tol: float = 1e-9) -> List[str]:
        """Best-effort check of the paper §3 triangle inequalities on revealed
        entries (only meaningful for symmetric Δ=Φ instances).  Returns a list
        of human-readable violations (empty = consistent)."""
        bad: List[str] = []
        diag = {i: c.delta for i, c in self._adj[0].items()}
        for p, q, cpq in self.edges():
            if p == 0:
                continue
            # |Δpp - Δpq| <= Δqq <= Δpp + Δpq
            dp, dq = diag.get(p), diag.get(q)
            if dp is not None and dq is not None:
                if not (abs(dp - cpq.delta) - tol <= dq <= dp + cpq.delta + tol):
                    bad.append(f"diag triangle violated at ({p},{q})")
            for w, cqw in self.out_edges(q):
                if w in (0, p):
                    continue
                cpw = self.cost(p, w)
                if cpw is None:
                    continue
                if cpw.delta > cpq.delta + cqw.delta + tol:
                    bad.append(f"edge triangle violated at ({p},{q},{w})")
        return bad


# ---------------------------------------------------------------------- trees
@dataclasses.dataclass
class StorageSolution:
    """A storage graph: ``parent[i]`` for every version ``i`` (paper's P).

    ``parent[i] == 0`` means ``V_i`` is materialized; otherwise ``V_i`` is
    stored as a delta from ``V_{parent[i]}``.  Lemma 1: any valid solution is a
    spanning tree of ``G`` rooted at the dummy vertex 0.
    """

    parent: Dict[int, int]
    graph: VersionGraph

    # -- structure ---------------------------------------------------------
    def validate(self) -> None:
        g = self.graph
        if set(self.parent) != set(g.versions()):
            raise ValueError("solution must assign a parent to every version")
        for i, p in self.parent.items():
            if p != 0 and g.cost(p, i) is None:
                raise ValueError(f"edge ({p},{i}) not revealed in graph")
            if p == 0 and g.materialization_cost(i) is None:
                raise ValueError(f"no materialization cost for {i}")
        # acyclicity / reachability from root
        seen = set()
        for i in g.versions():
            path = []
            v = i
            while v != 0 and v not in seen:
                path.append(v)
                v = self.parent[v]
                if len(path) > g.n:
                    raise ValueError("cycle detected in storage solution")
            seen.update(path)

    def children(self) -> Dict[int, List[int]]:
        ch: Dict[int, List[int]] = {v: [] for v in self.graph.vertices()}
        for i, p in self.parent.items():
            ch[p].append(i)
        return ch

    def depth(self, i: int) -> int:
        d = 0
        while i != 0:
            i = self.parent[i]
            d += 1
        return d

    # -- costs (paper §2.1) --------------------------------------------------
    def edge_cost(self, i: int) -> EdgeCost:
        p = self.parent[i]
        c = self.graph.materialization_cost(i) if p == 0 else self.graph.cost(p, i)
        assert c is not None
        return c

    def storage_cost(self) -> float:
        """Total storage C = Σ Δ over edges of the storage tree."""
        return sum(self.edge_cost(i).delta for i in self.graph.versions())

    def recreation_costs(self) -> Dict[int, float]:
        """R_i for every version — Φ summed along the path from the root."""
        memo: Dict[int, float] = {0: 0.0}

        def rec(i: int) -> float:
            if i not in memo:
                memo[i] = rec(self.parent[i]) + self.edge_cost(i).phi
            return memo[i]

        return {i: rec(i) for i in self.graph.versions()}

    def sum_recreation(self, weights: Optional[Dict[int, float]] = None) -> float:
        rc = self.recreation_costs()
        if weights is None:
            return sum(rc.values())
        return sum(rc[i] * weights.get(i, 0.0) for i in rc)

    def max_recreation(self) -> float:
        return max(self.recreation_costs().values())

    def materialized(self) -> List[int]:
        return sorted(i for i, p in self.parent.items() if p == 0)

    def edges(self) -> Iterable[Tuple[int, int]]:
        return ((p, i) for i, p in self.parent.items())

    def summary(self) -> str:
        return (
            f"storage={self.storage_cost():.6g} "
            f"sum_rec={self.sum_recreation():.6g} "
            f"max_rec={self.max_recreation():.6g} "
            f"materialized={len(self.materialized())}/{self.graph.n}"
        )
