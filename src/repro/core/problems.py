"""The paper's optimization problems behind one declarative entry point.

§2.1 poses six problems, but they are all points on a single
(objective, constraint) grid — which is exactly how the public API now
exposes them: build an :class:`~repro.core.spec.OptimizeSpec` and call
:func:`optimize`.

::

    spec                                              problem  solver
    ------------------------------------------------  -------  --------------
    OptimizeSpec.problem(1)
      = min storage                                   1        MST / MCA
    OptimizeSpec.problem(2)
      = min every_recreation                          2        SPT
    OptimizeSpec.problem(3, beta=B)
      = min sum_recreation  s.t. storage <= B         3        LMG
    OptimizeSpec.problem(4, beta=B)
      = min max_recreation  s.t. storage <= B         4        MP + bisection
    OptimizeSpec.problem(5, theta=T)
      = min storage  s.t. sum_recreation <= T         5        LMG + bin search
    OptimizeSpec.problem(6, theta=T)
      = min storage  s.t. max_recreation <= T         6        MP

Specs may be built from parts too —
``OptimizeSpec(Objective.sum_recreation(), (Constraint.storage_at_most(B),))``
is the same grid point as ``OptimizeSpec.problem(3, beta=B)``.  ``optimize``
maps the spec onto the right problem, runs the solver (``backend="numpy"`` or
``"jax"``, ``pallas=True`` for the Pallas reduction kernels), transparently
falls back to the bit-identical NumPy path where the jitted formulation does
not apply (directed MCA cycle contraction; degree-skew instances whose dense
padded layout would OOM), validates every constraint on the returned tree,
and wraps the :class:`~repro.core.version_graph.StorageSolution` in an
:class:`~repro.core.spec.OptimizeResult` carrying the problem id, solver and
backend actually used, objective values, constraint slack, and wall time.

``workload={vid: weight}`` turns the recreation objective into
``sum_i w_i R_i`` (paper Fig. 16); only the LMG-based grid points (Problems
3 and 5) honor it, and every other point refuses loudly instead of silently
dropping the weights.

The legacy surfaces remain: ``solve_problem1..6`` are the positional
entry points (bit-identical to ``optimize`` on the corresponding spec — a
property test enforces this), and the ``SOLVERS`` registry / ``run_solver``
dispatch by name with validated kwargs — unknown solver names and
unsupported kwargs raise ``ValueError`` naming the offender and the
accepted set.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, FrozenSet, Optional, Tuple

from ..obs.tracer import start as _trace_start
from .solvers import CONSTRAINT_TOL, BackendUnsupported
from .solvers.gith import git_heuristic
from .solvers.last import last_tree
from .solvers.lmg import local_move_greedy, minimize_storage_sum_recreation
from .solvers.mp import min_max_recreation_under_budget, modified_prim
from .solvers.mst import minimum_storage_tree
from .solvers.spt import shortest_path_tree
from .spec import OptimizeResult, OptimizeSpec
from .version_graph import StorageSolution, VersionGraph

__all__ = [
    "optimize",
    "ConstraintViolation",
    "run_solver",
    "spec_from_solver",
    "solve_problem1",
    "solve_problem2",
    "solve_problem3",
    "solve_problem4",
    "solve_problem5",
    "solve_problem6",
    "SOLVERS",
]


class ConstraintViolation(RuntimeError):
    """A solver returned a tree violating the spec's constraints — a solver
    bug by definition (``optimize`` refuses to return such a solution)."""


# --------------------------------------------------------------- legacy API
def solve_problem1(g: VersionGraph) -> StorageSolution:
    """Minimize total storage; recreation costs merely finite."""
    return minimum_storage_tree(g)


def solve_problem2(g: VersionGraph) -> StorageSolution:
    """Minimize every R_i (the SPT minimizes all of them simultaneously)."""
    return shortest_path_tree(g)


def solve_problem3(
    g: VersionGraph, beta: float, *, weights: Optional[Dict[int, float]] = None
) -> StorageSolution:
    """Minimize Σ R_i subject to C ≤ β (LMG; workload-aware via weights)."""
    return local_move_greedy(g, beta, weights=weights)


def solve_problem4(g: VersionGraph, beta: float) -> StorageSolution:
    """Minimize max R_i subject to C ≤ β."""
    return min_max_recreation_under_budget(g, beta)


def solve_problem5(
    g: VersionGraph, theta: float, *, weights: Optional[Dict[int, float]] = None
) -> StorageSolution:
    """Minimize C subject to Σ R_i ≤ θ."""
    return minimize_storage_sum_recreation(g, theta, weights=weights)


def solve_problem6(g: VersionGraph, theta: float) -> StorageSolution:
    """Minimize C subject to max R_i ≤ θ."""
    return modified_prim(g, theta)


# ---------------------------------------------------------- named dispatch
# per-solver contract: callable, required kwargs, accepted kwargs
_SOLVER_TABLE: Dict[str, Tuple[Callable, FrozenSet[str], FrozenSet[str]]] = {
    "mca": (
        minimum_storage_tree,
        frozenset(),
        frozenset({"backend", "pallas"}),
    ),
    "spt": (
        shortest_path_tree,
        frozenset(),
        frozenset({"weight", "backend", "pallas"}),
    ),
    "lmg": (
        lambda g, budget, **kw: local_move_greedy(g, budget, **kw),
        frozenset({"budget"}),
        frozenset({"budget", "weights", "base", "spt", "backend", "pallas"}),
    ),
    "mp": (
        lambda g, theta, **kw: modified_prim(g, theta, **kw),
        frozenset({"theta"}),
        frozenset({"theta", "backend", "pallas"}),
    ),
    "last": (
        lambda g, alpha=2.0, **kw: last_tree(g, alpha, **kw),
        frozenset(),
        frozenset({"alpha", "base"}),
    ),
    "gith": (
        git_heuristic,
        frozenset(),
        frozenset({"window", "max_depth"}),
    ),
}


def _validate_solver_kwargs(name: str, kwargs: Dict[str, Any]) -> Callable:
    """Shared validation for ``run_solver`` and the legacy spec shim; returns
    the solver callable.  Unknown names and unsupported/missing kwargs raise
    ``ValueError`` naming the solver, the offending kwarg, and the accepted
    set — never a bare ``KeyError``/``TypeError``."""
    entry = _SOLVER_TABLE.get(name)
    if entry is None:
        raise ValueError(
            f"unknown solver {name!r}: accepted solvers are "
            f"{sorted(_SOLVER_TABLE)}"
        )
    fn, required, accepted = entry
    unknown = set(kwargs) - accepted
    if unknown:
        raise ValueError(
            f"solver {name!r} does not accept kwarg(s) {sorted(unknown)}; "
            f"accepted: {sorted(accepted)}"
        )
    missing = required - set(kwargs)
    if missing:
        raise ValueError(
            f"solver {name!r} requires kwarg(s) {sorted(missing)}"
        )
    return fn


def run_solver(name: str, g: VersionGraph, **kwargs: Any) -> StorageSolution:
    """Dispatch a solver by name with validated kwargs (see
    :func:`_validate_solver_kwargs` for the failure modes)."""
    return _validate_solver_kwargs(name, kwargs)(g, **kwargs)


class _SolverRegistry(dict):
    """Name -> callable registry whose misses explain themselves."""

    def __missing__(self, key):
        raise ValueError(
            f"unknown solver {key!r}: accepted solvers are {sorted(self)}"
        )


def _make_entry(name: str) -> Callable:
    def call(g: VersionGraph, **kwargs: Any) -> StorageSolution:
        return run_solver(name, g, **kwargs)

    call.__name__ = f"solver_{name}"
    return call


# registry used by benchmarks / the version store's legacy repack shim; each
# entry validates its kwargs (see ``run_solver``) and the registry itself
# turns unknown names into a ValueError listing the accepted set
SOLVERS = _SolverRegistry({name: _make_entry(name) for name in _SOLVER_TABLE})


def spec_from_solver(solver: str, kwargs: Dict[str, Any]) -> OptimizeSpec:
    """Map a legacy ``solver=`` string + kwargs to the equivalent spec.

    This is the deprecated-shim constructor behind
    ``VersionStore.repack("lmg", budget=...)`` and friends; new code should
    build the :class:`OptimizeSpec` directly.
    """
    _validate_solver_kwargs(solver, kwargs)
    kw = dict(kwargs)
    backend = kw.pop("backend", "numpy")
    pallas = kw.pop("pallas", False)
    if solver == "mca":
        return OptimizeSpec.problem(1, backend=backend, pallas=pallas)
    if solver == "spt":
        # Problem 2 is defined over Φ; a delta-weighted SPT is not a grid
        # point, so refuse rather than silently optimize the wrong metric
        if kw.pop("weight", "phi") != "phi":
            raise ValueError(
                "the spec grid's Problem 2 minimizes recreation cost (phi); "
                "for a delta-weighted SPT call shortest_path_tree(weight=...) "
                "directly"
            )
        return OptimizeSpec.problem(2, backend=backend, pallas=pallas)
    if solver == "lmg":
        return OptimizeSpec.problem(
            3, beta=kw.pop("budget"), workload=kw.pop("weights", None),
            backend=backend, pallas=pallas, **kw,
        )
    if solver == "mp":
        return OptimizeSpec.problem(
            6, theta=kw.pop("theta"), backend=backend, pallas=pallas, **kw,
        )
    # balance heuristics ride outside the grid
    return OptimizeSpec.heuristic(solver, backend=backend, pallas=pallas, **kw)


# ------------------------------------------------------------ optimize(...)
# solver options each grid point accepts (beyond backend/pallas, which are
# spec fields)
_PROBLEM_OPTIONS: Dict[int, FrozenSet[str]] = {
    1: frozenset(),
    2: frozenset(),
    3: frozenset({"base", "spt"}),
    4: frozenset({"tol", "max_iters"}),
    5: frozenset({"tol", "max_iters"}),
    6: frozenset(),
}
_HEURISTIC_OPTIONS: Dict[str, FrozenSet[str]] = {
    "last": frozenset({"alpha", "base"}),
    "gith": frozenset({"window", "max_depth"}),
}


def _check_options(opts: Dict[str, Any], accepted: FrozenSet[str], who: str) -> None:
    unknown = set(opts) - accepted
    if unknown:
        raise ValueError(
            f"{who} does not accept option(s) {sorted(unknown)}; "
            f"accepted: {sorted(accepted)}"
        )


def optimize(g: VersionGraph, spec: OptimizeSpec) -> OptimizeResult:
    """Solve the grid point named by ``spec`` on ``g``.

    Returns an :class:`~repro.core.spec.OptimizeResult`; the wrapped
    ``StorageSolution`` is bit-identical to the corresponding legacy
    ``solve_problemN`` call (same tree, same float costs).  Raises
    ``ValueError`` for infeasible bounds (propagated from the solvers) and
    :class:`ConstraintViolation` if a solver ever returned a tree violating
    the spec — the latter is a bug trap, not an expected path.
    """
    if not isinstance(spec, OptimizeSpec):
        raise TypeError(
            f"optimize() takes an OptimizeSpec, got {type(spec).__name__}; "
            f"legacy string solvers go through run_solver()/spec_from_solver()"
        )
    t0 = time.monotonic()
    _sp = _trace_start("core.optimize", n=g.n)
    weights = spec.weights()
    opts = spec.options_dict()
    diagnostics: Dict[str, Any] = {}
    backend_used = spec.backend

    problem = spec.problem_id()
    solver_name = spec.solver_name()
    if problem is None:
        _check_options(opts, _HEURISTIC_OPTIONS[spec.solver], f"solver {spec.solver!r}")
        sol = run_solver(spec.solver, g, **opts)
        backend_used = "numpy"  # heuristics are host-only
        if spec.backend != "numpy":
            diagnostics["backend_fallback"] = (
                f"heuristic solver {spec.solver!r} has no jitted formulation"
            )
    else:
        _check_options(opts, _PROBLEM_OPTIONS[problem], f"Problem {problem}")

        def run(backend: str) -> StorageSolution:
            if problem == 1:
                return minimum_storage_tree(g, backend=backend,
                                            pallas=spec.pallas)
            if problem == 2:
                return shortest_path_tree(g, backend=backend,
                                          pallas=spec.pallas)
            if problem == 3:
                return local_move_greedy(
                    g, spec.bound("storage"), weights=weights,
                    backend=backend, pallas=spec.pallas, **opts,
                )
            if problem == 4:
                return min_max_recreation_under_budget(
                    g, spec.bound("storage"), backend=backend,
                    pallas=spec.pallas, **opts,
                )
            if problem == 5:
                return minimize_storage_sum_recreation(
                    g, spec.bound("sum_recreation"), weights=weights,
                    backend=backend, pallas=spec.pallas, **opts,
                )
            return modified_prim(
                g, spec.bound("max_recreation"), backend=backend,
                pallas=spec.pallas,
            )

        try:
            sol = run(spec.backend)
        except BackendUnsupported as e:
            # the jax backend refuses instances its formulation cannot run
            # (degree skew blowing up the dense padded layout); the NumPy
            # CSR path is bit-identical, so fall back transparently
            diagnostics["backend_fallback"] = str(e)
            backend_used = "numpy"
            sol = run("numpy")
        if problem == 1 and g.directed and spec.backend == "jax":
            # MCA cycle contraction is host-only; minimum_storage_tree took
            # the Edmonds path regardless of the requested backend
            backend_used = "numpy"
            diagnostics.setdefault(
                "backend_fallback",
                "directed Problem 1 uses the host mergeable-heap Edmonds "
                "MCA (near-linear run-heap contraction; cycle contraction "
                "has no jitted formulation)",
            )

    sol.validate()

    # objective values + constraint validation on the *returned* tree
    values: Dict[str, float] = {
        "storage": sol.storage_cost(),
        "sum_recreation": sol.sum_recreation(),
        "max_recreation": sol.max_recreation(),
    }
    if weights is not None:
        values["weighted_sum_recreation"] = sol.sum_recreation(weights)

    def achieved(metric: str) -> float:
        if metric == "sum_recreation" and weights is not None:
            return values["weighted_sum_recreation"]
        return values[metric]

    slack: Dict[str, float] = {}
    for c in spec.constraints:
        got = achieved(c.metric)
        slack[c.metric] = c.bound - got
        tol = CONSTRAINT_TOL + 1e-9 * abs(c.bound)
        if got > c.bound + tol:
            raise ConstraintViolation(
                f"solver {solver_name!r} returned {c.metric}={got!r} above "
                f"the bound {c.bound!r} (slack {slack[c.metric]:.3g}) — "
                f"this is a solver bug"
            )

    obj_metric = spec.objective.metric
    if obj_metric == "every_recreation":
        objective_value = values["sum_recreation"]
    elif obj_metric == "sum_recreation" and weights is not None:
        objective_value = values["weighted_sum_recreation"]
    else:
        objective_value = values[obj_metric]

    if _sp:
        _sp.set(
            problem=problem,
            solver=solver_name,
            backend=spec.backend,
            backend_used=backend_used,
            fallback="backend_fallback" in diagnostics,
            objective=obj_metric,
            objective_value=float(objective_value),
        )
    _sp.end()
    return OptimizeResult(
        solution=sol,
        spec=spec,
        problem=problem,
        solver=solver_name,
        backend_used=backend_used,
        objective_value=objective_value,
        objective_values=values,
        constraint_slack=slack,
        wall_time_s=time.monotonic() - t0,
        diagnostics=diagnostics,
    )
