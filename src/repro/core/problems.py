"""The six optimization problems of paper §2.1, dispatched to solvers.

| Problem | objective            | constraint       | solver                       |
|---------|----------------------|------------------|------------------------------|
| 1       | min C                | R_i < ∞          | MST / MCA                    |
| 2       | min each R_i         | C < ∞            | SPT                          |
| 3       | min Σ R_i            | C ≤ β            | LMG                          |
| 4       | min max R_i          | C ≤ β            | MP + bisection               |
| 5       | min C                | Σ R_i ≤ θ        | LMG + binary search          |
| 6       | min C                | max R_i ≤ θ      | MP                           |
"""

from __future__ import annotations

from typing import Dict, Optional

from .solvers.gith import git_heuristic
from .solvers.last import last_tree
from .solvers.lmg import local_move_greedy, minimize_storage_sum_recreation
from .solvers.mp import min_max_recreation_under_budget, modified_prim
from .solvers.mst import minimum_storage_tree
from .solvers.spt import shortest_path_tree
from .version_graph import StorageSolution, VersionGraph

__all__ = [
    "solve_problem1",
    "solve_problem2",
    "solve_problem3",
    "solve_problem4",
    "solve_problem5",
    "solve_problem6",
    "SOLVERS",
]


def solve_problem1(g: VersionGraph) -> StorageSolution:
    """Minimize total storage; recreation costs merely finite."""
    return minimum_storage_tree(g)


def solve_problem2(g: VersionGraph) -> StorageSolution:
    """Minimize every R_i (the SPT minimizes all of them simultaneously)."""
    return shortest_path_tree(g)


def solve_problem3(
    g: VersionGraph, beta: float, *, weights: Optional[Dict[int, float]] = None
) -> StorageSolution:
    """Minimize Σ R_i subject to C ≤ β (LMG; workload-aware via weights)."""
    return local_move_greedy(g, beta, weights=weights)


def solve_problem4(g: VersionGraph, beta: float) -> StorageSolution:
    """Minimize max R_i subject to C ≤ β."""
    return min_max_recreation_under_budget(g, beta)


def solve_problem5(
    g: VersionGraph, theta: float, *, weights: Optional[Dict[int, float]] = None
) -> StorageSolution:
    """Minimize C subject to Σ R_i ≤ θ."""
    return minimize_storage_sum_recreation(g, theta, weights=weights)


def solve_problem6(g: VersionGraph, theta: float) -> StorageSolution:
    """Minimize C subject to max R_i ≤ θ."""
    return modified_prim(g, theta)


# registry used by benchmarks / the version store's repack policy; the
# array-native solvers take backend="numpy"|"jax" (+ pallas=True to route
# reductions through the Pallas kernels — see core/solvers/__init__.py)
SOLVERS = {
    "mca": lambda g, **kw: minimum_storage_tree(g, **kw),
    "spt": lambda g, **kw: shortest_path_tree(g, **kw),
    "lmg": lambda g, budget, **kw: local_move_greedy(g, budget, **kw),
    "mp": lambda g, theta, **kw: modified_prim(g, theta, **kw),
    "last": lambda g, alpha=2.0, **kw: last_tree(g, alpha),
    "gith": lambda g, window=10, max_depth=50, **kw: git_heuristic(
        g, window=window, max_depth=max_depth
    ),
}
