"""GitH — the Git `repack` heuristic (paper §4.4 and Appendix A).

* versions considered in non-increasing size (Δ_ii) order;
* sliding window of at most ``w`` candidate bases;
* depth bias: candidate base V_l is scored  Δ_{l,i} / (d_max − depth(V_l)),
  so shallow chains are preferred over slightly smaller deltas deep in a
  chain; bases at depth ≥ d_max are ineligible;
* a version with no eligible base in the window (or whose best delta is not
  smaller than materializing) is materialized at depth 0;
* window reshuffle (Appendix A step 3): the chosen base moves to the *end*
  of the window, the new version is appended, and the front is dropped to
  keep the window at ``w``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from ..version_graph import StorageSolution, VersionGraph


def git_heuristic(
    g: VersionGraph,
    *,
    window: int = 10,
    max_depth: int = 50,
) -> StorageSolution:
    sizes = {}
    for i in g.versions():
        c = g.materialization_cost(i)
        if c is None:
            raise ValueError(f"GitH needs Δ_ii for every version (missing {i})")
        sizes[i] = c.delta
    order = sorted(g.versions(), key=lambda i: (-sizes[i], i))

    parent: Dict[int, int] = {}
    depth: Dict[int, int] = {}
    win: List[int] = []

    for vi in order:
        best_score, best_base = None, None
        for vl in win:
            if depth[vl] >= max_depth:
                continue
            c = g.cost(vl, vi)
            if c is None:
                continue  # delta never revealed / too large to request
            if c.delta >= sizes[vi]:
                continue  # delta no better than storing vi outright
            score = c.delta / (max_depth - depth[vl])
            if best_score is None or score < best_score:
                best_score, best_base = score, vl
        if best_base is None:
            parent[vi] = 0
            depth[vi] = 0
        else:
            parent[vi] = best_base
            depth[vi] = depth[best_base] + 1
            # move the chosen base to the end of the window
            win.remove(best_base)
            win.append(vi)
            win.append(best_base)
            vi = None  # appended already
        if vi is not None:
            win.append(vi)
        while len(win) > window:
            win.pop(0)

    return StorageSolution(parent=parent, graph=g)
