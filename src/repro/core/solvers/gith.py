"""GitH — the Git `repack` heuristic (paper §4.4 and Appendix A).

* versions considered in non-increasing size (Δ_ii) order;
* sliding window of at most ``w`` candidate bases;
* depth bias: candidate base V_l is scored  Δ_{l,i} / (d_max − depth(V_l)),
  so shallow chains are preferred over slightly smaller deltas deep in a
  chain; bases at depth ≥ d_max are ineligible;
* a version with no eligible base in the window (or whose best delta is not
  smaller than materializing) is materialized at depth 0;
* window reshuffle (Appendix A step 3): the chosen base moves to the *end*
  of the window, the new version is appended, and the front is dropped to
  keep the window at ``w``.

The per-version window scan is one batched ``lookup_many`` + masked argmin
over the window's candidate edges (ineligible slots scored ``inf``; argmin's
first-minimum tie-break matches the sequential strict-`<` scan); sizes and
depths live in flat arrays.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..version_graph import StorageSolution, VersionGraph


def git_heuristic(
    g: VersionGraph,
    *,
    window: int = 10,
    max_depth: int = 50,
) -> StorageSolution:
    ea = g.arrays()
    n = g.n
    vs = np.arange(1, n + 1, dtype=np.int64)
    mat = ea.lookup_many(np.zeros(n + 1, dtype=np.int64)[1:], vs)
    if (mat < 0).any():
        missing = int(vs[mat < 0][0])
        raise ValueError(f"GitH needs Δ_ii for every version (missing {missing})")
    sizes = np.zeros(n + 1, dtype=np.float64)
    sizes[1:] = ea.delta[mat]
    # non-increasing size, ties by ascending id (lexsort: last key primary)
    order = np.lexsort((vs, -sizes[1:]))
    order = vs[order]

    parent: Dict[int, int] = {}
    depth = np.zeros(n + 1, dtype=np.int64)
    win: List[int] = []

    for vi in order.tolist():
        best_base = None
        if win:
            warr = np.asarray(win, dtype=np.int64)
            eid = ea.lookup_many(warr, np.full(warr.shape, vi, dtype=np.int64))
            elig = (
                (eid >= 0)
                & (depth[warr] < max_depth)
                & (np.where(eid >= 0, ea.delta[np.maximum(eid, 0)], np.inf)
                   < sizes[vi])
            )
            if elig.any():
                score = np.full(warr.shape, np.inf, dtype=np.float64)
                score[elig] = (
                    ea.delta[eid[elig]]
                    / (max_depth - depth[warr[elig]]).astype(np.float64)
                )
                best_base = int(warr[int(np.argmin(score))])
        if best_base is None:
            parent[vi] = 0
            depth[vi] = 0
            win.append(vi)
        else:
            parent[vi] = best_base
            depth[vi] = depth[best_base] + 1
            # move the chosen base to the end of the window
            win.remove(best_base)
            win.append(vi)
            win.append(best_base)
        while len(win) > window:
            win.pop(0)

    return StorageSolution(parent=parent, graph=g)
