"""Mergeable min-heaps of ``(weight, id)`` items with vectorized bulk offsets.

This is the in-edge structure behind the near-linear Edmonds MCA in
:mod:`repro.core.solvers.mst`.  The classic formulations use skew or pairing
heaps of individual edges; at the scales we target (10M+ edges driven from
Python) a pointer-per-edge heap spends all its time in the interpreter.
:class:`RunHeap` keeps the same *interface* contract — amortized-O(1)
``meld``, bulk ``add_offset``, cheap ``pop`` — but stores items as **sorted
runs** (numpy arrays pre-sorted by ``(weight, id)``):

* a *run* is a contiguous ``(w, ids)`` array pair sorted ascending by
  ``(w, id)`` with a cursor ``pos`` and a cached head;
* a heap holds at most ~log2(items) runs, one per binary size class:
  ``meld`` adopts the donor's runs and then *consolidates* — equal-size
  runs merge (one vectorized lexsort) and carry, exactly like binary-counter
  addition / bottom-up mergesort, so each item is re-merged O(log n) times
  total and ``peek``/``pop`` scan a logarithmic run list;
* ``add_offset(c)`` adds ``c`` to every remaining item **eagerly**, as one
  in-place contiguous numpy add per run.  The textbook structure makes this
  O(1) with a lazy per-heap scalar, but lazily *summed* offsets regroup the
  float arithmetic — ``w + (c1 + c2)`` is not bit-equal to ``(w + c1) +
  c2`` — and the Edmonds parity contract (bit-identical trees vs the seed
  oracle, which subtracts reduced costs sequentially in place) needs every
  offset applied individually in chronological order.  Eager application
  costs O(remaining items) per call, but as C-speed contiguous array ops
  behind an O(log) Python loop; in Edmonds each edge absorbs one offset per
  contraction level containing its head — exactly the seed's update count,
  minus the seed's Python-level rescans and fancy-index gathers.

Additive shifts preserve the within-run ``(w, id)`` order, so offsets never
force a re-sort.  Ties on weight break to the lower ``id`` — the tie-break
contract the Edmonds seed oracle uses (lowest edge id on equal cost).
"""

from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Tuple

import numpy as np


class _Run:
    """A sorted ``(w, ids)`` slab with a cursor; arrays are owned, not shared."""

    __slots__ = ("w", "ids", "pos", "head_w", "head_id")

    def __init__(self, w: np.ndarray, ids: np.ndarray) -> None:
        self.w = w
        self.ids = ids
        self.pos = 0
        self.head_w = float(w[0])
        self.head_id = int(ids[0])

    def _refresh_head(self) -> None:
        p = self.pos
        self.head_w = float(self.w[p])
        self.head_id = int(self.ids[p])

    def _remaining(self) -> int:
        return self.ids.shape[0] - self.pos


def _merge_runs(a: _Run, b: _Run) -> _Run:
    aw = a.w[a.pos:]
    bw = b.w[b.pos:]
    # disjoint weight ranges concatenate pre-sorted (strict <: on a boundary
    # tie the ids would still need interleaving)
    if aw[-1] < bw[0]:
        return _Run(
            np.concatenate((aw, bw)),
            np.concatenate((a.ids[a.pos:], b.ids[b.pos:])),
        )
    if bw[-1] < aw[0]:
        return _Run(
            np.concatenate((bw, aw)),
            np.concatenate((b.ids[b.pos:], a.ids[a.pos:])),
        )
    w = np.concatenate((aw, bw))
    ids = np.concatenate((a.ids[a.pos:], b.ids[b.pos:]))
    o = np.lexsort((ids, w))
    return _Run(w[o], ids[o])


class RunHeap:
    """Mergeable min-heap over ``(weight, id)`` items; see module docstring.

    Runs always have at least one remaining item (exhausted runs are removed
    immediately), and ``_runs`` stays logarithmic in the item count via
    size-class consolidation, so head scans are O(log).
    """

    __slots__ = ("_runs", "_n", "_compact_at")

    def __init__(self) -> None:
        self._runs: List[_Run] = []
        self._n = 0
        self._compact_at = 64

    # ------------------------------------------------------------ construction
    @classmethod
    def from_sorted(cls, w: np.ndarray, ids: np.ndarray) -> "RunHeap":
        """Heap over one pre-sorted run (``(w, id)`` ascending).

        ``w`` must be float64 and exclusively owned by the heap — offsets
        mutate it in place.
        """
        h = cls()
        if w.shape[0]:
            h._runs.append(_Run(w, ids))
            h._n = int(w.shape[0])
            h._compact_at = max(64, 2 * h._n)  # born fully live
        return h

    def push(self, w: float, item: int) -> None:
        """Insert a single item (a one-element run, then consolidate)."""
        self._runs.append(
            _Run(np.array([w], dtype=np.float64), np.array([item], dtype=np.int64))
        )
        self._n += 1
        self._consolidate()

    # ----------------------------------------------------------------- queries
    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0

    def _min_run(self) -> _Run:
        runs = self._runs
        best = runs[0]
        bw = best.head_w
        bi = best.head_id
        for r in runs:
            hw = r.head_w
            if hw < bw or (hw == bw and r.head_id < bi):
                best = r
                bw = hw
                bi = r.head_id
        return best

    def peek(self) -> Tuple[float, int]:
        """``(weight, id)`` of the min item; IndexError when empty.

        Ties across runs resolve to the lower head id.  A uniform offset can
        collapse two *distinct* weights in the same run to bitwise equality
        (rounding), leaving an equal-weight block whose ids are not sorted —
        ``peek`` ignores entries hidden behind a tied head; use
        :meth:`min_tied_ids` for the exact lowest-id-on-tie contract.
        """
        r = self._min_run()
        return r.head_w, r.head_id

    def min_tied_ids(self) -> Tuple[float, np.ndarray]:
        """Min weight and the ids of *all* remaining items tied at it.

        Offsets only shift runs uniformly, so runs stay weakly sorted and
        items tied at the min form a leading block of each run whose head is
        tied — one ``searchsorted`` per tied run.  This recovers the exact
        lowest-id-on-tie contract even after rounding collapses distinct
        weights to equality (see :meth:`peek`).
        """
        w = self._min_run().head_w
        tied: List[np.ndarray] = []
        for r in self._runs:
            if r.head_w == w:
                stop = r.pos + int(
                    np.searchsorted(r.w[r.pos:], w, side="right")
                )
                tied.append(r.ids[r.pos:stop])
        return w, tied[0] if len(tied) == 1 else np.concatenate(tied)

    # --------------------------------------------------------------- mutation
    def add_offset(self, c: float) -> None:
        """Add ``c`` to every remaining item, one in-place array add per run.

        A uniform shift preserves run order, so no re-sort; cached heads get
        the same single float add the array elements do, so they stay
        bit-consistent.
        """
        for r in self._runs:
            if r.pos:
                r.w[r.pos:] += c
            else:
                r.w += c
            r.head_w += c

    def pop(self) -> Tuple[float, int]:
        """Remove and return the min ``(weight, id)``."""
        r = self._min_run()
        out = (r.head_w, r.head_id)
        r.pos += 1
        self._n -= 1
        if r.pos < r.ids.shape[0]:
            r._refresh_head()
        else:
            self._runs.remove(r)
        return out

    def meld(self, other: "RunHeap") -> "RunHeap":
        """Merge ``other`` into this heap (or vice versa) and return the result.

        The side with fewer runs donates its run objects to the other —
        callers must keep using the *returned* object and drop both operands.
        Consolidation then merges equal-size-class runs (binary-counter
        carry), keeping the run list logarithmic; each item takes part in
        O(log total) merges over a heap's lifetime.
        """
        a, b = self, other
        if len(b._runs) > len(a._runs):
            a, b = b, a
        a._runs.extend(b._runs)
        a._n += b._n
        b._runs = []
        b._n = 0
        # defer the binary-counter carry while the run list is short: head
        # scans and offsets over ≤8 runs cost less than eager tiny merges
        if len(a._runs) > 8:
            a._consolidate()
        return a

    def _consolidate(self) -> None:
        runs = self._runs
        if len(runs) <= 1:
            return
        by_class: Dict[int, _Run] = {}
        for r in runs:
            k = r._remaining().bit_length()
            while k in by_class:
                # both operands are in [2^(k-1), 2^k), so the merge lands in
                # class k+1 exactly — the carry always terminates
                r = _merge_runs(by_class.pop(k), r)
                k += 1
            by_class[k] = r
        self._runs = list(by_class.values())

    def drop_while(self, dead: Callable[[np.ndarray], np.ndarray]) -> None:
        """Discard dead items until the min item is alive (or the heap empties).

        ``dead`` maps an ``ids`` array to a boolean mask (True = discard).  It
        must be *stable*: an item reported dead stays dead forever (Edmonds
        self-loops only ever stay self-loops as components coarsen).  Under
        that contract this may also discard dead items that are not currently
        minimal — it scans the min run past other runs' heads in doubling
        batches so the predicate runs vectorized — which is safe and saves
        re-inspection later.
        """
        while self._runs:
            r = self._min_run()
            ids = r.ids
            pos = r.pos
            n = ids.shape[0]
            if not bool(dead(ids[pos : pos + 1])[0]):
                return
            pos += 1
            batch = 4
            while pos < n:
                stop = min(n, pos + batch)
                mask = dead(ids[pos:stop])
                if mask.all():
                    pos = stop
                    batch *= 2
                    continue
                pos += int(np.argmin(mask))  # first False = first live item
                break
            self._n -= pos - r.pos
            r.pos = pos
            if pos < n:
                r._refresh_head()
            else:
                self._runs.remove(r)

    def compact(self, dead: Callable[[np.ndarray], np.ndarray]) -> None:
        """Physically remove every dead item now (same ``dead`` contract as
        :meth:`drop_while`).

        Filtering preserves each run's sort order, so no re-sort; offsets
        applied after the purge then touch live items only.  Without purging,
        a long contraction chain pays every ``add_offset`` over an
        ever-growing tail of dead self-loops — the old quadratic regime at
        array speed instead of the near-linear live-edge bound.
        """
        runs: List[_Run] = []
        n = 0
        for r in self._runs:
            ids = r.ids[r.pos:]
            alive = ~dead(ids)
            k = int(alive.sum())
            if k == 0:
                continue
            if k != ids.shape[0]:
                r = _Run(r.w[r.pos:][alive], ids[alive])
            runs.append(r)
            n += k
        self._runs = runs
        self._n = n
        self._consolidate()
        self._compact_at = max(64, 2 * n)

    def maybe_compact(self, dead: Callable[[np.ndarray], np.ndarray]) -> None:
        """Amortized :meth:`compact`: only when the heap has doubled since the
        last purge (so at least half the items *could* be dead).  Keeps purge
        work O(1) amortized per item on purge-heavy workloads while staying
        O(log) total purges on dense heaps that stay mostly live."""
        if self._n >= self._compact_at:
            self.compact(dead)

    # ------------------------------------------------------------- inspection
    def items(self) -> Iterator[Tuple[float, int]]:
        """Yield all remaining ``(weight, id)`` items, unordered."""
        for r in self._runs:
            p = r.pos
            for raw, item in zip(r.w[p:].tolist(), r.ids[p:].tolist()):
                yield raw, int(item)
