"""The paper's storage-graph solvers (§4), with selectable compute backends.

Every array-native solver takes ``backend="numpy"`` (default) or
``backend="jax"``:

* ``numpy`` — the heap-driven host implementations; the reference/oracle
  path, always available, and the fallback wherever the jitted formulation
  does not apply (directed MCA cycle contraction).
* ``jax`` — jitted device loops in :mod:`.jax_backend`: whole-graph
  Bellman-Ford SSSP, one-``fori_loop`` Prim and Modified-Prim, and the LMG
  per-round candidate scoring.  Outputs are **bit-identical** to the NumPy
  backend (same trees, same float costs); ``tests/test_jax_backend.py``
  enforces this on the 56-instance property suite.

``pallas=True`` additionally routes the inner segment-min / argmin
reductions through the Pallas kernels of :mod:`repro.kernels.segment_ops`.
CPU caveat: on this container Pallas runs with ``interpret=True`` — the
kernel body executes under the Pallas interpreter, which is correct but far
slower than compiled XLA; benchmarks therefore measure the jitted XLA path
(``pallas=False``), and the kernels compile for real on TPU backends.

Solvers: :mod:`.spt` (Problem 2), :mod:`.mst` (Problem 1 — Prim / Edmonds
MCA), :mod:`.lmg` (Problems 3/5), :mod:`.mp` (Problems 4/6), :mod:`.last`,
:mod:`.gith`, :mod:`.exact`.
"""

class BackendUnsupported(ValueError):
    """The requested compute backend cannot run this instance (e.g. the jax
    dense padded layout would OOM on degree-skew graphs).  The NumPy path is
    bit-identical by contract, so ``optimize`` catches this and falls back;
    direct solver callers see it as the documented clear error."""


# Shared numerical slacks.  The jax backend's bit-identity contract requires
# both backends to apply *identical* tolerances in every relaxation and
# feasibility check, so they live here rather than as per-module literals.
EPS = 1e-15            # relaxation acceptance slack (improvements ≤ EPS are
                       # rejected; ties within (0, EPS] are order-dependent
                       # and outside the parity contract)
CONSTRAINT_TOL = 1e-9  # θ / budget feasibility slack
