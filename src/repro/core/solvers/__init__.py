"""The paper's storage-graph solvers (§4), with selectable compute backends.

Every array-native solver takes ``backend="numpy"`` (default) or
``backend="jax"``:

* ``numpy`` — the heap-driven host implementations; the reference/oracle
  path, always available, and the fallback wherever the jitted formulation
  does not apply (directed MCA cycle contraction).
* ``jax`` — jitted device loops in :mod:`.jax_backend`: whole-graph
  Bellman-Ford SSSP, one-``fori_loop`` Prim and Modified-Prim, and the LMG
  per-round candidate scoring.  Device arrays are f32/i32 (the
  real-accelerator regime — TPUs have no 64-bit lanes); the device output
  is a *structure selection*, and every authoritative cost is recomputed
  host-side in f64 from the selected tree, so returned solutions match the
  NumPy backend exactly (same trees, same float costs) —
  ``tests/test_jax_backend.py`` enforces this on the 56-instance property
  suite.

Host complexity, per solver: Prim / SPT / MP are O(E log V) binary-heap
loops with vectorized CSR row relaxation; directed MCA is mergeable-heap
Edmonds (:mod:`.mst`), O(E log V) with near-linear constants on chain-like
instances — 1M-version instances solve in minutes on one core
(``BENCH_solver_scale.json``); LMG is O(rounds · ξ) with vectorized
scoring; GitH is linear in the walked window.  Cheapest-edge ties break to
the lowest edge id everywhere, which is what keeps backends and oracles in
exact agreement.  Rule of thumb for backend choice: ``jax`` wins on SPT at
every size (~2× at 50k) and on LMG's scoring rounds; the sequential-argmin
MP scan and host-only directed MCA favor ``numpy`` on CPU; past ~500k
versions the padded device layout approaches its cell cap, so scale sweeps
run ``numpy``.

``pallas=True`` additionally routes the inner segment-min / argmin
reductions through the Pallas kernels of :mod:`repro.kernels.segment_ops`.
CPU caveat: on this container Pallas runs with ``interpret=True`` — the
kernel body executes under the Pallas interpreter, which is correct but far
slower than compiled XLA; benchmarks therefore measure the jitted XLA path
(``pallas=False``), and the kernels compile for real on TPU backends.

Solvers: :mod:`.spt` (Problem 2), :mod:`.mst` (Problem 1 — Prim / Edmonds
MCA), :mod:`.lmg` (Problems 3/5), :mod:`.mp` (Problems 4/6), :mod:`.last`,
:mod:`.gith`, :mod:`.exact`.
"""

class BackendUnsupported(ValueError):
    """The requested compute backend cannot run this instance (e.g. the jax
    dense padded layout would OOM on degree-skew graphs).  The NumPy path is
    bit-identical by contract, so ``optimize`` catches this and falls back;
    direct solver callers see it as the documented clear error."""


# Shared numerical slacks.  Both backends apply identical tolerances in
# every relaxation and feasibility check, so they live here rather than as
# per-module literals.  On the f32 device path EPS sits below float32
# resolution, so those guards degrade to strict comparisons there — exact
# tolerance semantics are restored by the host-side f64 recompute.
EPS = 1e-15            # relaxation acceptance slack (improvements ≤ EPS are
                       # rejected; ties within (0, EPS] are order-dependent
                       # and outside the parity contract)
CONSTRAINT_TOL = 1e-9  # θ / budget feasibility slack
