"""LAST — balancing the MST and the SPT (paper §4.3, Algorithm 3).

Adapted from Khuller, Raghavachari & Young, "Balancing minimum spanning trees
and shortest-path trees" (Algorithmica '95).  Guarantees for undirected
Δ = Φ instances with parameter α > 1:

* every vertex: d_T(v) ≤ α · SP(v);
* total weight: W(T) ≤ (1 + 2/(α-1)) · W(MST).

As in the paper we also run it unchanged on directed instances, without the
guarantees.  The DFS relaxes along tree edges in both traversal directions
(the "back-edge" relaxation of the paper's Example 6); when a vertex exceeds
its α·SP budget the entire shortest path from the root is spliced in.

State (tentative distances, parents, SPT distances) lives in flat NumPy
arrays indexed by vertex id; edge costs come from the graph's
:class:`~repro.core.edge_arrays.EdgeArrays` via point lookups.  The tour is
iterative, so deep MST chains never touch the recursion limit.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from ..version_graph import StorageSolution, VersionGraph
from .mst import minimum_storage_tree
from .spt import dijkstra_arrays


def last_tree(
    g: VersionGraph,
    alpha: float = 2.0,
    *,
    base: Optional[StorageSolution] = None,
) -> StorageSolution:
    if alpha <= 1.0:
        raise ValueError("alpha must exceed 1")
    base = base or minimum_storage_tree(g)
    ea = g.arrays()
    sp_dist, sp_parent = dijkstra_arrays(ea, weight="phi")

    mst_children: List[List[int]] = [[] for _ in range(g.n + 1)]
    for i, p in base.parent.items():
        mst_children[p].append(i)

    parent = np.zeros(g.n + 1, dtype=np.int64)
    for i, p in base.parent.items():
        parent[i] = p
    d = np.full(g.n + 1, np.inf, dtype=np.float64)
    d[0] = 0.0

    def edge_phi(u: int, v: int) -> float:
        e = ea.lookup(u, v)
        assert e >= 0, (u, v)
        return float(ea.phi[e])

    def relax(u: int, v: int) -> None:
        w = edge_phi(u, v)
        if d[u] + w < d[v] - 1e-15:
            d[v] = d[u] + w
            parent[v] = u

    def splice_shortest_path(v: int) -> None:
        # walk the SPT path root→v and relax every edge along it
        path = [v]
        while path[-1] != 0:
            path.append(int(sp_parent[path[-1]]))
        for u, x in zip(path[::-1], path[::-1][1:]):
            relax(u, x)
        # after splicing, d[v] == sp_dist[v]

    # iterative DFS (Euler tour) over the MST with both-direction relaxation
    stack: List[tuple] = [(0, iter(mst_children[0]))]
    while stack:
        u, it = stack[-1]
        child = next(it, None)
        if child is None:
            stack.pop()
            if stack:
                pu = stack[-1][0]
                # returning edge child->parent: relax parent via child when
                # the reverse edge exists (undirected instances)
                if pu != 0 and ea.lookup(u, pu) >= 0:
                    relax(u, pu)
            continue
        v = child
        relax(u, v)
        if d[v] > alpha * sp_dist[v] + 1e-12:
            splice_shortest_path(v)
        stack.append((v, iter(mst_children[v])))

    sol = StorageSolution(
        parent={i: int(parent[i]) for i in g.versions()}, graph=g
    )
    return sol
