"""LMG — Local Move Greedy (paper §4.1, Algorithm 1).

Targets the *average/sum* recreation objective under a storage budget
(Problem 3); Problem 5 is solved by binary search over the budget
(`minimize_storage_sum_recreation`).

Starts from the minimum-storage tree (MST undirected / MCA directed) and
greedily swaps in shortest-path-tree edges maximizing

    ρ = (reduction in Σ recreation cost) / (increase in storage cost)

The paper's O(|V|²) refinement is implemented: subtree sizes (or subtree
access-frequency mass for the workload-aware variant, §4.1 "Access
Frequencies") are maintained incrementally so each candidate evaluates in
O(1); applying a swap updates the affected subtree only.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from ..version_graph import StorageSolution, VersionGraph
from .mst import minimum_storage_tree
from .spt import shortest_path_tree


def local_move_greedy(
    g: VersionGraph,
    budget: float,
    *,
    weights: Optional[Dict[int, float]] = None,
    base: Optional[StorageSolution] = None,
    spt: Optional[StorageSolution] = None,
) -> StorageSolution:
    """Problem 3: min Σ_i R_i subject to C ≤ budget.

    ``weights`` enables the workload-aware variant: the objective becomes
    Σ_i w_i · R_i (Fig. 16 experiment).  ``base``/``spt`` may be passed to
    reuse precomputed trees (the benchmark sweeps budgets over one instance).
    """
    base = base or minimum_storage_tree(g)
    spt = spt or shortest_path_tree(g)
    parent = dict(base.parent)
    tree = StorageSolution(parent=parent, graph=g)

    w_total = tree.storage_cost()
    if w_total > budget + 1e-9:
        raise ValueError(
            f"budget {budget} below minimum storage {w_total}: infeasible"
        )

    # --- incremental state -------------------------------------------------
    children: Dict[int, Set[int]] = {v: set() for v in g.vertices()}
    for i, p in parent.items():
        children[p].add(i)
    d: Dict[int, float] = {0: 0.0}  # recreation cost in current tree

    def _init_d(u: int) -> None:
        for v in children[u]:
            d[v] = d[u] + tree.edge_cost(v).phi
            _init_d(v)

    import sys

    old_limit = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old_limit, g.n + 100))
    try:
        _init_d(0)
        # subtree mass: count (unweighted) or Σ weights (workload-aware)
        mass: Dict[int, float] = {}

        def _init_mass(u: int) -> float:
            m = (1.0 if weights is None else weights.get(u, 0.0)) if u != 0 else 0.0
            for v in children[u]:
                m += _init_mass(v)
            mass[u] = m
            return m

        _init_mass(0)
    finally:
        sys.setrecursionlimit(old_limit)

    def in_subtree(node: int, root_v: int) -> bool:
        v = node
        while v != 0:
            if v == root_v:
                return True
            v = parent[v]
        return False

    # candidate pool ξ: SPT edges absent from the current tree
    candidates: Set[Tuple[int, int]] = {
        (spt.parent[v], v) for v in g.versions() if spt.parent[v] != parent[v]
    }

    while candidates:
        best_rho, best_edge = 0.0, None
        for (u, v) in candidates:
            if parent[v] == u:
                continue
            c_new = g.materialization_cost(v) if u == 0 else g.cost(u, v)
            assert c_new is not None
            c_old = tree.edge_cost(v)
            dw = c_new.delta - c_old.delta
            if w_total + dw > budget + 1e-9:
                continue  # would violate the storage budget
            if u != 0 and in_subtree(u, v):
                continue  # would create a cycle
            dd = (d[u] + c_new.phi) - d[v]  # change in v's recreation cost
            reduction = -dd * mass[v]
            if reduction <= 0:
                continue
            rho = reduction / dw if dw > 0 else float("inf")
            if rho > best_rho:
                best_rho, best_edge = rho, (u, v, dw, dd)
        if best_edge is None:
            break
        u, v, dw, dd = best_edge
        old_u = parent[v]
        # rewire
        children[old_u].discard(v)
        children[u].add(v)
        parent[v] = u
        w_total += dw
        # subtree mass moves from old ancestors to new ancestors
        m = mass[v]
        a = old_u
        while a != 0:
            mass[a] -= m
            a = parent[a]
        a = u
        while a != 0:
            mass[a] += m
            a = parent[a]
        # recreation costs of v's subtree shift by dd
        stack = [v]
        while stack:
            x = stack.pop()
            d[x] += dd
            stack.extend(children[x])
        candidates.discard((u, v))

    return tree


def minimize_storage_sum_recreation(
    g: VersionGraph,
    theta: float,
    *,
    weights: Optional[Dict[int, float]] = None,
    tol: float = 1e-3,
    max_iters: int = 48,
) -> StorageSolution:
    """Problem 5: min C subject to Σ_i R_i ≤ theta, by binary search on the
    budget passed to LMG (paper §4.1: "repeated iterations and binary search").
    """
    base = minimum_storage_tree(g)
    spt = shortest_path_tree(g)
    lo = base.storage_cost()
    hi = spt.storage_cost()
    if spt.sum_recreation(weights) > theta + 1e-9:
        raise ValueError("theta below SPT sum-recreation: infeasible")
    if base.sum_recreation(weights) <= theta:
        return base
    best = None
    for _ in range(max_iters):
        mid = 0.5 * (lo + hi)
        sol = local_move_greedy(g, mid, weights=weights, base=base, spt=spt)
        if sol.sum_recreation(weights) <= theta:
            best, hi = sol, mid
        else:
            lo = mid
        if hi - lo <= tol * max(1.0, lo):
            break
    if best is None:
        # fall back to the SPT (always feasible given the check above)
        best = spt
    return best
