"""LMG — Local Move Greedy (paper §4.1, Algorithm 1).

Targets the *average/sum* recreation objective under a storage budget
(Problem 3); Problem 5 is solved by binary search over the budget
(`minimize_storage_sum_recreation`).

Starts from the minimum-storage tree (MST undirected / MCA directed) and
greedily swaps in shortest-path-tree edges maximizing

    ρ = (reduction in Σ recreation cost) / (increase in storage cost)

Vectorized implementation
-------------------------
Every round scores the whole candidate set ξ with masked array ops instead
of a per-edge Python loop:

* candidate edges live in flat ``(u, v, Δ, Φ)`` arrays sorted by ``(u, v)``;
* the cycle test ("is ``u`` inside ``v``'s subtree?") is an Euler-tour
  interval containment check — the current tree's preorder is kept as an
  ``order`` array with per-vertex ``tin`` positions and subtree ``size``s,
  so the whole candidate set is filtered with two compares;
* applying a swap splices the moved subtree's contiguous preorder block to
  just after its new parent (one ``concatenate`` + one scatter), shifts the
  subtree's recreation costs with one fancy-indexed add, and walks only the
  two ancestor chains to fix subtree masses/sizes.

Ties in ρ resolve to the smallest ``(u, v)`` candidate (argmax returns the
first maximum over the sorted candidate arrays), which matches a sequential
strict-`>` scan in sorted order.  All traversals are iterative — no
``sys.setrecursionlimit`` games on deep chains.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from ..version_graph import StorageSolution, VersionGraph
from . import CONSTRAINT_TOL
from .mst import minimum_storage_tree
from .spt import shortest_path_tree


def _preorder(children: List[List[int]], n: int) -> np.ndarray:
    """Iterative preorder over vertices 0..n visiting children ascending."""
    order = np.empty(n + 1, dtype=np.int64)
    stack = [0]
    k = 0
    while stack:
        x = stack.pop()
        order[k] = x
        k += 1
        stack.extend(reversed(children[x]))
    assert k == n + 1, "storage tree does not span all versions"
    return order


def local_move_greedy(
    g: VersionGraph,
    budget: float,
    *,
    weights: Optional[Dict[int, float]] = None,
    base: Optional[StorageSolution] = None,
    spt: Optional[StorageSolution] = None,
    backend: str = "numpy",
    pallas: bool = False,
) -> StorageSolution:
    """Problem 3: min Σ_i R_i subject to C ≤ budget.

    ``weights`` enables the workload-aware variant: the objective becomes
    Σ_i w_i · R_i (Fig. 16 experiment).  ``base``/``spt`` may be passed to
    reuse precomputed trees (the benchmark sweeps budgets over one instance).
    ``backend="jax"`` scores every round's candidate set ξ on device in f32
    (:class:`repro.core.solvers.jax_backend.LmgScorer`); the device argmax is
    a *selection* — the chosen move's Δw/Δd are recomputed in f64 and
    feasibility re-checked before committing, so the tree bookkeeping below
    (shared by both backends) only ever sees exact arithmetic.
    """
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown solver backend {backend!r}")
    base = base or minimum_storage_tree(g, backend=backend, pallas=pallas)
    spt = spt or shortest_path_tree(g, backend=backend, pallas=pallas)
    ea = g.arrays()
    n = g.n

    parent = np.zeros(n + 1, dtype=np.int64)
    for i, p in base.parent.items():
        parent[i] = p
    vs = np.arange(1, n + 1, dtype=np.int64)
    eid = ea.lookup_many(parent[vs], vs)
    assert (eid >= 0).all(), "base tree uses an unrevealed edge"
    cur_delta = np.zeros(n + 1, dtype=np.float64)
    cur_phi = np.zeros(n + 1, dtype=np.float64)
    cur_delta[1:] = ea.delta[eid]
    cur_phi[1:] = ea.phi[eid]

    # sequential left-fold to match StorageSolution.storage_cost() exactly
    w_total = 0.0
    for x in cur_delta[1:].tolist():
        w_total += x
    if w_total > budget + CONSTRAINT_TOL:
        raise ValueError(
            f"budget {budget} below minimum storage {w_total}: infeasible"
        )

    # --- incremental state -------------------------------------------------
    children: List[List[int]] = [[] for _ in range(n + 1)]
    for i in range(1, n + 1):
        children[parent[i]].append(i)
    order = _preorder(children, n)
    tin = np.empty(n + 1, dtype=np.int64)
    tin[order] = np.arange(n + 1, dtype=np.int64)

    # recreation cost d: preorder guarantees parents before children
    d = np.zeros(n + 1, dtype=np.float64)
    for x in order[1:].tolist():
        d[x] = d[parent[x]] + cur_phi[x]

    # subtree mass (count, or Σ weights workload-aware) and subtree size
    own = np.ones(n + 1, dtype=np.float64)
    own[0] = 0.0
    if weights is not None:
        for i in range(1, n + 1):
            own[i] = weights.get(i, 0.0)
    mass = own.copy()
    size = np.ones(n + 1, dtype=np.int64)
    for x in order[:0:-1].tolist():  # reverse preorder, root excluded
        p = int(parent[x])
        mass[p] += mass[x]
        size[p] += size[x]

    # candidate pool ξ: SPT edges absent from the current tree, (u, v)-sorted
    cu_l, cv_l = [], []
    for v in range(1, n + 1):
        if spt.parent[v] != parent[v]:
            cu_l.append(spt.parent[v])
            cv_l.append(v)
    cu = np.asarray(cu_l, dtype=np.int64)
    cv = np.asarray(cv_l, dtype=np.int64)
    if cu.shape[0]:
        perm = np.lexsort((cv, cu))
        cu, cv = cu[perm], cv[perm]
    ceid = ea.lookup_many(cu, cv)
    assert (ceid >= 0).all() or ceid.shape[0] == 0
    cand_delta = ea.delta[ceid] if ceid.shape[0] else np.empty(0)
    cand_phi = ea.phi[ceid] if ceid.shape[0] else np.empty(0)
    active = np.ones(cu.shape[0], dtype=bool)

    scorer = None
    if backend == "jax" and cu.shape[0]:
        from .jax_backend import LmgScorer

        scorer = LmgScorer(cu, cv, cand_delta, cand_phi, pallas=pallas)

    while active.any():
        if scorer is not None:
            i, rho_i, _, _, any_ok = scorer.score(
                active, cur_delta, d, mass, tin, size, w_total, budget
            )
            if not any_ok or rho_i <= 0.0:
                break
            # the f32 device scores only *select* i: recompute the move in
            # f64 and re-check feasibility; a borderline candidate that flips
            # under exact arithmetic is retired and the round re-scored
            ui, vi = int(cu[i]), int(cv[i])
            dwi = float(cand_delta[i] - cur_delta[vi])
            ddi = float((d[ui] + cand_phi[i]) - d[vi])
            reduction = -ddi * mass[vi]
            if (
                w_total + dwi > budget + CONSTRAINT_TOL
                or reduction <= 0
                or (tin[vi] <= tin[ui] < tin[vi] + size[vi])
            ):
                active[i] = False
                continue
        else:
            dw = cand_delta - cur_delta[cv]
            ok = active & (w_total + dw <= budget + CONSTRAINT_TOL)
            dd = (d[cu] + cand_phi) - d[cv]
            reduction = -dd * mass[cv]
            ok &= reduction > 0
            # cycle test: u inside subtree(v) ⇔ tin[v] ≤ tin[u] < tin[v]+size[v];
            # the root is never excluded (tin[0] == 0 < tin[v] for any v ≥ 1)
            ok &= ~((tin[cv] <= tin[cu]) & (tin[cu] < tin[cv] + size[cv]))
            if not ok.any():
                break
            rho = np.full(cu.shape[0], -1.0, dtype=np.float64)
            pos = ok & (dw > 0)
            rho[pos] = reduction[pos] / dw[pos]
            rho[ok & (dw <= 0)] = np.inf
            i = int(np.argmax(rho))
            if rho[i] <= 0.0:
                break
            dwi, ddi = float(dw[i]), float(dd[i])
        u, v = int(cu[i]), int(cv[i])
        old_u = int(parent[v])
        # rewire
        parent[v] = u
        w_total += dwi
        cur_delta[v] = cand_delta[i]
        cur_phi[v] = cand_phi[i]
        # subtree mass moves from old ancestors to new ancestors
        mv = float(mass[v])
        a = old_u
        while a != 0:
            mass[a] -= mv
            a = int(parent[a])
        a = u
        while a != 0:
            mass[a] += mv
            a = int(parent[a])
        # recreation costs of v's subtree shift by dd (contiguous in preorder)
        pv = int(tin[v])
        sz = int(size[v])
        block = order[pv:pv + sz]
        d[block] += ddi
        # splice the subtree block to immediately after its new parent
        tu = int(tin[u])
        if tu < pv:
            order = np.concatenate(
                (order[:tu + 1], block, order[tu + 1:pv], order[pv + sz:])
            )
        else:  # u sits after the block (it cannot be inside it)
            order = np.concatenate(
                (order[:pv], order[pv + sz:tu + 1], block, order[tu + 1:])
            )
        tin[order] = np.arange(n + 1, dtype=np.int64)
        a = old_u
        while a != 0:
            size[a] -= sz
            a = int(parent[a])
        a = u
        while a != 0:
            size[a] += sz
            a = int(parent[a])
        active[i] = False

    return StorageSolution(
        parent={i: int(parent[i]) for i in range(1, n + 1)}, graph=g
    )


def minimize_storage_sum_recreation(
    g: VersionGraph,
    theta: float,
    *,
    weights: Optional[Dict[int, float]] = None,
    tol: float = 1e-3,
    max_iters: int = 48,
    backend: str = "numpy",
    pallas: bool = False,
) -> StorageSolution:
    """Problem 5: min C subject to Σ_i R_i ≤ theta, by binary search on the
    budget passed to LMG (paper §4.1: "repeated iterations and binary search").
    """
    base = minimum_storage_tree(g, backend=backend, pallas=pallas)
    spt = shortest_path_tree(g, backend=backend, pallas=pallas)
    lo = base.storage_cost()
    hi = spt.storage_cost()
    if spt.sum_recreation(weights) > theta + 1e-9:
        raise ValueError("theta below SPT sum-recreation: infeasible")
    if base.sum_recreation(weights) <= theta:
        return base
    best = None
    for _ in range(max_iters):
        mid = 0.5 * (lo + hi)
        sol = local_move_greedy(g, mid, weights=weights, base=base, spt=spt,
                                backend=backend, pallas=pallas)
        if sol.sum_recreation(weights) <= theta:
            best, hi = sol, mid
        else:
            lo = mid
        if hi - lo <= tol * max(1.0, lo):
            break
    if best is None:
        # fall back to the SPT (always feasible given the check above)
        best = spt
    return best
