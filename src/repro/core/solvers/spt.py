"""Shortest-path tree (paper Problem 2, Lemma 3) — Dijkstra from the dummy root.

The SPT minimizes every ``R_i`` simultaneously: path lengths are measured in
``Φ`` (recreation cost).  Works for directed and undirected instances alike
(undirected instances simply have both edge directions revealed).
"""

from __future__ import annotations

import heapq
from typing import Dict, Optional, Tuple

from ..version_graph import StorageSolution, VersionGraph


def shortest_path_tree(
    g: VersionGraph, *, weight: str = "phi"
) -> StorageSolution:
    dist, parent = dijkstra(g, weight=weight)
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"versions unreachable from root: {missing[:8]}")
    return StorageSolution(parent={i: parent[i] for i in g.versions()}, graph=g)


def dijkstra(
    g: VersionGraph, *, weight: str = "phi", source: int = 0
) -> Tuple[Dict[int, float], Dict[int, int]]:
    """Single-source shortest paths over the chosen cost component.

    Returns ``(dist, parent)``; ``parent`` excludes the source itself.
    """
    dist: Dict[int, float] = {source: 0.0}
    parent: Dict[int, int] = {}
    done = set()
    pq: list = [(0.0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if u in done:
            continue
        done.add(u)
        for v, c in g.out_edges(u):
            w = c.phi if weight == "phi" else c.delta
            nd = d + w
            if v not in dist or nd < dist[v] - 1e-15:
                dist[v] = nd
                parent[v] = u
                heapq.heappush(pq, (nd, v))
    return dist, parent
