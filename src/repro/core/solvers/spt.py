"""Shortest-path tree (paper Problem 2, Lemma 3) — Dijkstra from the dummy root.

The SPT minimizes every ``R_i`` simultaneously: path lengths are measured in
``Φ`` (recreation cost).  Works for directed and undirected instances alike
(undirected instances simply have both edge directions revealed).

The relaxation runs on the flat :class:`~repro.core.edge_arrays.EdgeArrays`
view: popping a vertex relaxes its whole CSR out-row with one masked array
op, pushing only the improved frontier entries onto the binary heap — the
per-edge Python loop of the dict-based implementation is gone.

``backend="jax"`` replaces the Python-heap loop with the jitted whole-graph
Bellman-Ford relaxation of :mod:`.jax_backend` (bit-identical output; see
that module for the equivalence argument).
"""

from __future__ import annotations

import heapq
from typing import Dict, Optional, Tuple

import numpy as np

from ..edge_arrays import EdgeArrays
from ..version_graph import StorageSolution, VersionGraph
from . import EPS


def shortest_path_tree(
    g: VersionGraph, *, weight: str = "phi", backend: str = "numpy",
    pallas: bool = False,
) -> StorageSolution:
    if backend == "jax":
        from . import jax_backend

        dist, parent = jax_backend.sssp(g.arrays(), weight=weight,
                                        pallas=pallas)
    elif backend == "numpy":
        dist, parent = dijkstra_arrays(g.arrays(), weight=weight)
    else:
        raise ValueError(f"unknown solver backend {backend!r}")
    missing = [i for i in g.versions() if parent[i] < 0]
    if missing:
        raise ValueError(f"versions unreachable from root: {missing[:8]}")
    return StorageSolution(
        parent={i: int(parent[i]) for i in g.versions()}, graph=g
    )


def dijkstra_arrays(
    ea: EdgeArrays, *, weight: str = "phi", source: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-source shortest paths over the chosen cost component.

    Returns ``(dist, parent)`` arrays indexed by vertex id; unreachable
    vertices have ``dist == inf`` and ``parent == -1`` (the source keeps
    ``parent == -1`` too).
    """
    w = ea.phi if weight == "phi" else ea.delta
    nv = ea.n + 1
    dist = np.full(nv, np.inf, dtype=np.float64)
    parent = np.full(nv, -1, dtype=np.int64)
    done = np.zeros(nv, dtype=bool)
    dist[source] = 0.0
    pq: list = [(0.0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if done[u]:
            continue
        done[u] = True
        s, e = ea.out_range(u)
        if s == e:
            continue
        vs = ea.dst[s:e]
        nd = d + w[s:e]
        imp = ~done[vs] & (nd < dist[vs] - EPS)
        if imp.any():
            vi = vs[imp]
            ndi = nd[imp]
            dist[vi] = ndi
            parent[vi] = u
            for dv, vv in zip(ndi.tolist(), vi.tolist()):
                heapq.heappush(pq, (dv, vv))
    return dist, parent


def dijkstra(
    g: VersionGraph, *, weight: str = "phi", source: int = 0
) -> Tuple[Dict[int, float], Dict[int, int]]:
    """Dict-shaped compatibility wrapper around :func:`dijkstra_arrays`.

    Returns ``(dist, parent)``; ``parent`` excludes the source itself and
    ``dist`` omits unreachable vertices, exactly like the old implementation.
    """
    dist_a, parent_a = dijkstra_arrays(g.arrays(), weight=weight, source=source)
    dist = {
        v: float(dist_a[v]) for v in range(g.n + 1) if np.isfinite(dist_a[v])
    }
    parent = {v: int(parent_a[v]) for v in range(g.n + 1) if parent_a[v] >= 0}
    return dist, parent
