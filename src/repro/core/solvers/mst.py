"""Minimum spanning structures (paper Problem 1, Lemma 2).

* Undirected instances: Prim's algorithm (binary heap), O(E log V).
* Directed instances: Edmonds' optimum branching / minimum-cost arborescence
  (MCA), recursive cycle-contraction formulation, rooted at the dummy vertex.

Weights are the ``Δ`` components (storage bytes).  Tests cross-check the MCA
against ``networkx.minimum_spanning_arborescence``.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

from ..version_graph import StorageSolution, VersionGraph


def minimum_storage_tree(g: VersionGraph) -> StorageSolution:
    """Solve Problem 1: min total storage, any finite recreation."""
    if g.directed:
        parent = _edmonds_mca(g)
    else:
        parent = _prim(g)
    return StorageSolution(parent=parent, graph=g)


# ------------------------------------------------------------------- Prim MST
def _prim(g: VersionGraph) -> Dict[int, int]:
    parent: Dict[int, int] = {}
    best: Dict[int, float] = {0: 0.0}
    in_tree = set()
    pq: List[Tuple[float, int, int]] = [(0.0, 0, 0)]  # (w, vertex, parent)
    while pq:
        w, u, p = heapq.heappop(pq)
        if u in in_tree:
            continue
        in_tree.add(u)
        if u != 0:
            parent[u] = p
        for v, c in g.out_edges(u):
            if v in in_tree:
                continue
            if v not in best or c.delta < best[v]:
                best[v] = c.delta
                heapq.heappush(pq, (c.delta, v, u))
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return parent


# --------------------------------------------------- Edmonds (recursive form)
def _edmonds_mca(g: VersionGraph) -> Dict[int, int]:
    edges = [(u, v, c.delta) for u, v, c in g.edges()]
    nodes = list(g.vertices())
    parent_edges = _edmonds(nodes, edges, root=0)
    parent = {v: u for (u, v) in parent_edges}
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"no arborescence: unreachable {missing[:8]}")
    return parent


def _edmonds(
    nodes: List[int], edges: List[Tuple[int, int, float]], root: int
) -> List[Tuple[int, int]]:
    """Return the edge set ``{(u, v)}`` of the min-cost arborescence.

    Classic recursive contraction.  Each recursion level works with edge
    tuples ``(u, v, w, payload)`` whose endpoints are *that level's* vertex
    ids; ``payload`` is the corresponding edge tuple of the level below
    (``None`` marks an original edge), so expansion unwinds level by level —
    this handles arbitrarily nested cycle contractions.
    """
    work = [(u, v, w, None) for (u, v, w) in edges if v != root and u != v]
    chosen = _edmonds_rec(set(nodes), work, root)
    out = []
    for e in chosen:
        while e[3] is not None:  # unwind to the original edge
            e = e[3]
        out.append((e[0], e[1]))
    return out


def _edmonds_rec(nodes, edges, root):
    """Return the chosen subset of ``edges`` (tuples of this level)."""
    # 1. cheapest incoming edge per node
    min_in: Dict[int, tuple] = {}
    for e in edges:
        u, v, w, _ = e
        if v == root:
            continue
        cur = min_in.get(v)
        if cur is None or w < cur[2]:
            min_in[v] = e
    for v in nodes:
        if v != root and v not in min_in:
            raise ValueError(f"vertex {v} unreachable from root")

    # 2. detect a cycle among chosen edges
    cycle = _find_cycle(nodes, min_in, root)
    if cycle is None:
        return list(min_in.values())

    # 3. contract the cycle into a supernode
    cyc_set = set(cycle)
    super_node = max(nodes) + 1
    new_nodes = {n for n in nodes if n not in cyc_set} | {super_node}
    cyc_cost = {v: min_in[v][2] for v in cycle}
    new_edges = []
    for e in edges:
        u, v, w, _ = e
        iu, iv = u in cyc_set, v in cyc_set
        if iu and iv:
            continue
        if iv:
            # reduced cost: picking this edge un-picks the cycle edge into v
            new_edges.append((u, super_node, w - cyc_cost[v], e))
        elif iu:
            new_edges.append((super_node, v, w, e))
        else:
            new_edges.append((u, v, w, e))

    # Drop this level's edge list before recursing: the expansion step only
    # needs min_in and the cycle — without this, dense graphs with deeply
    # nested contractions hold O(E·levels) tuples live (observed OOM on the
    # 800-version DC runtime benchmark).
    edges = None  # noqa: F841
    sub = _edmonds_rec(new_nodes, new_edges, root)

    # 4. expand: map chosen contracted edges back to this level's edges; the
    # unique chosen edge entering the supernode tells us which cycle edge to
    # drop.
    result = []
    enter_head = None
    for e in sub:
        u, v, w, payload = e
        this_level = payload  # every new_edge wrapped one of this level's edges
        result.append(this_level)
        if v == super_node:
            assert enter_head is None, "two edges entering one supernode"
            enter_head = this_level[1]  # entry vertex inside the cycle
    assert enter_head is not None, "no edge entered the contracted cycle"
    for v in cycle:
        if v != enter_head:
            result.append(min_in[v])
    return result


def _find_cycle(nodes, min_in, root):
    color: Dict[int, int] = {}
    for start in nodes:
        if start == root or color.get(start) == 2:
            continue
        path = []
        v = start
        while True:
            if v == root or color.get(v) == 2:
                break
            if color.get(v) == 1:
                # found a cycle: extract it from path
                idx = path.index(v)
                for p in path:
                    color[p] = 2
                return path[idx:]
            color[v] = 1
            path.append(v)
            v = min_in[v][0]
        for p in path:
            color[p] = 2
    return None
