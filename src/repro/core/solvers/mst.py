"""Minimum spanning structures (paper Problem 1, Lemma 2).

* Undirected instances: Prim's algorithm — binary heap over vertices, with
  each dequeued vertex's whole CSR out-row relaxed in one masked array op.
* Directed instances: Edmonds' optimum branching / minimum-cost arborescence
  (MCA), iterative cycle-contraction with a **mergeable-heap** in-edge
  structure (:class:`repro.core.solvers.meldable_heap.RunHeap`): each
  vertex's in-edges live in a heap of sorted runs, cycle contraction is
  "meld the members' heaps + one vectorized additive offset over the live
  items" instead of concatenating and rescanning arrays, and supernode
  self-loops are purged with a vectorized union-find gather on an amortized
  doubling schedule.  Total work is O(E log V) with near-linear constants
  on chain-like instances — ~6× the seed's incremental list-merge
  formulation at 50k versions and tractable at 1M
  (``BENCH_solver_scale.json``).

Weights are the ``Δ`` components (storage bytes).  Cheapest-in-edge ties
break to the lowest edge id, matching the dict-based seed implementation
bit-for-bit; tests cross-check the MCA against the seed oracle on the
56-instance property suite plus dense two-cycle adversarial instances.

``backend="jax"`` runs the undirected case as one jitted Prim loop
(:func:`repro.core.solvers.jax_backend.prim`, bit-identical).  Directed
instances always use the host mergeable-heap Edmonds — cycle contraction is
pointer-chasing with data-dependent shapes, unsuited to jitting, and the
heap path is fast enough that a device formulation stopped being the
bottleneck (see ``BENCH_solver_scale.json`` 500k/1M rows).
"""

from __future__ import annotations

import heapq
from bisect import bisect_right
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..edge_arrays import EdgeArrays
from ..version_graph import StorageSolution, VersionGraph
from .meldable_heap import RunHeap


def minimum_storage_tree(
    g: VersionGraph, *, backend: str = "numpy", pallas: bool = False
) -> StorageSolution:
    """Solve Problem 1: min total storage, any finite recreation."""
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown solver backend {backend!r}")
    if g.directed:
        parent = _edmonds_mca(g)
    elif backend == "jax":
        parent = _prim_jax(g, pallas=pallas)
    else:
        parent = _prim(g)
    return StorageSolution(parent=parent, graph=g)


# ------------------------------------------------------------------- Prim MST
def _prim_jax(g: VersionGraph, *, pallas: bool = False) -> Dict[int, int]:
    from . import jax_backend

    bp = jax_backend.prim(g.arrays(), pallas=pallas)
    missing = [i for i in g.versions() if bp[i] < 0]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return {i: int(bp[i]) for i in g.versions()}


def _prim(g: VersionGraph) -> Dict[int, int]:
    ea = g.arrays()
    nv = ea.n + 1
    parent = np.full(nv, -1, dtype=np.int64)
    best = np.full(nv, np.inf, dtype=np.float64)
    in_tree = np.zeros(nv, dtype=bool)
    best[0] = 0.0
    pq: List[Tuple[float, int, int]] = [(0.0, 0, 0)]  # (w, vertex, parent)
    while pq:
        w, u, p = heapq.heappop(pq)
        if in_tree[u]:
            continue
        in_tree[u] = True
        if u != 0:
            parent[u] = p
        s, e = ea.out_range(u)
        if s == e:
            continue
        vs = ea.dst[s:e]
        ws = ea.delta[s:e]
        imp = ~in_tree[vs] & (ws < best[vs])
        if imp.any():
            vi = vs[imp]
            wi = ws[imp]
            best[vi] = wi
            for wv, vv in zip(wi.tolist(), vi.tolist()):
                heapq.heappush(pq, (wv, vv, u))
    missing = [i for i in g.versions() if parent[i] < 0]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return {i: int(parent[i]) for i in g.versions()}


# ------------------------------------- Edmonds (mergeable-heap contraction)
def _edmonds_mca(g: VersionGraph) -> Dict[int, int]:
    eids = _edmonds_arrays(g.arrays(), root=0)
    ea = g.arrays()
    parent = dict(zip(ea.dst[eids].tolist(), ea.src[eids].tolist()))
    if len(parent) != ea.n:
        missing = [i for i in g.versions() if i not in parent]
        raise ValueError(f"no arborescence: unreachable {missing[:8]}")
    return parent


def _edmonds_arrays(ea: EdgeArrays, root: int = 0) -> np.ndarray:
    """Edge ids (into ``ea``) of the min-cost arborescence rooted at ``root``.

    Gabow-style cycle contraction over mergeable heaps: each group's
    in-edges live in a :class:`RunHeap` (a binary-counter list of sorted
    runs).  Contracting a cycle is, per member, one ``add_offset(-cost)``
    for the reduced-cost update (eager, vectorized over the member's live
    items — eagerness keeps the float-op order identical to the seed's
    sequential subtractions) plus one amortized-O(1) ``meld`` — no
    concatenation or rescan of edge arrays.  Components are tracked in a
    union-find; supernode self-loops are trimmed from the heap top with a
    vectorized representative gather (``drop_while``) and bulk-purged on a
    doubling schedule (``maybe_compact``) so offsets never pay for a long
    dead tail; a discarded edge never has to be looked at again because
    components only ever coarsen.

    Cheapest-in-edge ties break to the lowest edge id — runs are built from
    one stable ``(dst, weight)`` lexsort, so equal-weight edges surface in
    ascending id order — matching a sequential strict-`<` scan; results are
    bit-identical to the recursive seed formulation on the property suite.

    The expansion phase walks the contraction forest: each frame re-routes
    its supernode's chosen entering edge to the member it actually points
    at, then adopts the remaining members' cycle edges.
    """
    keep = (ea.dst != root) & (ea.src != ea.dst)
    eids = np.nonzero(keep)[0].astype(np.int64)
    u = ea.src[eids]
    v = ea.dst[eids]
    w0 = ea.delta[eids].astype(np.float64)

    n_base = ea.n + 1                       # vertex ids 0..n
    cap = 2 * n_base + 2                    # ≤ one supernode per contraction
    dsu = np.arange(cap, dtype=np.int64)

    def find(x: int) -> int:
        while dsu[x] != x:
            dsu[x] = dsu[dsu[x]]
            x = int(dsu[x])
        return x

    def reps_of(nodes: np.ndarray) -> np.ndarray:
        """Representative lookup; vectorized gather-to-fixpoint when wide."""
        if nodes.shape[0] <= 16:
            # the gather loop's fixpoint test costs several ufunc dispatches;
            # for the short batches drop_while probes with, scalar path-halving
            # finds win by ~3x
            return np.array([find(x) for x in nodes.tolist()], dtype=np.int64)
        t = dsu[nodes]
        while True:
            t2 = dsu[t]
            if (t2 == t).all():
                break
            t = t2
        dsu[nodes] = t  # path compression for later gathers
        return t

    # one global stable (dst, weight) sort: per-vertex runs are ascending by
    # (w, local id) — and ascending local id is ascending global edge id
    order = np.lexsort((w0, v))
    ptr = np.searchsorted(v[order], np.arange(n_base + 1, dtype=np.int64))

    empty = np.nonzero((ptr[1:] == ptr[:-1]) & (np.arange(n_base) != root))[0]
    if empty.shape[0]:
        raise ValueError(f"vertex {int(empty[0])} unreachable from root")
    # no self-loops exist before the first contraction, so each vertex's run
    # head is already its min-(w, id) in-edge; bulk-convert heads to Python
    # scalars once instead of per-vertex int()/float() round-trips
    head_sl = order[ptr[:-1] % order.shape[0]]
    head_eid = head_sl.tolist()
    head_w = w0[head_sl].tolist()
    ptr_l = ptr.tolist()
    heaps: Dict[int, RunHeap] = {}
    min_edge: Dict[int, int] = {}
    min_cost: Dict[int, float] = {}
    for x in range(n_base):
        if x == root:
            continue
        sl = order[ptr_l[x]:ptr_l[x + 1]]
        heaps[x] = RunHeap.from_sorted(w0[sl], sl)
        min_edge[x] = head_eid[x]
        min_cost[x] = head_w[x]

    one_true = np.ones(1, dtype=bool)
    one_false = np.zeros(1, dtype=bool)

    def choose_min(gr: int) -> None:
        """Min-(w, id) in-edge of supernode ``gr``; drops self-loops lazily."""
        h = heaps[gr]

        def dead(ids: np.ndarray) -> np.ndarray:
            # head probes are single-edge: scalar find beats the vectorized
            # gather-to-fixpoint by ~10x there
            if ids.shape[0] == 1:
                return one_true if find(int(u[ids[0]])) == gr else one_false
            return reps_of(u[ids]) == gr

        # amortized purge keeps offsets touching live in-edges only (the
        # seed compacts on every choose; doubling keeps dense heaps cheap)
        h.maybe_compact(dead)
        h.drop_while(dead)
        if not h:
            raise ValueError(f"vertex {gr} unreachable from root")
        wt, cands = h.min_tied_ids()
        if cands.shape[0] > 1:
            # lowest edge id among the live tied candidates (the tie block
            # can hide dead self-loops and, after rounding collapses two
            # distinct reduced weights, ids out of order — filter + min
            # reproduces the seed's full rescan semantics)
            cands = cands[reps_of(u[cands]) != gr]
        min_edge[gr] = int(cands.min())
        min_cost[gr] = wt

    frames: List[Tuple[int, List[int], Dict[int, int]]] = []
    next_node = n_base
    u_l = u.tolist()  # walk steps index one tail at a time; lists beat ndarray

    # cycle hunt over the min-in functional graph, ascending starts; each
    # contraction resumes the walk from the fresh supernode
    color = np.zeros(cap, dtype=np.int8)  # 0=white 1=on path 2=done
    on_path: Dict[int, int] = {}          # node -> index in `path`
    starts: List[int] = [x for x in range(n_base) if x != root]
    si = 0
    while si < len(starts):
        start = starts[si]
        si += 1
        if find(start) != start or color[start] == 2:
            continue
        path: List[int] = []
        on_path.clear()
        x = start
        while True:
            if x == root or color[x] == 2:
                for p in path:
                    color[p] = 2
                break
            if color[x] == 1:
                ci = on_path[x]
                members = path[ci:]
                del path[ci:]
                s = next_node
                next_node += 1
                frames.append((s, members, {m: min_edge[m] for m in members}))
                acc: Optional[RunHeap] = None
                for m in members:
                    h = heaps.pop(m)
                    h.add_offset(-min_cost[m])  # reduced costs, O(1)
                    acc = h if acc is None else acc.meld(h)
                    del min_edge[m]
                    del min_cost[m]
                    del on_path[m]
                    dsu[m] = s
                heaps[s] = acc  # type: ignore[assignment]
                choose_min(s)
                x = s  # resume the walk from the contracted node
                continue
            color[x] = 1
            on_path[x] = len(path)
            path.append(x)
            x = find(u_l[min_edge[x]])

    # -------------------------------------------------------------- expansion
    # Preorder leaf numbering of the contraction forest: each frame's entry
    # edge points at a *base* vertex, and the member whose subtree contains
    # it is one bisect over the members' (preorder-ascending) tins — an
    # ancestor walk instead goes quadratic under deep contraction nesting.
    children: Dict[int, List[int]] = {s: members for s, members, _ in frames}
    has_parent = bytearray(next_node)
    for _, members, _ in frames:
        for m in members:
            has_parent[m] = 1
    tin = [0] * next_node
    timer = 0
    stack: List[int] = []
    for nd in range(next_node):
        if has_parent[nd]:
            continue
        stack.append(nd)
        while stack:
            cur = stack.pop()
            tin[cur] = timer
            kids = children.get(cur)
            if kids is None:
                timer += 1  # base vertices are the leaves
            else:
                stack.extend(reversed(kids))

    # entry_edge: group -> chosen in-edge; start from the surviving groups
    entry_edge: Dict[int, int] = dict(min_edge)
    for s, members, min_map in reversed(frames):
        e = entry_edge.pop(s)
        # the member the entering edge actually points at
        x = members[bisect_right([tin[m] for m in members], tin[int(v[e])]) - 1]
        entry_edge[x] = e
        for m in members:
            if m != x:
                entry_edge[m] = min_map[m]
    return eids[np.asarray(sorted(entry_edge.values()), dtype=np.int64)]
