"""Minimum spanning structures (paper Problem 1, Lemma 2).

* Undirected instances: Prim's algorithm — binary heap over vertices, with
  each dequeued vertex's whole CSR out-row relaxed in one masked array op.
* Directed instances: Edmonds' optimum branching / minimum-cost arborescence
  (MCA), iterative cycle-contraction over flat edge arrays: the cheapest
  in-edge per vertex is a single stable lexsort per contraction level, and
  the contraction/expansion stack replaces the old recursive formulation
  (no recursion-limit concerns, and per-level state is compact int/float
  arrays instead of nested Python tuples).

Weights are the ``Δ`` components (storage bytes).  Tests cross-check the MCA
against the dict-based seed implementation on random instances.

``backend="jax"`` runs the undirected case as one jitted Prim loop
(:func:`repro.core.solvers.jax_backend.prim`, bit-identical).  Directed
instances always use the host Edmonds — cycle contraction is pointer-chasing
with data-dependent shapes, unsuited to jitting (ROADMAP tracks the
mergeable-heap rewrite instead).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..edge_arrays import EdgeArrays
from ..version_graph import StorageSolution, VersionGraph


def minimum_storage_tree(
    g: VersionGraph, *, backend: str = "numpy", pallas: bool = False
) -> StorageSolution:
    """Solve Problem 1: min total storage, any finite recreation."""
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown solver backend {backend!r}")
    if g.directed:
        parent = _edmonds_mca(g)
    elif backend == "jax":
        parent = _prim_jax(g, pallas=pallas)
    else:
        parent = _prim(g)
    return StorageSolution(parent=parent, graph=g)


# ------------------------------------------------------------------- Prim MST
def _prim_jax(g: VersionGraph, *, pallas: bool = False) -> Dict[int, int]:
    from . import jax_backend

    bp = jax_backend.prim(g.arrays(), pallas=pallas)
    missing = [i for i in g.versions() if bp[i] < 0]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return {i: int(bp[i]) for i in g.versions()}


def _prim(g: VersionGraph) -> Dict[int, int]:
    ea = g.arrays()
    nv = ea.n + 1
    parent = np.full(nv, -1, dtype=np.int64)
    best = np.full(nv, np.inf, dtype=np.float64)
    in_tree = np.zeros(nv, dtype=bool)
    best[0] = 0.0
    pq: List[Tuple[float, int, int]] = [(0.0, 0, 0)]  # (w, vertex, parent)
    while pq:
        w, u, p = heapq.heappop(pq)
        if in_tree[u]:
            continue
        in_tree[u] = True
        if u != 0:
            parent[u] = p
        s, e = ea.out_range(u)
        if s == e:
            continue
        vs = ea.dst[s:e]
        ws = ea.delta[s:e]
        imp = ~in_tree[vs] & (ws < best[vs])
        if imp.any():
            vi = vs[imp]
            wi = ws[imp]
            best[vi] = wi
            for wv, vv in zip(wi.tolist(), vi.tolist()):
                heapq.heappush(pq, (wv, vv, u))
    missing = [i for i in g.versions() if parent[i] < 0]
    if missing:
        raise ValueError(f"graph disconnected; unreachable: {missing[:8]}")
    return {i: int(parent[i]) for i in g.versions()}


# ----------------------------------------- Edmonds (incremental contraction)
def _edmonds_mca(g: VersionGraph) -> Dict[int, int]:
    eids = _edmonds_arrays(g.arrays(), root=0)
    ea = g.arrays()
    parent = {int(ea.dst[e]): int(ea.src[e]) for e in eids}
    missing = [i for i in g.versions() if i not in parent]
    if missing:
        raise ValueError(f"no arborescence: unreachable {missing[:8]}")
    return parent


def _edmonds_arrays(ea: EdgeArrays, root: int = 0) -> np.ndarray:
    """Edge ids (into ``ea``) of the min-cost arborescence rooted at ``root``.

    Incremental cycle contraction: instead of rebuilding the whole edge list
    per level (O(E) per contraction — quadratic on graphs with many
    two-cycles), each contraction merges only the cycle members' in-edge
    lists.  Components are tracked in a union-find; reduced edge weights are
    applied in place to the members' in-edges; supernode in-edge selection
    filters self-loops lazily with a vectorized representative gather.
    Cheapest-in-edge ties break to the lowest edge id — the first edge in
    ``(src, dst)`` order — matching a sequential strict-`<` scan, so results
    are bit-identical to the recursive seed formulation.

    The expansion phase walks the contraction forest: each frame re-routes
    its supernode's chosen entering edge to the member it actually points
    at, then adopts the remaining members' cycle edges.
    """
    keep = (ea.dst != root) & (ea.src != ea.dst)
    eids = np.nonzero(keep)[0].astype(np.int64)
    u = ea.src[eids]
    v = ea.dst[eids]
    w_cur = ea.delta[eids].astype(np.float64).copy()

    n_base = ea.n + 1                       # vertex ids 0..n
    cap = 2 * n_base + 2                    # ≤ one supernode per contraction
    dsu = np.arange(cap, dtype=np.int64)

    def find(x: int) -> int:
        while dsu[x] != x:
            dsu[x] = dsu[dsu[x]]
            x = int(dsu[x])
        return x

    def reps_of(nodes: np.ndarray) -> np.ndarray:
        """Vectorized representative lookup (gather to fixpoint)."""
        t = dsu[nodes]
        while True:
            t2 = dsu[t]
            if (t2 == t).all():
                return t
            t = t2

    # per-group in-edge lists (filtered edge ids), grouped via reverse sort
    order = np.argsort(v, kind="stable")
    ptr = np.searchsorted(v[order], np.arange(n_base + 1, dtype=np.int64))
    in_list: Dict[int, List[np.ndarray]] = {}
    for x in range(n_base):
        if x != root:
            in_list[x] = [order[ptr[x]:ptr[x + 1]]]

    def choose_min(gr: int) -> int:
        """Min-(w, id) in-edge of group ``gr``; compacts out self-loops."""
        arrs = in_list[gr]
        cat = arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
        if cat.size:
            good = reps_of(u[cat]) != gr
            cat = cat[good]
        if cat.size == 0:
            raise ValueError(f"vertex {gr} unreachable from root")
        in_list[gr] = [cat]
        ws = w_cur[cat]
        wmin = ws.min()
        # min (w, id): lowest edge id among the exact-min weights
        return int(cat[ws == wmin].min())

    min_edge: Dict[int, int] = {}
    for x in range(n_base):
        if x != root:
            min_edge[x] = choose_min(x)

    forest_parent: Dict[int, int] = {}
    frames: List[Tuple[int, List[int], Dict[int, int]]] = []
    next_node = n_base

    # cycle hunt over the min-in functional graph, ascending starts; each
    # contraction resumes the walk from the fresh supernode
    color = np.zeros(cap, dtype=np.int8)  # 0=white 1=on path 2=done
    starts: List[int] = [x for x in range(n_base) if x != root]
    si = 0
    while si < len(starts):
        start = starts[si]
        si += 1
        if find(start) != start or color[start] == 2:
            continue
        path: List[int] = []
        x = start
        while True:
            if x == root or color[x] == 2:
                for p in path:
                    color[p] = 2
                break
            if color[x] == 1:
                ci = path.index(x)
                members = path[ci:]
                path = path[:ci]
                s = next_node
                next_node += 1
                frames.append((s, members, {m: min_edge[m] for m in members}))
                merged: List[np.ndarray] = []
                for m in members:
                    cost_m = float(w_cur[min_edge[m]])
                    for arr in in_list[m]:
                        w_cur[arr] -= cost_m
                        merged.append(arr)
                    del in_list[m]
                    del min_edge[m]
                    forest_parent[m] = s
                    dsu[m] = s
                in_list[s] = merged
                min_edge[s] = choose_min(s)
                x = s  # resume the walk from the contracted node
                continue
            color[x] = 1
            path.append(x)
            x = find(int(u[min_edge[x]]))

    # -------------------------------------------------------------- expansion
    # entry_edge: group -> chosen in-edge; start from the surviving groups
    entry_edge: Dict[int, int] = dict(min_edge)
    for s, members, min_map in reversed(frames):
        e = entry_edge.pop(s)
        # the member the entering edge actually points at: ancestor of the
        # edge head whose contraction parent is s
        x = int(v[e])
        while forest_parent.get(x) != s:
            x = forest_parent[x]
        entry_edge[x] = e
        for m in members:
            if m != x:
                entry_edge[m] = min_map[m]
    return eids[np.asarray(sorted(entry_edge.values()), dtype=np.int64)]
