"""Exact solver — branch-and-bound over parent assignments.

Stands in for the paper's Gurobi ILP (§2.3, Table 2): this container ships no
ILP solver, so we solve the same optimization exactly by DFS over parent
choices with

* incremental cycle detection (parent pointers form a functional graph);
* admissible storage lower bound: current cost + Σ over unassigned versions
  of their cheapest revealed in-edge;
* recreation-feasibility pruning: a parent p is inadmissible for v when even
  the *best possible* recreation of p (its Φ-shortest-path distance) plus
  Φ_{p,v} already exceeds θ;
* exact constraint check on completed chains;
* optional wall-clock budget — like the paper's Gurobi runs, the solver then
  reports the incumbent and whether optimality was proven.

Supports Problem 6 (min C s.t. max R ≤ θ) and Problem 5 (min C s.t. Σ R ≤ θ).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..version_graph import StorageSolution, VersionGraph
from .spt import dijkstra_arrays


@dataclasses.dataclass
class ExactResult:
    solution: Optional[StorageSolution]
    optimal: bool
    nodes_explored: int
    wall_seconds: float


def exact_min_storage(
    g: VersionGraph,
    *,
    theta_max: Optional[float] = None,
    theta_sum: Optional[float] = None,
    time_budget_s: float = 60.0,
    incumbent: Optional[StorageSolution] = None,
) -> ExactResult:
    if (theta_max is None) == (theta_sum is None):
        raise ValueError("set exactly one of theta_max / theta_sum")

    versions = list(g.versions())
    n = len(versions)
    ea = g.arrays()
    sp_phi, _ = dijkstra_arrays(ea, weight="phi")

    # candidate parents per version, cheapest-Δ first — one reverse-CSR slice
    # per version instead of a per-edge Python scan
    cand: Dict[int, List[Tuple[float, float, int]]] = {}
    for v in versions:
        eids = ea.in_edge_ids(v)
        us = ea.src[eids]
        ds = ea.delta[eids]
        ps = ea.phi[eids]
        keep = np.ones(us.shape[0], dtype=bool)
        if theta_max is not None:
            # feasibility pre-prune for the max-recreation variant: best-case
            # recreation of the parent plus this edge must stay within θ
            keep &= (us == 0) | (sp_phi[us] + ps <= theta_max + 1e-9)
        opts = [
            (float(ds[k]), float(ps[k]), int(us[k]))
            for k in np.nonzero(keep)[0].tolist()
        ]
        opts.sort()
        cand[v] = opts
    min_in = {v: (cand[v][0][0] if cand[v] else float("inf")) for v in versions}

    # order versions by descending cheapest-in-edge cost: decide the expensive,
    # most-constrained versions first for tighter early bounds.
    order = sorted(versions, key=lambda v: -min_in[v])
    suffix_lb = [0.0] * (n + 1)
    for k in range(n - 1, -1, -1):
        suffix_lb[k] = suffix_lb[k + 1] + min_in[order[k]]

    best_cost = float("inf")
    best_parent: Optional[Dict[int, int]] = None
    if incumbent is not None:
        best_cost = incumbent.storage_cost() + 1e-12
        best_parent = dict(incumbent.parent)

    parent: Dict[int, int] = {}
    t0 = time.monotonic()
    nodes = 0
    timed_out = False

    def creates_cycle(v: int, p: int) -> bool:
        x = p
        while x != 0:
            if x == v:
                return True
            nx = parent.get(x)
            if nx is None:
                return False
            x = nx
        return False

    def recreation_exact(v: int) -> Optional[float]:
        """Exact R_v if the whole chain to root is assigned, else None."""
        total = 0.0
        x = v
        while x != 0:
            p = parent.get(x)
            if p is None:
                return None
            c = g.materialization_cost(x) if p == 0 else g.cost(p, x)
            total += c.phi
            x = p
        return total

    def dfs(k: int, cost: float) -> None:
        nonlocal best_cost, best_parent, nodes, timed_out
        if timed_out or time.monotonic() - t0 > time_budget_s:
            timed_out = True
            return
        nodes += 1
        if cost + suffix_lb[k] >= best_cost - 1e-12:
            return
        if k == n:
            # all parents set: verify the recreation constraint exactly
            sol = StorageSolution(parent=dict(parent), graph=g)
            rc = sol.recreation_costs()
            if theta_max is not None and max(rc.values()) > theta_max + 1e-9:
                return
            if theta_sum is not None and sum(rc.values()) > theta_sum + 1e-9:
                return
            best_cost = cost
            best_parent = dict(parent)
            return
        v = order[k]
        for (dlt, phi, p) in cand[v]:
            if cost + dlt + suffix_lb[k + 1] >= best_cost - 1e-12:
                break  # candidates are Δ-sorted: nothing better follows
            if p != 0 and creates_cycle(v, p):
                continue
            parent[v] = p
            if theta_max is not None:
                r = recreation_exact(v)
                if r is not None and r > theta_max + 1e-9:
                    del parent[v]
                    continue
            dfs(k + 1, cost + dlt)
            del parent[v]

    dfs(0, 0.0)
    wall = time.monotonic() - t0
    sol = None
    if best_parent is not None:
        sol = StorageSolution(parent=best_parent, graph=g)
        sol.validate()
    return ExactResult(
        solution=sol,
        optimal=not timed_out and sol is not None,
        nodes_explored=nodes,
        wall_seconds=wall,
    )
