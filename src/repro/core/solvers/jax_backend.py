"""Accelerator-backed solver kernels: jitted SSSP / Prim / Modified-Prim /
LMG-scoring over the flat :class:`~repro.core.edge_arrays.EdgeArrays`.

Every solver in this package takes ``backend="numpy"|"jax"``; this module is
the ``"jax"`` implementation.  The NumPy solvers remain the default and the
oracle — the jitted paths are **bit-identical** to them (same trees, same
float costs; enforced by ``tests/test_jax_backend.py`` on the 56-instance
property suite):

* :func:`sssp` — whole-graph Bellman-Ford relaxation to fixpoint: each round
  gathers ``dist[src] + w`` over the padded in-edge CSR rows and reduces with
  the Pallas segment-min kernel, accepting only >EPS improvements — the same
  slack the heap Dijkstra applies.  Distances converge to the least fixpoint,
  which is exactly what the heap Dijkstra computes (both evaluate path costs
  as prefix-sum left-folds); parents are then extracted per vertex
  as the in-edge minimizing ``(dist[u], u)`` among those attaining the final
  distance — the heap's pop-order tie-break.
* :func:`prim` — full Prim (undirected Problem 1) as one jitted ``fori_loop``:
  vertex selection is a masked argmin (first-min == smallest id, the heap's
  ``(w, v)`` order) and each accepted vertex relaxes its padded out-row with
  one masked scatter.
* :func:`modified_prim_core` — the MP loop (Algorithm 2) jitted end to end,
  including the sequential in-tree re-parenting (lines 10–17) with its
  ancestor-chain walk as a nested ``while_loop``; the rare unreached-version
  SPT splice stays host-side in :mod:`.mp` (shared with the NumPy path).
* :func:`lmg_score_round` — the LMG per-round candidate scoring (ρ reduction
  + argmax) on device; the splice bookkeeping stays host-side in :mod:`.lmg`.

Layout: CSR rows are padded to dense ``(rows, max_degree)`` matrices (+inf /
sentinel-id filled) so every reduction is a fixed-shape row op — the root's
out-row (degree ``n``) is handled separately before the main loops.  Shapes
are bucketed (rows to powers of two, widths to multiples of 8) so jit caches
are shared across same-bucket instances.

Dtype regime (the real-accelerator contract): every device array is 32-bit —
cost matrices float32, index/id arrays int32 (TPUs have no 64-bit lanes; the
``repro.analysis`` auditor gates this module at full strength).  The device
therefore performs *selection* — which parent, which candidate, which vertex
— while every authoritative cost is recomputed host-side in float64 from the
selected structure: SPT/Prim/MP solutions derive their costs from the parent
tree and the f64 edge arrays, MP's splice state is rebuilt by
:func:`repro.core.solvers.mp` and LMG re-scores the chosen move before
committing it.  Trees still match the NumPy oracles on the whole property
suite (enforced by ``tests/test_jax_backend.py``); instances engineered with
cost gaps below f32 resolution (~1e-7 relative) may legitimately pick a
different tree of equal f64-rounded quality — that is the documented price of
the f32 regime, and the EPS relaxation slack already put such ties outside
the bit-identity contract.

``pallas=True`` routes reductions through the Pallas kernels of
:mod:`repro.kernels.segment_ops` (``interpret=True`` on CPU — correct but
slow, the interpreter executes the kernel body op by op); ``pallas=False``
(the CPU-benchmark default) lowers the same reductions through plain XLA.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from ...kernels.segment_ops import min_argmin_1d, segment_min_rows
from ..edge_arrays import EdgeArrays
from . import CONSTRAINT_TOL, EPS, BackendUnsupported

# the padded layouts are dense (rows × max_degree): refuse instances whose
# degree skew would blow that up (a hub vertex that is delta-base for a
# large fraction of versions) instead of silently exhausting memory — the
# NumPy backend handles those in CSR form; a degree-bucketed layout is the
# ROADMAP follow-on
MAX_PADDED_CELLS = 1 << 25


def _check_padded_size(nvp: int, width: int, what: str) -> None:
    if nvp * width > MAX_PADDED_CELLS:
        raise BackendUnsupported(
            f"backend='jax' padded {what} layout would need {nvp}x{width} "
            f"cells (> {MAX_PADDED_CELLS}): instance degree skew too high "
            f"for the dense row padding — use backend='numpy' (bit-identical)"
        )


def _bucket_rows(k: int) -> int:
    """Next power of two ≥ k (≥ 8): row-count buckets share jit caches."""
    b = 8
    while b < k:
        b *= 2
    return b


def _bucket_width(k: int) -> int:
    """Row widths padded to multiples of 8."""
    return max(8, -(-k // 8) * 8)


# ------------------------------------------------------------- padded layouts
@dataclasses.dataclass(frozen=True)
class PaddedRows:
    """Dense padded view of CSR rows: ``ids[r, c]`` is the c-th neighbour of
    row r (sentinel ``nvp`` past the end), weights +inf-padded.  Arrays are
    device-bound and 32-bit (f32 costs, i32 ids)."""

    nvp: int                 # bucketed row count (real rows are 0..nv-1)
    ids: np.ndarray          # int32 [nvp, D]
    w: np.ndarray            # float32 [nvp, D]
    w2: Optional[np.ndarray] = None  # second cost component, same layout


def padded_in_rows(ea: EdgeArrays, *, weight: str = "phi") -> PaddedRows:
    """In-edges per vertex (the SSSP relaxation layout): ``ids`` holds edge
    sources, ``w`` the chosen cost component."""
    nv = ea.n + 1
    nvp = _bucket_rows(nv)
    indeg = np.diff(ea.rrow_ptr[: nv + 1])
    d = _bucket_width(int(indeg.max()) if ea.m else 1)
    _check_padded_size(nvp, d, "in-edge")
    ids = np.full((nvp, d), nvp, dtype=np.int32)
    w = np.full((nvp, d), np.inf, dtype=np.float32)
    if ea.m:
        rows = np.repeat(np.arange(nv), indeg)
        cols = np.arange(ea.m) - ea.rrow_ptr[rows]
        wsrc = ea.phi if weight == "phi" else ea.delta
        ids[rows, cols] = ea.src[ea.rperm]
        w[rows, cols] = wsrc[ea.rperm]
    return PaddedRows(nvp=nvp, ids=ids, w=w)


def padded_out_rows(ea: EdgeArrays) -> Tuple[PaddedRows, np.ndarray, np.ndarray, np.ndarray]:
    """Out-edges per non-root vertex, plus the root's (degree-n) row dense:
    ``(rows, root_dst, root_delta, root_phi)`` — root arrays padded to nvp."""
    nv = ea.n + 1
    nvp = _bucket_rows(nv)
    outdeg = np.diff(ea.row_ptr[: nv + 1])
    d = _bucket_width(int(outdeg[1:].max()) if nv > 1 and outdeg[1:].size else 1)
    _check_padded_size(nvp, d, "out-edge")
    ids = np.full((nvp, d), nvp, dtype=np.int32)
    delta = np.full((nvp, d), np.inf, dtype=np.float32)
    phi = np.full((nvp, d), np.inf, dtype=np.float32)
    s1 = int(ea.row_ptr[1])
    m1 = ea.m - s1
    if m1:
        rows = np.repeat(np.arange(1, nv), outdeg[1:])
        cols = np.arange(s1, ea.m) - ea.row_ptr[rows]
        ids[rows, cols] = ea.dst[s1:]
        delta[rows, cols] = ea.delta[s1:]
        phi[rows, cols] = ea.phi[s1:]
    root_dst = np.full(nvp, nvp + 1, dtype=np.int32)  # nvp+1 => scatter-drop
    root_delta = np.full(nvp, np.inf, dtype=np.float32)
    root_phi = np.full(nvp, np.inf, dtype=np.float32)
    root_dst[:s1] = ea.dst[:s1]
    root_delta[:s1] = ea.delta[:s1]
    root_phi[:s1] = ea.phi[:s1]
    return (
        PaddedRows(nvp=nvp, ids=ids, w=delta, w2=phi),
        root_dst, root_delta, root_phi,
    )


# ------------------------------------------------------------------- (a) SSSP
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _sssp_jit(ps, pw, use_pallas):
    nvp = ps.shape[0]
    dist0 = jnp.full((nvp + 1,), jnp.inf, jnp.float32).at[0].set(0.0)

    def cond(c):
        return c[1]

    def body(c):
        dist, _ = c
        cand = dist[ps] + pw
        row = segment_min_rows(cand, use_pallas=use_pallas)
        # accept only >EPS improvements — the same slack the heap Dijkstra
        # applies, so near-tie path costs settle identically (for costs whose
        # differences are either 0 or >EPS, both equal the exact fixpoint;
        # ties *within* (0, EPS] are order-dependent in both backends and
        # outside the bit-identity contract, as they already were for the
        # NumPy-vs-seed equivalence)
        new = jnp.where(row < dist[:nvp] - EPS, row, dist[:nvp])
        return dist.at[:nvp].set(new), jnp.any(new < dist[:nvp])

    dist, _ = lax.while_loop(cond, body, (dist0, jnp.bool_(True)))
    # parent: among in-edges attaining dist[v], the min-(dist[u], u) source —
    # exactly the first entry the heap Dijkstra would have popped
    du = dist[ps]
    elig = (du + pw == dist[:nvp, None]) & jnp.isfinite(dist[:nvp, None])
    m1 = segment_min_rows(
        jnp.where(elig, du, jnp.inf), use_pallas=use_pallas
    )
    pu = jnp.min(jnp.where(elig & (du == m1[:, None]), ps, nvp + 1), axis=1)
    parent = jnp.where(jnp.isfinite(dist[:nvp]) & (pu <= nvp), pu, -1)
    return dist[:nvp], parent.at[0].set(-1)


def sssp(
    ea: EdgeArrays, *, weight: str = "phi", pallas: bool = False
) -> Tuple[np.ndarray, np.ndarray]:
    """Single-source shortest paths from the root — the jax counterpart of
    :func:`repro.core.solvers.spt.dijkstra_arrays`.  The parent tree matches
    the heap Dijkstra (same EPS tie semantics, f32 selection); the returned
    ``dist`` is the f32 fixpoint — callers wanting exact costs derive them
    from the tree (as :class:`~repro.core.version_graph.StorageSolution`
    does)."""
    rows = padded_in_rows(ea, weight=weight)
    dist, parent = _sssp_jit(
        jnp.asarray(rows.ids), jnp.asarray(rows.w), pallas
    )
    nv = ea.n + 1
    return np.asarray(dist)[:nv], np.asarray(parent)[:nv]


# ------------------------------------------------------------------- (b) Prim
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _prim_jit(pd, pw, root_dst, root_w, n, use_pallas):
    nvp = pd.shape[0]
    inf = jnp.inf
    best = jnp.full((nvp + 1,), inf, jnp.float32)
    bp = jnp.full((nvp + 1,), -1, jnp.int32)
    in_tree = jnp.zeros((nvp + 1,), jnp.bool_).at[0].set(True)
    # the root's whole out-row relaxes first (its pop is always step 0)
    best = best.at[root_dst].set(root_w, mode="drop")
    bp = bp.at[root_dst].set(0, mode="drop")

    def body(_, state):
        best, bp, in_tree = state
        key = jnp.where(in_tree[:nvp], inf, best[:nvp])
        bmin, u = min_argmin_1d(key, use_pallas=use_pallas)
        active = jnp.isfinite(bmin)
        in_tree = in_tree.at[u].set(True)
        vs = pd[u]
        ws = pw[u]
        imp = active & ~in_tree[vs] & (ws < best[vs])
        idx = jnp.where(imp, vs, nvp + 1)
        best = best.at[idx].set(ws, mode="drop")
        bp = bp.at[idx].set(u, mode="drop")
        return best, bp, in_tree

    best, bp, in_tree = lax.fori_loop(0, n, body, (best, bp, in_tree))
    return bp[:nvp]


def prim(ea: EdgeArrays, *, pallas: bool = False) -> np.ndarray:
    """Prim over the undirected instance; returns the parent array (index 0
    and unreachable vertices hold ``-1``)."""
    rows, root_dst, root_delta, _ = padded_out_rows(ea)
    bp = _prim_jit(
        jnp.asarray(rows.ids), jnp.asarray(rows.w),
        jnp.asarray(root_dst), jnp.asarray(root_delta),
        jnp.int32(ea.n), pallas,
    )
    return np.asarray(bp)[: ea.n + 1]


# -------------------------------------------------------- (c) Modified Prim
def _is_ancestor(p, anc, node):
    """Jitted ancestor-chain walk: True iff ``anc`` is on ``node``'s chain."""
    node = node.astype(p.dtype)  # argmin picks can be a narrower int

    def cond(x):
        return (x > 0) & (x != anc)

    def body(x):
        px = p[x]
        return jnp.where(px < 0, 0, px)

    return lax.while_loop(cond, body, node) == anc


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _mp_jit(pd, pdelta, pphi, root_dst, root_delta, root_phi, n, theta,
            use_pallas):
    nvp = pd.shape[0]
    inf = jnp.inf
    l = jnp.full((nvp + 1,), inf, jnp.float32).at[0].set(0.0)
    d = jnp.full((nvp + 1,), inf, jnp.float32).at[0].set(0.0)
    p = jnp.full((nvp + 1,), -1, jnp.int32)
    in_tree = jnp.zeros((nvp + 1,), jnp.bool_).at[0].set(True)
    # the root pops first: frontier-relax its whole out-row under θ
    rimp = root_phi <= theta + CONSTRAINT_TOL
    ridx = jnp.where(rimp, root_dst, nvp + 1)
    d = d.at[ridx].set(root_phi, mode="drop")
    l = l.at[ridx].set(root_delta, mode="drop")
    p = p.at[ridx].set(0, mode="drop")

    def step(_, state):
        l, d, p, in_tree = state
        key = jnp.where(in_tree[:nvp], inf, l[:nvp])
        li, vi = min_argmin_1d(key, use_pallas=use_pallas)
        active = jnp.isfinite(li)
        in_tree = in_tree.at[vi].set(in_tree[vi] | active)
        vs = pd[vi]
        it = in_tree[vs]  # snapshot used by the frontier mask below

        # in-tree re-parenting (Algorithm 2 lines 10-17): sequential, each
        # acceptance rewires the ancestor chain consulted by the next slot
        def reparent(k, s):
            l, d, p = s
            vj = vs[k]
            cdel = pdelta[vi, k]
            cphi = pphi[vi, k]
            ok = (
                active & in_tree[vj]
                & (cphi + d[vi] <= d[vj] + EPS)
                & (cdel <= l[vj] - EPS)
            )
            ok &= ~lax.cond(
                ok, lambda: _is_ancestor(p, vj, vi), lambda: jnp.bool_(True)
            )
            tgt = jnp.where(ok, vj, nvp + 1)
            p = p.at[tgt].set(vi, mode="drop")
            d = d.at[tgt].set(cphi + d[vi], mode="drop")
            l = l.at[tgt].set(cdel, mode="drop")
            return l, d, p

        # int32 bounds: Python-int bounds would trace an int64 counter under
        # an x64 audit trace even though the loop body is all 32-bit
        l, d, p = lax.fori_loop(
            jnp.int32(0), jnp.int32(pd.shape[1]), reparent, (l, d, p)
        )

        # frontier relaxation under θ — one masked row op (padding carries
        # +inf costs, so both conditions mask it out)
        dts = pdelta[vi]
        phs = pphi[vi]
        imp = (active & ~it & (phs + d[vi] <= theta + CONSTRAINT_TOL)
               & (dts < l[vs] - EPS))
        idx = jnp.where(imp, vs, nvp + 1)
        d = d.at[idx].set(phs + d[vi], mode="drop")
        l = l.at[idx].set(dts, mode="drop")
        p = p.at[idx].set(vi, mode="drop")
        return l, d, p, in_tree

    l, d, p, in_tree = lax.fori_loop(0, n, step, (l, d, p, in_tree))
    return l[:nvp], d[:nvp], p[:nvp], in_tree[:nvp]


def modified_prim_core(
    ea: EdgeArrays, theta: float, *, pallas: bool = False
) -> Tuple[np.ndarray, np.ndarray]:
    """The jitted MP main loop; returns ``(p, in_tree)`` host arrays.

    The f32 ``l``/``d`` loop state stays on device — the caller (:mod:`.mp`)
    rebuilds both in f64 from the returned structure, and routes any vertex
    whose exact recreation cost overshoots θ (possible when a borderline
    acceptance rounds the other way in f32) through the SPT splice it
    already has for unreached versions."""
    rows, root_dst, root_delta, root_phi = padded_out_rows(ea)
    _, _, p, in_tree = _mp_jit(
        jnp.asarray(rows.ids), jnp.asarray(rows.w), jnp.asarray(rows.w2),
        jnp.asarray(root_dst), jnp.asarray(root_delta),
        jnp.asarray(root_phi), jnp.int32(ea.n), jnp.float32(theta),
        pallas,
    )
    nv = ea.n + 1
    # writable copies: the caller's SPT splice mutates these in place
    return np.array(p[:nv], dtype=np.intp), np.array(in_tree[:nv])


# ------------------------------------------------------------ (d) LMG scoring
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _lmg_score_jit(cu, cv, cand_delta, cand_phi, active, cur_delta, d, mass,
                   tin, size, w_total, budget, use_pallas):
    dw = cand_delta - cur_delta[cv]
    ok = active & (w_total + dw <= budget + CONSTRAINT_TOL)
    dd = (d[cu] + cand_phi) - d[cv]
    reduction = -dd * mass[cv]
    ok &= reduction > 0
    # cycle test: u inside subtree(v) ⇔ tin[v] ≤ tin[u] < tin[v]+size[v]
    ok &= ~((tin[cv] <= tin[cu]) & (tin[cu] < tin[cv] + size[cv]))
    pos = ok & (dw > 0)
    rho = jnp.where(
        pos,
        reduction / jnp.where(pos, dw, 1.0),
        jnp.where(ok & (dw <= 0), jnp.inf, -1.0),
    )
    _, i = min_argmin_1d(-rho, use_pallas=use_pallas)
    return i, rho[i], dw[i], dd[i], jnp.any(ok)


class LmgScorer:
    """Device-resident candidate set ξ; scores one LMG round per call.

    The candidate arrays are uploaded once (f32/i32); per-round tree state
    (d / mass / tin / size / current edge Δ) is shipped each call — the
    splice bookkeeping that mutates it stays host-side in :mod:`.lmg`.  The
    device output is a *selection*: the caller recomputes the chosen move's
    Δw/Δd/ρ in f64 and re-checks feasibility before committing (the returned
    ρ/Δw/Δd here are the f32 scores, advisory only).
    """

    def __init__(self, cu, cv, cand_delta, cand_phi, *, pallas: bool = False):
        self._pallas = pallas
        self._nc = nc = cu.shape[0]
        self._ncp = ncp = _bucket_rows(max(1, nc))
        pad = lambda a, fill, dt: jnp.asarray(
            np.concatenate([a, np.full(ncp - nc, fill, dt)]).astype(dt)
        )
        self._cu = pad(cu, 0, np.int32)
        self._cv = pad(cv, 0, np.int32)
        self._cdelta = pad(cand_delta, 0.0, np.float32)
        self._cphi = pad(cand_phi, 0.0, np.float32)

    def score(self, active, cur_delta, d, mass, tin, size, w_total, budget):
        """Returns ``(i, rho_i, dw_i, dd_i, any_feasible)`` as host scalars;
        ``i`` indexes the un-padded candidate arrays; the floats are f32
        scores (selection only — recompute in f64 before mutating state)."""
        full_active = np.zeros(self._ncp, bool)
        full_active[: self._nc] = active
        # bucket the tree-state arrays like everything else, so the jit
        # cache is shared across same-bucket graph sizes
        nvp = _bucket_rows(d.shape[0])

        def padv(a, dt):
            out = np.zeros(nvp, dt)
            out[: a.shape[0]] = a
            return jnp.asarray(out)

        i, rho, dw, dd, any_ok = _lmg_score_jit(
            self._cu, self._cv, self._cdelta, self._cphi,
            jnp.asarray(full_active),
            padv(cur_delta, np.float32), padv(d, np.float32),
            padv(mass, np.float32), padv(tin, np.int32),
            padv(size, np.int32),
            jnp.float32(w_total), jnp.float32(budget), self._pallas,
        )
        return int(i), float(rho), float(dw), float(dd), bool(any_ok)
