"""MP — Modified Prim's algorithm (paper §4.2, Algorithm 2).

Targets the *max* recreation objective: Problem 6 (min C s.t. max_i R_i ≤ θ)
directly, Problem 4 (min max R_i s.t. C ≤ β) by bisecting θ.

Faithful details:
* priority queue keyed by the marginal storage cost l(V_i);
* when a dequeued vertex's edges are scanned, neighbours *already in the
  tree* may be re-parented if that lowers their storage cost without raising
  their recreation cost (the non-standard relaxation of Algorithm 2,
  lines 10–17);
* neighbours outside the tree are relaxed only if the recreation cost through
  V_i stays within θ (lines 19–24).

The frontier relaxation (the hot path) runs as one masked array op over the
dequeued vertex's CSR out-row; the rare in-tree re-parenting keeps its
sequential scan because each acceptance must consult the ancestor chain
built so far.  State lives in flat ``l`` / ``d`` / ``p`` arrays.
"""

from __future__ import annotations

import heapq
from typing import Dict, Optional

import numpy as np

from ..version_graph import StorageSolution, VersionGraph
from . import CONSTRAINT_TOL, EPS
from .mst import minimum_storage_tree
from .spt import dijkstra


class InfeasibleError(ValueError):
    pass


def _is_ancestor(p: np.ndarray, anc: int, node: int) -> bool:
    """True if ``anc`` lies on ``node``'s current parent chain."""
    x = node
    while x != 0:
        if x == anc:
            return True
        px = int(p[x])
        if px < 0:  # unset parent — chain ends
            return False
        x = px
    return False


def modified_prim(
    g: VersionGraph, theta: float, *, backend: str = "numpy",
    pallas: bool = False,
) -> StorageSolution:
    """Problem 6: min total storage subject to max_i R_i ≤ theta.

    ``backend="jax"`` runs the main loop as one jitted scan
    (:func:`repro.core.solvers.jax_backend.modified_prim_core`) whose f32
    state selects the structure; the exact f64 ``l``/``d`` are rebuilt from
    that structure below, and any vertex whose exact recreation cost lands
    above θ (a borderline f32 acceptance) is repaired through the same SPT
    splice that serves unreached versions — shared by both backends.
    """
    if backend == "jax":
        from . import jax_backend

        p, in_tree = jax_backend.modified_prim_core(
            g.arrays(), theta, pallas=pallas
        )
        l, d = _tree_costs_f64(g, p, in_tree)
        over = np.asarray(
            [i for i in g.versions()
             if in_tree[i] and d[i] > theta + CONSTRAINT_TOL],
            dtype=np.int64,
        )
        if over.shape[0]:
            in_tree[over] = False  # re-routed by the SPT splice below
    elif backend == "numpy":
        l, d, p, in_tree = _mp_core_numpy(g, theta)
    else:
        raise ValueError(f"unknown solver backend {backend!r}")
    missing = [i for i in g.versions() if not in_tree[i]]
    if missing:
        _splice_spt_paths(g, theta, missing, d, l, p, in_tree)
    sol = StorageSolution(
        parent={i: int(p[i]) for i in g.versions()}, graph=g
    )
    return sol


def _tree_costs_f64(g: VersionGraph, p, in_tree):
    """Exact ``(l, d)`` state for the parent structure ``p``.

    The jitted MP loop keeps its state in f32; everything downstream (the
    SPT splice comparisons, the θ re-check) must run on exact f64 costs, so
    they are rebuilt here from the selected tree and the f64 edge arrays.
    """
    nv = g.n + 1
    l = np.full(nv, np.inf, dtype=np.float64)
    d = np.full(nv, np.inf, dtype=np.float64)
    l[0] = d[0] = 0.0
    kids = [[] for _ in range(nv)]
    for v in range(1, nv):
        if in_tree[v] and p[v] >= 0:
            kids[int(p[v])].append(v)
    stack = [0]
    while stack:
        u = stack.pop()
        du = float(d[u])
        for v in kids[u]:
            c = g.materialization_cost(v) if u == 0 else g.cost(u, v)
            d[v] = du + c.phi
            l[v] = c.delta
            stack.append(v)
    return l, d


def _mp_core_numpy(g: VersionGraph, theta: float):
    """The heap-driven MP main loop; returns ``(l, d, p, in_tree)``."""
    ea = g.arrays()
    nv = g.n + 1
    l = np.full(nv, np.inf, dtype=np.float64)
    d = np.full(nv, np.inf, dtype=np.float64)
    p = np.full(nv, -1, dtype=np.int64)
    l[0] = d[0] = 0.0
    in_tree = np.zeros(nv, dtype=bool)
    pq = [(0.0, 0)]
    while pq:
        li, vi = heapq.heappop(pq)
        if in_tree[vi] or li > l[vi] + EPS:
            continue  # stale entry
        in_tree[vi] = True
        s, e = ea.out_range(vi)
        if s == e:
            continue
        vs = ea.dst[s:e]
        dts = ea.delta[s:e]
        phs = ea.phi[s:e]
        it = in_tree[vs]
        if it.any():
            # relaxation of in-tree nodes (lines 10-17): sequential, because
            # each acceptance rewires the ancestor chain consulted next
            dvi = float(d[vi])
            for k in np.nonzero(it)[0].tolist():
                vj = int(vs[k])
                cphi = float(phs[k])
                cdel = float(dts[k])
                if cphi + dvi <= d[vj] + EPS and cdel <= l[vj] - EPS:
                    if _is_ancestor(p, vj, vi):
                        continue  # re-parenting under a descendant would cycle
                    p[vj] = vi
                    d[vj] = cphi + dvi
                    l[vj] = cdel
        # standard frontier relaxation under the θ constraint — one masked op
        imp = ~it & (phs + d[vi] <= theta + CONSTRAINT_TOL) & (dts < l[vs] - EPS)
        if imp.any():
            vj = vs[imp]
            d[vj] = phs[imp] + d[vi]
            l[vj] = dts[imp]
            p[vj] = vi
            for lv, vv in zip(l[vj].tolist(), vj.tolist()):
                heapq.heappush(pq, (lv, vv))
    return l, d, p, in_tree


def _splice_spt_paths(g, theta, missing, d, l, p, in_tree) -> None:
    # The greedy dequeue order (by storage) can strand a version even at a
    # feasible θ, because d() along the partially-built tree may overshoot
    # where the SPT path would not.  Problem 6 is feasible iff
    # θ ≥ max_i SPT(i) (the SPT minimizes every R_i), so splice SPT paths:
    # each splice sets d to the SPT distance — never an increase for any
    # already-reached node — hence the θ invariant is preserved.
    dist, sp_parent = dijkstra(g, weight="phi")
    bad = [i for i in missing if dist.get(i, float("inf")) > theta + CONSTRAINT_TOL]
    if bad:
        raise InfeasibleError(
            f"theta={theta} infeasible: versions {bad[:5]} have SPT "
            f"recreation above the bound"
        )
    for v in missing:
        # full SPT path root→v, relaxed front to back: the running cost is
        # ≤ the SPT distance at every node (induction on path prefixes).
        path = [v]
        while path[-1] != 0:
            path.append(sp_parent[path[-1]])
        path.reverse()
        for u, x in zip(path, path[1:]):
            c = g.materialization_cost(x) if u == 0 else g.cost(u, x)
            cand = float(d[u]) + c.phi
            if not in_tree[x] or cand < d[x] - EPS:
                p[x] = u
                d[x] = cand
                l[x] = c.delta
                in_tree[x] = True


def min_max_recreation_under_budget(
    g: VersionGraph,
    budget: float,
    *,
    tol: float = 1e-3,
    max_iters: int = 48,
    backend: str = "numpy",
    pallas: bool = False,
) -> StorageSolution:
    """Problem 4: min max_i R_i subject to C ≤ budget — bisection on θ fed to
    `modified_prim` (the paper notes "the solution for Problem 4 is similar").
    """
    dist, _ = dijkstra(g, weight="phi")
    lo = max(dist[i] for i in g.versions())  # SPT bound: best achievable max R
    base = minimum_storage_tree(g, backend=backend, pallas=pallas)
    if base.storage_cost() > budget + 1e-9:
        raise InfeasibleError("budget below minimum storage cost")
    hi = base.max_recreation()
    best: Optional[StorageSolution] = None
    # check the ideal point first
    try:
        sol = modified_prim(g, lo * (1 + 1e-12), backend=backend,
                            pallas=pallas)
        if sol.storage_cost() <= budget + 1e-9:
            return sol
    except InfeasibleError:
        pass
    for _ in range(max_iters):
        mid = 0.5 * (lo + hi)
        try:
            sol = modified_prim(g, mid, backend=backend, pallas=pallas)
            feasible = sol.storage_cost() <= budget + 1e-9
        except InfeasibleError:
            feasible = False
        if feasible:
            best, hi = sol, mid
        else:
            lo = mid
        if hi - lo <= tol * max(1.0, lo):
            break
    if best is None:
        best = base  # MST/MCA always fits the budget
    return best
