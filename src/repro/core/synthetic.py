"""Synthetic dataset-versioning workload generator (paper §5.1).

Two-step approach, exactly as the paper describes:

1. generate a *version graph* with the desired shape, controlled by
   ``commits``, ``branch_interval``, ``branch_prob``, ``branch_limit``,
   ``branch_length`` (merges close a fraction of branches back to trunk);
2. generate versions' *contents* and derive Δ/Φ from them.

Contents are modelled as sets of content blocks (block id → size).  Each
commit applies edit commands to its parent — add / delete / modify blocks —
mirroring the paper's six CSV edit instructions at block granularity.  Deltas
are then *measured* from the block sets:

* directed Δ_ij  = Σ size(blocks in j \\ i) + per-edit overhead
  (what must be stored to turn V_i into V_j);
* undirected Δ_ij = Σ size(sym-diff) + overhead — a metric, so the paper's
  §3 triangle inequalities hold by construction;
* Φ = Δ · io_factor for the proportional scenarios, or Δ · per-edge random
  compute factor for Scenario 3 (Φ ≠ Δ, e.g. compressed deltas).

Presets `dc_like` / `lc_like` reproduce the flat/linear shapes of the paper's
DC and LC datasets (at configurable scale); deltas are revealed within a
``reveal_hops`` BFS radius of the version graph, like the paper's 10/25-hop
matrices.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

from .version_graph import VersionGraph


@dataclasses.dataclass
class WorkloadSpec:
    commits: int = 200
    branch_interval: int = 5
    branch_prob: float = 0.4
    branch_limit: int = 3
    branch_length: int = 8
    merge_prob: float = 0.3
    # content model
    init_blocks: int = 400
    block_size_mean: float = 2048.0
    edit_rate: float = 0.05          # fraction of blocks touched per commit
    grow_rate: float = 0.01          # net growth per commit
    edit_overhead: float = 64.0      # bytes of bookkeeping per stored delta
    # cost model
    io_factor: float = 1.0           # Φ = io_factor·Δ when proportional
    phi_independent: bool = False    # Scenario 3: Φ ≠ Δ
    compute_factor_range: Tuple[float, float] = (0.2, 5.0)
    reveal_hops: int = 10
    directed: bool = True
    seed: int = 0


def dc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Densely-connected: frequent short branches (paper's DC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=3, branch_prob=0.7, branch_limit=4,
        branch_length=4, reveal_hops=10, seed=seed, **kw,
    )


def lc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Linear-chain: rare, long branches (paper's LC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=25, branch_prob=0.2, branch_limit=1,
        branch_length=40, reveal_hops=25, seed=seed, **kw,
    )


@dataclasses.dataclass
class SyntheticWorkload:
    graph: VersionGraph
    version_dag: Dict[int, List[int]]      # derivation edges (parents per version)
    sizes: Dict[int, float]                # full size of each version
    blocks: Optional[Dict[int, Dict[int, float]]] = None


def generate(spec: WorkloadSpec) -> SyntheticWorkload:
    rng = random.Random(spec.seed)

    # ---------------------------------------------------------------- step 1
    # version DAG: trunk + branches (+ occasional merges)
    parents: Dict[int, List[int]] = {1: []}
    trunk = [1]
    open_branches: List[List[int]] = []
    next_id = 2
    while next_id <= spec.commits:
        # advance trunk
        v = next_id
        next_id += 1
        parents[v] = [trunk[-1]]
        trunk.append(v)
        # maybe branch off
        if len(trunk) % spec.branch_interval == 0 and rng.random() < spec.branch_prob:
            n_branches = rng.randint(1, spec.branch_limit)
            for _ in range(n_branches):
                if next_id > spec.commits:
                    break
                length = rng.randint(1, spec.branch_length)
                prev = trunk[-1]
                branch = []
                for _ in range(length):
                    if next_id > spec.commits:
                        break
                    b = next_id
                    next_id += 1
                    parents[b] = [prev]
                    branch.append(b)
                    prev = b
                if branch:
                    open_branches.append(branch)
        # maybe merge a finished branch into trunk
        if open_branches and rng.random() < spec.merge_prob and next_id <= spec.commits:
            branch = open_branches.pop(rng.randrange(len(open_branches)))
            m = next_id
            next_id += 1
            parents[m] = [trunk[-1], branch[-1]]
            trunk.append(m)

    n = len(parents)

    # ---------------------------------------------------------------- step 2
    # contents: block sets evolved by edit commands
    blocks: Dict[int, Dict[int, float]] = {}
    block_id = [0]

    def new_block() -> Tuple[int, float]:
        block_id[0] += 1
        size = max(64.0, rng.gauss(spec.block_size_mean, spec.block_size_mean / 4))
        return block_id[0], size

    for v in sorted(parents):
        ps = parents[v]
        if not ps:
            blk = dict(new_block() for _ in range(spec.init_blocks))
        else:
            base = dict(blocks[ps[0]])
            if len(ps) > 1:  # merge: union of parents
                for b, s in blocks[ps[1]].items():
                    base.setdefault(b, s)
            n_edit = max(1, int(len(base) * spec.edit_rate))
            ids = list(base)
            # modify: delete + re-add as new ids
            for b in rng.sample(ids, min(n_edit, len(ids))):
                del base[b]
                nb, s = new_block()
                base[nb] = s
            # net growth: linear in the base size (compounding on the current
            # size explodes exponentially over long histories)
            for _ in range(max(0, int(spec.init_blocks * spec.grow_rate))):
                nb, s = new_block()
                base[nb] = s
            blk = base
        blocks[v] = blk

    sizes = {v: sum(blk.values()) for v, blk in blocks.items()}

    # ------------------------------------------------------------- reveal Δ/Φ
    g = VersionGraph(n, directed=spec.directed)

    def phi_of(delta: float) -> float:
        if spec.phi_independent:
            lo, hi = spec.compute_factor_range
            return delta * rng.uniform(lo, hi)
        return delta * spec.io_factor

    for v in g.versions():
        g.set_materialization(v, sizes[v], phi_of(sizes[v]))

    # BFS within reveal_hops over the *undirected* version DAG
    adj: Dict[int, Set[int]] = {v: set() for v in parents}
    for v, ps in parents.items():
        for p in ps:
            adj[v].add(p)
            adj[p].add(v)

    revealed = set()
    for src in g.versions():
        frontier = {src}
        seen = {src}
        for _ in range(spec.reveal_hops):
            frontier = {y for x in frontier for y in adj[x]} - seen
            seen |= frontier
            if not frontier:
                break
        for dst in seen - {src}:
            key = (src, dst) if spec.directed else (min(src, dst), max(src, dst))
            if key in revealed:
                continue
            revealed.add(key)
            a, b = blocks[src], blocks[dst]
            fwd = sum(s for bid, s in b.items() if bid not in a)
            if spec.directed:
                d = fwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))
                bwd = sum(s for bid, s in a.items() if bid not in b) + spec.edit_overhead
                if (dst, src) not in revealed:
                    revealed.add((dst, src))
                    g.set_delta(dst, src, bwd, phi_of(bwd))
            else:
                bwd = sum(s for bid, s in a.items() if bid not in b)
                d = fwd + bwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))

    dag = {v: list(ps) for v, ps in parents.items()}
    return SyntheticWorkload(graph=g, version_dag=dag, sizes=sizes, blocks=blocks)


def zipf_weights(n: int, exponent: float = 2.0, seed: int = 0) -> Dict[int, float]:
    """Zipfian access frequencies over versions (paper Fig. 16 workload)."""
    rng = random.Random(seed)
    ranks = list(range(1, n + 1))
    rng.shuffle(ranks)
    raw = [1.0 / (r ** exponent) for r in ranks]
    z = sum(raw)
    return {i + 1: raw[i] / z for i in range(n)}
