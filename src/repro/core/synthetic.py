"""Synthetic dataset-versioning workload generator (paper §5.1).

Two-step approach, exactly as the paper describes:

1. generate a *version graph* with the desired shape, controlled by
   ``commits``, ``branch_interval``, ``branch_prob``, ``branch_limit``,
   ``branch_length`` (merges close a fraction of branches back to trunk);
2. generate versions' *contents* and derive Δ/Φ from them.

Contents are modelled as sets of content blocks (block id → size).  Each
commit applies edit commands to its parent — add / delete / modify blocks —
mirroring the paper's six CSV edit instructions at block granularity.  Deltas
are then *measured* from the block sets:

* directed Δ_ij  = Σ size(blocks in j \\ i) + per-edit overhead
  (what must be stored to turn V_i into V_j);
* undirected Δ_ij = Σ size(sym-diff) + overhead — a metric, so the paper's
  §3 triangle inequalities hold by construction;
* Φ = Δ · io_factor for the proportional scenarios, or Δ · per-edge random
  compute factor for Scenario 3 (Φ ≠ Δ, e.g. compressed deltas).

Presets `dc_like` / `lc_like` reproduce the flat/linear shapes of the paper's
DC and LC datasets (at configurable scale); deltas are revealed within a
``reveal_hops`` BFS radius of the version graph, like the paper's 10/25-hop
matrices.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .version_graph import VersionGraph


@dataclasses.dataclass
class WorkloadSpec:
    commits: int = 200
    branch_interval: int = 5
    branch_prob: float = 0.4
    branch_limit: int = 3
    branch_length: int = 8
    merge_prob: float = 0.3
    # content model
    init_blocks: int = 400
    block_size_mean: float = 2048.0
    edit_rate: float = 0.05          # fraction of blocks touched per commit
    grow_rate: float = 0.01          # net growth per commit
    edit_overhead: float = 64.0      # bytes of bookkeeping per stored delta
    # cost model
    io_factor: float = 1.0           # Φ = io_factor·Δ when proportional
    phi_independent: bool = False    # Scenario 3: Φ ≠ Δ
    compute_factor_range: Tuple[float, float] = (0.2, 5.0)
    reveal_hops: int = 10
    directed: bool = True
    seed: int = 0


def dc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Densely-connected: frequent short branches (paper's DC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=3, branch_prob=0.7, branch_limit=4,
        branch_length=4, reveal_hops=10, seed=seed, **kw,
    )


def lc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Linear-chain: rare, long branches (paper's LC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=25, branch_prob=0.2, branch_limit=1,
        branch_length=40, reveal_hops=25, seed=seed, **kw,
    )


@dataclasses.dataclass
class SyntheticWorkload:
    graph: VersionGraph
    version_dag: Dict[int, List[int]]      # derivation edges (parents per version)
    sizes: Dict[int, float]                # full size of each version
    blocks: Optional[Dict[int, Dict[int, float]]] = None


def _build_dag(spec: WorkloadSpec, rng: random.Random) -> Dict[int, List[int]]:
    """Step 1: the version DAG — trunk + branches (+ occasional merges)."""
    parents: Dict[int, List[int]] = {1: []}
    trunk = [1]
    open_branches: List[List[int]] = []
    next_id = 2
    while next_id <= spec.commits:
        # advance trunk
        v = next_id
        next_id += 1
        parents[v] = [trunk[-1]]
        trunk.append(v)
        # maybe branch off
        if len(trunk) % spec.branch_interval == 0 and rng.random() < spec.branch_prob:
            n_branches = rng.randint(1, spec.branch_limit)
            for _ in range(n_branches):
                if next_id > spec.commits:
                    break
                length = rng.randint(1, spec.branch_length)
                prev = trunk[-1]
                branch = []
                for _ in range(length):
                    if next_id > spec.commits:
                        break
                    b = next_id
                    next_id += 1
                    parents[b] = [prev]
                    branch.append(b)
                    prev = b
                if branch:
                    open_branches.append(branch)
        # maybe merge a finished branch into trunk
        if open_branches and rng.random() < spec.merge_prob and next_id <= spec.commits:
            branch = open_branches.pop(rng.randrange(len(open_branches)))
            m = next_id
            next_id += 1
            parents[m] = [trunk[-1], branch[-1]]
            trunk.append(m)
    return parents


def generate(spec: WorkloadSpec) -> SyntheticWorkload:
    rng = random.Random(spec.seed)

    parents = _build_dag(spec, rng)
    n = len(parents)

    # ---------------------------------------------------------------- step 2
    # contents: block sets evolved by edit commands
    blocks: Dict[int, Dict[int, float]] = {}
    block_id = [0]

    def new_block() -> Tuple[int, float]:
        block_id[0] += 1
        size = max(64.0, rng.gauss(spec.block_size_mean, spec.block_size_mean / 4))
        return block_id[0], size

    for v in sorted(parents):
        ps = parents[v]
        if not ps:
            blk = dict(new_block() for _ in range(spec.init_blocks))
        else:
            base = dict(blocks[ps[0]])
            if len(ps) > 1:  # merge: union of parents
                for b, s in blocks[ps[1]].items():
                    base.setdefault(b, s)
            n_edit = max(1, int(len(base) * spec.edit_rate))
            ids = list(base)
            # modify: delete + re-add as new ids
            for b in rng.sample(ids, min(n_edit, len(ids))):
                del base[b]
                nb, s = new_block()
                base[nb] = s
            # net growth: linear in the base size (compounding on the current
            # size explodes exponentially over long histories)
            for _ in range(max(0, int(spec.init_blocks * spec.grow_rate))):
                nb, s = new_block()
                base[nb] = s
            blk = base
        blocks[v] = blk

    sizes = {v: sum(blk.values()) for v, blk in blocks.items()}

    # ------------------------------------------------------------- reveal Δ/Φ
    g = VersionGraph(n, directed=spec.directed)

    def phi_of(delta: float) -> float:
        if spec.phi_independent:
            lo, hi = spec.compute_factor_range
            return delta * rng.uniform(lo, hi)
        return delta * spec.io_factor

    for v in g.versions():
        g.set_materialization(v, sizes[v], phi_of(sizes[v]))

    # BFS within reveal_hops over the *undirected* version DAG
    adj: Dict[int, Set[int]] = {v: set() for v in parents}
    for v, ps in parents.items():
        for p in ps:
            adj[v].add(p)
            adj[p].add(v)

    revealed = set()
    for src in g.versions():
        frontier = {src}
        seen = {src}
        for _ in range(spec.reveal_hops):
            frontier = {y for x in frontier for y in adj[x]} - seen
            seen |= frontier
            if not frontier:
                break
        for dst in seen - {src}:
            key = (src, dst) if spec.directed else (min(src, dst), max(src, dst))
            if key in revealed:
                continue
            revealed.add(key)
            a, b = blocks[src], blocks[dst]
            fwd = sum(s for bid, s in b.items() if bid not in a)
            if spec.directed:
                d = fwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))
                bwd = sum(s for bid, s in a.items() if bid not in b) + spec.edit_overhead
                if (dst, src) not in revealed:
                    revealed.add((dst, src))
                    g.set_delta(dst, src, bwd, phi_of(bwd))
            else:
                bwd = sum(s for bid, s in a.items() if bid not in b)
                d = fwd + bwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))

    dag = {v: list(ps) for v, ps in parents.items()}
    return SyntheticWorkload(graph=g, version_dag=dag, sizes=sizes, blocks=blocks)


def generate_flat(spec: WorkloadSpec) -> SyntheticWorkload:
    """Array-native workload generator for 50k–100k-version instances.

    Same two-step shape as :func:`generate` — identical version-DAG builder,
    then Δ/Φ revealed within a ``reveal_hops`` ball — but the content model
    is scalar instead of per-block: each commit carries an *added* and a
    *deleted* byte volume relative to its first parent, and a delta between
    two versions accumulates those volumes along the DAG path between them
    (down-steps contribute the child's additions, up-steps the departed
    version's deletions).  That keeps the path-metric structure (undirected
    deltas satisfy the §3 triangle inequalities by construction) while
    skipping the per-version block dictionaries that make :func:`generate`
    infeasible beyond a few thousand commits.

    Edges are bulk-loaded straight into the flat
    :class:`~repro.core.edge_arrays.EdgeArrays` representation — no per-edge
    Python dict traffic — so ``benchmarks/solver_scale.py`` can sweep
    100k-version graphs.  ``blocks`` is ``None`` in the returned workload.
    """
    rng = random.Random(spec.seed)
    parents = _build_dag(spec, rng)
    n = len(parents)

    # scalar content model: per-commit added/deleted volumes and full sizes
    nrng = np.random.default_rng(spec.seed)
    base_size = spec.init_blocks * spec.block_size_mean
    n_edit = max(1, int(spec.init_blocks * spec.edit_rate))
    n_grow = max(0, int(spec.init_blocks * spec.grow_rate))
    noise = spec.block_size_mean / 4
    # modify = delete + re-add (both sides of the diff); growth adds only
    mod_vol = np.maximum(
        64.0, nrng.normal(spec.block_size_mean, noise, size=n + 1)
    ) * n_edit
    grow_vol = np.maximum(
        64.0, nrng.normal(spec.block_size_mean, noise, size=n + 1)
    ) * n_grow
    added = np.zeros(n + 1)
    deleted = np.zeros(n + 1)
    sizes_arr = np.zeros(n + 1)
    sizes_arr[1] = base_size
    for v in range(2, n + 1):
        p0 = parents[v][0]
        added[v] = mod_vol[v] + grow_vol[v]
        deleted[v] = mod_vol[v]
        sizes_arr[v] = sizes_arr[p0] + grow_vol[v]

    def phi_of(delta: np.ndarray) -> np.ndarray:
        if spec.phi_independent:
            lo, hi = spec.compute_factor_range
            return delta * nrng.uniform(lo, hi, size=delta.shape)
        return delta * spec.io_factor

    g = VersionGraph(n, directed=spec.directed)
    vs = np.arange(1, n + 1, dtype=np.int64)
    g.add_edges_bulk(
        np.zeros(n, dtype=np.int64), vs, sizes_arr[1:], phi_of(sizes_arr[1:])
    )

    # BFS within reveal_hops over the *undirected* version DAG, carrying the
    # (fwd, bwd) accumulated volumes per reached vertex
    adj: Dict[int, List[Tuple[int, float, float]]] = {v: [] for v in parents}
    for v, ps in parents.items():
        for p in ps:
            # step p→v descends to v ; step v→p ascends out of v
            adj[p].append((v, float(added[v]), float(deleted[v])))
            adj[v].append((p, float(deleted[v]), float(added[v])))

    e_src: List[int] = []
    e_dst: List[int] = []
    e_fwd: List[float] = []
    e_bwd: List[float] = []
    for src in range(1, n + 1):
        seen = {src}
        frontier: List[Tuple[int, float, float]] = [(src, 0.0, 0.0)]
        for _ in range(spec.reveal_hops):
            nxt: List[Tuple[int, float, float]] = []
            for x, fwd, bwd in frontier:
                for y, step_fwd, step_bwd in adj[x]:
                    if y in seen:
                        continue
                    seen.add(y)
                    nxt.append((y, fwd + step_fwd, bwd + step_bwd))
            if not nxt:
                break
            for y, fwd, bwd in nxt:
                if spec.directed or src < y:  # undirected pairs revealed once
                    e_src.append(src)
                    e_dst.append(y)
                    e_fwd.append(fwd)
                    e_bwd.append(bwd)
            frontier = nxt

    src_a = np.asarray(e_src, dtype=np.int64)
    dst_a = np.asarray(e_dst, dtype=np.int64)
    fwd_a = np.asarray(e_fwd, dtype=np.float64)
    bwd_a = np.asarray(e_bwd, dtype=np.float64)
    if spec.directed:
        d_fwd = fwd_a + spec.edit_overhead
        d_bwd = bwd_a + spec.edit_overhead
        g.add_edges_bulk(src_a, dst_a, d_fwd, phi_of(d_fwd))
        g.add_edges_bulk(dst_a, src_a, d_bwd, phi_of(d_bwd))
    else:
        d_sym = fwd_a + bwd_a + spec.edit_overhead
        g.add_edges_bulk(src_a, dst_a, d_sym, phi_of(d_sym), mirror=True)

    dag = {v: list(ps) for v, ps in parents.items()}
    sizes = {v: float(sizes_arr[v]) for v in range(1, n + 1)}
    return SyntheticWorkload(graph=g, version_dag=dag, sizes=sizes, blocks=None)


def zipf_weights(n: int, exponent: float = 2.0, seed: int = 0) -> Dict[int, float]:
    """Zipfian access frequencies over versions (paper Fig. 16 workload)."""
    rng = random.Random(seed)
    ranks = list(range(1, n + 1))
    rng.shuffle(ranks)
    raw = [1.0 / (r ** exponent) for r in ranks]
    z = sum(raw)
    return {i + 1: raw[i] / z for i in range(n)}
