"""Synthetic dataset-versioning workload generator (paper §5.1).

Two-step approach, exactly as the paper describes:

1. generate a *version graph* with the desired shape, controlled by
   ``commits``, ``branch_interval``, ``branch_prob``, ``branch_limit``,
   ``branch_length`` (merges close a fraction of branches back to trunk);
2. generate versions' *contents* and derive Δ/Φ from them.

Contents are modelled as sets of content blocks (block id → size).  Each
commit applies edit commands to its parent — add / delete / modify blocks —
mirroring the paper's six CSV edit instructions at block granularity.  Deltas
are then *measured* from the block sets:

* directed Δ_ij  = Σ size(blocks in j \\ i) + per-edit overhead
  (what must be stored to turn V_i into V_j);
* undirected Δ_ij = Σ size(sym-diff) + overhead — a metric, so the paper's
  §3 triangle inequalities hold by construction;
* Φ = Δ · io_factor for the proportional scenarios, or Δ · per-edge random
  compute factor for Scenario 3 (Φ ≠ Δ, e.g. compressed deltas).

Presets `dc_like` / `lc_like` reproduce the flat/linear shapes of the paper's
DC and LC datasets (at configurable scale); deltas are revealed within a
``reveal_hops`` BFS radius of the version graph, like the paper's 10/25-hop
matrices.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from .version_graph import VersionGraph


@dataclasses.dataclass
class WorkloadSpec:
    commits: int = 200
    branch_interval: int = 5
    branch_prob: float = 0.4
    branch_limit: int = 3
    branch_length: int = 8
    merge_prob: float = 0.3
    # content model
    init_blocks: int = 400
    block_size_mean: float = 2048.0
    edit_rate: float = 0.05          # fraction of blocks touched per commit
    grow_rate: float = 0.01          # net growth per commit
    edit_overhead: float = 64.0      # bytes of bookkeeping per stored delta
    # cost model
    io_factor: float = 1.0           # Φ = io_factor·Δ when proportional
    phi_independent: bool = False    # Scenario 3: Φ ≠ Δ
    compute_factor_range: Tuple[float, float] = (0.2, 5.0)
    reveal_hops: int = 10
    directed: bool = True
    seed: int = 0


def dc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Densely-connected: frequent short branches (paper's DC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=3, branch_prob=0.7, branch_limit=4,
        branch_length=4, reveal_hops=10, seed=seed, **kw,
    )


def lc_like(n: int = 200, seed: int = 0, **kw) -> WorkloadSpec:
    """Linear-chain: rare, long branches (paper's LC shape)."""
    return WorkloadSpec(
        commits=n, branch_interval=25, branch_prob=0.2, branch_limit=1,
        branch_length=40, reveal_hops=25, seed=seed, **kw,
    )


@dataclasses.dataclass
class SyntheticWorkload:
    graph: VersionGraph
    version_dag: Dict[int, List[int]]      # derivation edges (parents per version)
    sizes: Dict[int, float]                # full size of each version
    blocks: Optional[Dict[int, Dict[int, float]]] = None


def _build_dag(spec: WorkloadSpec, rng: random.Random) -> Dict[int, List[int]]:
    """Step 1: the version DAG — trunk + branches (+ occasional merges)."""
    parents: Dict[int, List[int]] = {1: []}
    trunk = [1]
    open_branches: List[List[int]] = []
    next_id = 2
    while next_id <= spec.commits:
        # advance trunk
        v = next_id
        next_id += 1
        parents[v] = [trunk[-1]]
        trunk.append(v)
        # maybe branch off
        if len(trunk) % spec.branch_interval == 0 and rng.random() < spec.branch_prob:
            n_branches = rng.randint(1, spec.branch_limit)
            for _ in range(n_branches):
                if next_id > spec.commits:
                    break
                length = rng.randint(1, spec.branch_length)
                prev = trunk[-1]
                branch = []
                for _ in range(length):
                    if next_id > spec.commits:
                        break
                    b = next_id
                    next_id += 1
                    parents[b] = [prev]
                    branch.append(b)
                    prev = b
                if branch:
                    open_branches.append(branch)
        # maybe merge a finished branch into trunk
        if open_branches and rng.random() < spec.merge_prob and next_id <= spec.commits:
            branch = open_branches.pop(rng.randrange(len(open_branches)))
            m = next_id
            next_id += 1
            parents[m] = [trunk[-1], branch[-1]]
            trunk.append(m)
    return parents


def generate(spec: WorkloadSpec) -> SyntheticWorkload:
    rng = random.Random(spec.seed)

    parents = _build_dag(spec, rng)
    n = len(parents)

    # ---------------------------------------------------------------- step 2
    # contents: block sets evolved by edit commands
    blocks: Dict[int, Dict[int, float]] = {}
    block_id = [0]

    def new_block() -> Tuple[int, float]:
        block_id[0] += 1
        size = max(64.0, rng.gauss(spec.block_size_mean, spec.block_size_mean / 4))
        return block_id[0], size

    for v in sorted(parents):
        ps = parents[v]
        if not ps:
            blk = dict(new_block() for _ in range(spec.init_blocks))
        else:
            base = dict(blocks[ps[0]])
            if len(ps) > 1:  # merge: union of parents
                for b, s in blocks[ps[1]].items():
                    base.setdefault(b, s)
            n_edit = max(1, int(len(base) * spec.edit_rate))
            ids = list(base)
            # modify: delete + re-add as new ids
            for b in rng.sample(ids, min(n_edit, len(ids))):
                del base[b]
                nb, s = new_block()
                base[nb] = s
            # net growth: linear in the base size (compounding on the current
            # size explodes exponentially over long histories)
            for _ in range(max(0, int(spec.init_blocks * spec.grow_rate))):
                nb, s = new_block()
                base[nb] = s
            blk = base
        blocks[v] = blk

    sizes = {v: sum(blk.values()) for v, blk in blocks.items()}

    # ------------------------------------------------------------- reveal Δ/Φ
    g = VersionGraph(n, directed=spec.directed)

    def phi_of(delta: float) -> float:
        if spec.phi_independent:
            lo, hi = spec.compute_factor_range
            return delta * rng.uniform(lo, hi)
        return delta * spec.io_factor

    for v in g.versions():
        g.set_materialization(v, sizes[v], phi_of(sizes[v]))

    # BFS within reveal_hops over the *undirected* version DAG
    adj: Dict[int, Set[int]] = {v: set() for v in parents}
    for v, ps in parents.items():
        for p in ps:
            adj[v].add(p)
            adj[p].add(v)

    revealed = set()
    for src in g.versions():
        frontier = {src}
        seen = {src}
        for _ in range(spec.reveal_hops):
            frontier = {y for x in frontier for y in adj[x]} - seen
            seen |= frontier
            if not frontier:
                break
        for dst in seen - {src}:
            key = (src, dst) if spec.directed else (min(src, dst), max(src, dst))
            if key in revealed:
                continue
            revealed.add(key)
            a, b = blocks[src], blocks[dst]
            fwd = sum(s for bid, s in b.items() if bid not in a)
            if spec.directed:
                d = fwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))
                bwd = sum(s for bid, s in a.items() if bid not in b) + spec.edit_overhead
                if (dst, src) not in revealed:
                    revealed.add((dst, src))
                    g.set_delta(dst, src, bwd, phi_of(bwd))
            else:
                bwd = sum(s for bid, s in a.items() if bid not in b)
                d = fwd + bwd + spec.edit_overhead
                g.set_delta(src, dst, d, phi_of(d))

    dag = {v: list(ps) for v, ps in parents.items()}
    return SyntheticWorkload(graph=g, version_dag=dag, sizes=sizes, blocks=blocks)


def generate_flat(spec: WorkloadSpec) -> SyntheticWorkload:
    """Array-native workload generator for 500k–1M-version instances.

    Same two-step shape as :func:`generate` — identical version-DAG builder,
    then Δ/Φ revealed within a ``reveal_hops`` ball — but the content model
    is scalar instead of per-block: each commit carries an *added* and a
    *deleted* byte volume relative to its first parent, and a delta between
    two versions accumulates those volumes along the DAG path between them
    (down-steps contribute the child's additions, up-steps the departed
    version's deletions).  That keeps the path-metric structure (undirected
    deltas satisfy the §3 triangle inequalities by construction) while
    skipping the per-version block dictionaries that make :func:`generate`
    infeasible beyond a few thousand commits.

    The reveal ball itself is computed as an *all-sources* vectorized hop
    expansion over a CSR adjacency of the DAG: each hop expands the whole
    ``(src, node)`` frontier with ``np.repeat`` gathers and dedups first
    reaches by ``src·(n+1)+node`` key against a sorted seen-array — no
    per-source Python BFS, no per-edge Python objects.  First-reach
    tie-breaks and float accumulation order match the scalar reference
    semantics exactly (``tests/test_array_refactor.py`` pins this against a
    naive per-source BFS), so instances are byte-identical to what the old
    Python loop produced.

    Edges are bulk-loaded straight into the flat
    :class:`~repro.core.edge_arrays.EdgeArrays` representation, so
    ``benchmarks/solver_scale.py`` can sweep 1M-version graphs.  ``blocks``
    is ``None`` in the returned workload.
    """
    rng = random.Random(spec.seed)
    parents = _build_dag(spec, rng)
    n = len(parents)

    # scalar content model: per-commit added/deleted volumes and full sizes
    nrng = np.random.default_rng(spec.seed)
    base_size = spec.init_blocks * spec.block_size_mean
    n_edit = max(1, int(spec.init_blocks * spec.edit_rate))
    n_grow = max(0, int(spec.init_blocks * spec.grow_rate))
    noise = spec.block_size_mean / 4
    # modify = delete + re-add (both sides of the diff); growth adds only
    mod_vol = np.maximum(
        64.0, nrng.normal(spec.block_size_mean, noise, size=n + 1)
    ) * n_edit
    grow_vol = np.maximum(
        64.0, nrng.normal(spec.block_size_mean, noise, size=n + 1)
    ) * n_grow
    added = np.zeros(n + 1)
    deleted = np.zeros(n + 1)
    sizes_arr = np.zeros(n + 1)
    sizes_arr[1] = base_size
    for v in range(2, n + 1):
        p0 = parents[v][0]
        added[v] = mod_vol[v] + grow_vol[v]
        deleted[v] = mod_vol[v]
        sizes_arr[v] = sizes_arr[p0] + grow_vol[v]

    def phi_of(delta: np.ndarray) -> np.ndarray:
        if spec.phi_independent:
            lo, hi = spec.compute_factor_range
            return delta * nrng.uniform(lo, hi, size=delta.shape)
        return delta * spec.io_factor

    g = VersionGraph(n, directed=spec.directed)
    vs = np.arange(1, n + 1, dtype=np.int64)
    g.add_edges_bulk(
        np.zeros(n, dtype=np.int64), vs, sizes_arr[1:], phi_of(sizes_arr[1:])
    )

    # reveal within reveal_hops over the *undirected* version DAG, carrying
    # the (fwd, bwd) accumulated volumes per reached (src, node) pair; all
    # sources expand together, one vectorized hop at a time
    src_a, dst_a, fwd_a, bwd_a = _reveal_ball_arrays(
        parents, added, deleted, n, spec.reveal_hops, spec.directed
    )
    if spec.directed:
        d_fwd = fwd_a + spec.edit_overhead
        d_bwd = bwd_a + spec.edit_overhead
        g.add_edges_bulk(src_a, dst_a, d_fwd, phi_of(d_fwd))
        g.add_edges_bulk(dst_a, src_a, d_bwd, phi_of(d_bwd))
    else:
        d_sym = fwd_a + bwd_a + spec.edit_overhead
        g.add_edges_bulk(src_a, dst_a, d_sym, phi_of(d_sym), mirror=True)

    dag = {v: list(ps) for v, ps in parents.items()}
    sizes = {v: float(sizes_arr[v]) for v in range(1, n + 1)}
    return SyntheticWorkload(graph=g, version_dag=dag, sizes=sizes, blocks=None)


def _reveal_ball_arrays(
    parents: Dict[int, List[int]],
    added: np.ndarray,
    deleted: np.ndarray,
    n: int,
    reveal_hops: int,
    directed: bool,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """All-sources vectorized reveal-ball expansion; see :func:`generate_flat`.

    Returns ``(src, dst, fwd, bwd)`` arrays of every first-reach within
    ``reveal_hops`` (undirected specs keep only ``src < dst``), in the exact
    order the per-source Python BFS used to append them (source-major, hops
    ascending within a source) — downstream Φ draws consume the arrays in
    that order, so instance bytes are unchanged.

    Semantics pinned to the reference BFS: within a hop, a node reached by
    several frontier entries keeps the *first* in (frontier-position,
    adjacency-order); its accumulated volumes are ``parent's + step's``, one
    float add per hop, so values are bit-equal to the sequential loop.
    """
    # flatten the undirected adjacency, preserving the reference insertion
    # order (the v-ascending parents iteration) for tie-break parity
    a_node: List[int] = []
    a_nbr: List[int] = []
    a_fwd: List[float] = []
    a_bwd: List[float] = []
    for v, ps in parents.items():
        av = float(added[v])
        dv = float(deleted[v])
        for p in ps:
            # step p→v descends to v ; step v→p ascends out of v
            a_node.append(p)
            a_nbr.append(v)
            a_fwd.append(av)
            a_bwd.append(dv)
            a_node.append(v)
            a_nbr.append(p)
            a_fwd.append(dv)
            a_bwd.append(av)
    node_arr = np.asarray(a_node, dtype=np.int64)
    adj_order = np.argsort(node_arr, kind="stable")
    adj_nbr = np.asarray(a_nbr, dtype=np.int64)[adj_order]
    adj_fwd = np.asarray(a_fwd, dtype=np.float64)[adj_order]
    adj_bwd = np.asarray(a_bwd, dtype=np.float64)[adj_order]
    adj_ptr = np.searchsorted(
        node_arr[adj_order], np.arange(n + 2, dtype=np.int64)
    )

    stride = np.int64(n + 1)  # (src, node) -> scalar dedup key
    fsrc = np.arange(1, n + 1, dtype=np.int64)
    fnode = fsrc.copy()
    ffwd = np.zeros(n, dtype=np.float64)
    fbwd = np.zeros(n, dtype=np.float64)
    seen = np.sort(fsrc * stride + fnode)

    chunks: List[Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]] = []
    for _ in range(reveal_hops):
        deg = adj_ptr[fnode + 1] - adj_ptr[fnode]
        total = int(deg.sum())
        if total == 0:
            break
        rep = np.repeat(np.arange(fnode.shape[0], dtype=np.int64), deg)
        base = np.concatenate(
            ([0], np.cumsum(deg[:-1]))
        ) if deg.shape[0] else np.zeros(0, dtype=np.int64)
        eidx = adj_ptr[fnode][rep] + (np.arange(total, dtype=np.int64) - base[rep])
        cand_src = fsrc[rep]
        cand_node = adj_nbr[eidx]
        key = cand_src * stride + cand_node
        # first reaches only: not seen in earlier hops, first in-batch winner
        pos = np.minimum(np.searchsorted(seen, key), seen.shape[0] - 1)
        fresh = np.nonzero(seen[pos] != key)[0]
        uniq_keys, first = np.unique(key[fresh], return_index=True)
        sel = fresh[np.sort(first)]
        if sel.shape[0] == 0:
            break
        fsrc = cand_src[sel]
        fnode = cand_node[sel]
        ffwd = ffwd[rep[sel]] + adj_fwd[eidx[sel]]
        fbwd = fbwd[rep[sel]] + adj_bwd[eidx[sel]]
        seen = np.sort(np.concatenate([seen, uniq_keys]))
        if directed:
            chunks.append((fsrc, fnode, ffwd, fbwd))
        else:
            m = fsrc < fnode  # undirected pairs revealed once
            chunks.append((fsrc[m], fnode[m], ffwd[m], fbwd[m]))

    if not chunks:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0), np.zeros(0)
    src_a = np.concatenate([c[0] for c in chunks])
    dst_a = np.concatenate([c[1] for c in chunks])
    fwd_a = np.concatenate([c[2] for c in chunks])
    bwd_a = np.concatenate([c[3] for c in chunks])
    # hop-major -> source-major, preserving per-source hop order (the exact
    # append order of the reference BFS, which the Φ RNG stream depends on)
    out = np.argsort(src_a, kind="stable")
    return src_a[out], dst_a[out], fwd_a[out], bwd_a[out]


def zipf_weights(n: int, exponent: float = 2.0, seed: int = 0) -> Dict[int, float]:
    """Zipfian access frequencies over versions (paper Fig. 16 workload)."""
    rng = random.Random(seed)
    ranks = list(range(1, n + 1))
    rng.shuffle(ranks)
    raw = [1.0 / (r ** exponent) for r in ranks]
    z = sum(raw)
    return {i + 1: raw[i] / z for i in range(n)}
