"""Declarative optimization specs — the paper's (objective, constraint) grid.

The six problems of §2.1 are not six APIs: they are the points of one grid
spanned by *what to minimize* and *what to bound*.  An :class:`OptimizeSpec`
names a grid point declaratively; :func:`repro.core.problems.optimize` maps
it onto the correct paper problem, picks the solver, and returns the
:class:`OptimizeResult` wrapper (solution + diagnostics).

::

    minimize            subject to               paper problem   solver
    ------------------  -----------------------  -------------   ----------------
    storage             (nothing)                1               MST / MCA
    every_recreation    (nothing)                2               SPT
    sum_recreation      storage      <= beta     3               LMG
    max_recreation      storage      <= beta     4               MP + bisection
    storage             sum_recreation <= theta  5               LMG + bin search
    storage             max_recreation <= theta  6               MP

Any other (objective, constraints) combination is off the grid and rejected
at spec construction time.  ``workload`` attaches access-frequency weights
``w_i`` (the Fig. 16 experiment): the recreation objective becomes
``sum_i w_i * R_i``; only problems 3 and 5 can honor it, every other grid
point raises.  ``solver="last"|"gith"`` forces one of the paper's
storage/recreation *balance heuristics* instead of a grid solver — those
take no constraints (their knobs ride in ``options``).

Specs are frozen and hashable: mapping-valued inputs (``workload``,
``options``) are normalized to sorted tuples at construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Mapping, Optional, Tuple

from .version_graph import StorageSolution

__all__ = [
    "Objective",
    "Constraint",
    "OptimizeSpec",
    "OptimizeResult",
    "OBJECTIVES",
    "CONSTRAINT_METRICS",
    "HEURISTIC_SOLVERS",
]

#: objective metrics: what a spec minimizes
OBJECTIVES = ("storage", "sum_recreation", "max_recreation", "every_recreation")

#: constraint metrics: what a spec may bound (``every_recreation`` is an
#: objective only — "each R_i finite" is the implicit Problem-1 constraint)
CONSTRAINT_METRICS = ("storage", "sum_recreation", "max_recreation")

#: solvers selectable via ``OptimizeSpec(solver=...)`` — balance heuristics
#: outside the six-problem grid (grid solvers are always auto-picked)
HEURISTIC_SOLVERS = ("last", "gith")

# (objective, sorted constraint metrics) -> paper problem id
_GRID: Dict[Tuple[str, Tuple[str, ...]], int] = {
    ("storage", ()): 1,
    ("every_recreation", ()): 2,
    ("sum_recreation", ("storage",)): 3,
    ("max_recreation", ("storage",)): 4,
    ("storage", ("sum_recreation",)): 5,
    ("storage", ("max_recreation",)): 6,
}

#: grid points whose solver honors per-version workload weights
_WORKLOAD_PROBLEMS = (3, 5)


def _grid_table() -> str:
    return (
        "the grid is: min storage (Problem 1); min every_recreation "
        "(Problem 2); min sum_recreation s.t. storage<=beta (Problem 3); "
        "min max_recreation s.t. storage<=beta (Problem 4); min storage "
        "s.t. sum_recreation<=theta (Problem 5); min storage s.t. "
        "max_recreation<=theta (Problem 6)"
    )


@dataclasses.dataclass(frozen=True)
class Objective:
    """What to minimize: one of :data:`OBJECTIVES`.

    ``every_recreation`` is the Problem-2 objective — minimize *each* ``R_i``
    simultaneously (the SPT achieves all of them at once).
    """

    metric: str

    def __post_init__(self) -> None:
        if self.metric not in OBJECTIVES:
            raise ValueError(
                f"unknown objective metric {self.metric!r}: "
                f"expected one of {list(OBJECTIVES)}"
            )

    # -- constructors ------------------------------------------------------
    @classmethod
    def storage(cls) -> "Objective":
        """Minimize total storage C."""
        return cls("storage")

    @classmethod
    def sum_recreation(cls) -> "Objective":
        """Minimize sum_i R_i (weighted by the spec's workload, if any)."""
        return cls("sum_recreation")

    @classmethod
    def max_recreation(cls) -> "Objective":
        """Minimize max_i R_i."""
        return cls("max_recreation")

    @classmethod
    def every_recreation(cls) -> "Objective":
        """Minimize each R_i simultaneously (Problem 2; the SPT)."""
        return cls("every_recreation")


@dataclasses.dataclass(frozen=True)
class Constraint:
    """An upper bound on one of :data:`CONSTRAINT_METRICS`."""

    metric: str
    bound: float

    def __post_init__(self) -> None:
        if self.metric not in CONSTRAINT_METRICS:
            raise ValueError(
                f"unknown constraint metric {self.metric!r}: "
                f"expected one of {list(CONSTRAINT_METRICS)}"
            )
        bound = float(self.bound)
        if bound != bound or bound in (float("inf"), float("-inf")):
            raise ValueError(
                f"constraint bound on {self.metric!r} must be finite, "
                f"got {self.bound!r}"
            )
        object.__setattr__(self, "bound", bound)

    # -- constructors ------------------------------------------------------
    @classmethod
    def storage_at_most(cls, beta: float) -> "Constraint":
        """C <= beta (the storage budget of Problems 3/4)."""
        return cls("storage", beta)

    @classmethod
    def sum_recreation_at_most(cls, theta: float) -> "Constraint":
        """sum_i R_i <= theta (Problem 5)."""
        return cls("sum_recreation", theta)

    @classmethod
    def max_recreation_at_most(cls, theta: float) -> "Constraint":
        """max_i R_i <= theta (Problem 6; the restore-latency SLA)."""
        return cls("max_recreation", theta)


def _as_sorted_items(
    value: Any, what: str, *, key_type: type = str
) -> Tuple[Tuple[Any, Any], ...]:
    """Normalize a mapping / iterable of pairs to a sorted tuple of pairs."""
    if value is None:
        return ()
    items = value.items() if isinstance(value, Mapping) else value
    out = []
    for pair in items:
        k, v = pair
        if key_type is int:
            try:
                k = int(k)  # accept numpy integer ids
            except (TypeError, ValueError):
                raise ValueError(f"{what} keys must be ints, got {k!r}")
        elif not isinstance(k, key_type):
            raise ValueError(
                f"{what} keys must be {key_type.__name__}, got {k!r}"
            )
        out.append((k, v))
    out.sort(key=lambda kv: kv[0])
    keys = [k for k, _ in out]
    if len(set(keys)) != len(keys):
        raise ValueError(f"duplicate {what} keys: {keys}")
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class OptimizeSpec:
    """A declarative request: objective + constraints + execution knobs.

    Fields
    ------
    objective / constraints
        The grid point (see module docstring).  At most one constraint per
        metric; the objective's own metric cannot also be constrained.
    workload
        Optional per-version access weights ``{vid: w}``; the recreation
        objective becomes ``sum_i w_i R_i``.  Only grid points whose solver
        is LMG-based (Problems 3 and 5) honor weights — any other point
        raises at construction.
    solver
        ``None`` (auto-pick from the grid) or one of
        :data:`HEURISTIC_SOLVERS` to force a balance heuristic; forced
        heuristics take ``objective=storage`` and no constraints.
    backend / pallas
        Compute backend for the solver inner loops (``"numpy"`` or
        ``"jax"``; ``pallas=True`` routes reductions through the Pallas
        kernels).  ``optimize`` transparently falls back to the NumPy path
        — bit-identical by contract — where the jitted formulation does not
        apply (directed MCA, degree-skew instances) and records the
        fallback in the result diagnostics.
    options
        Solver-specific knobs (``alpha``, ``window``, ``max_depth``,
        ``tol``, ``max_iters``, precomputed ``base``/``spt`` trees);
        validated against the chosen solver by ``optimize``.
    """

    objective: Objective
    constraints: Tuple[Constraint, ...] = ()
    workload: Optional[Tuple[Tuple[int, float], ...]] = None
    solver: Optional[str] = None
    backend: str = "numpy"
    pallas: bool = False
    options: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self) -> None:
        if isinstance(self.objective, str):
            object.__setattr__(self, "objective", Objective(self.objective))
        cons = self.constraints
        if isinstance(cons, Constraint):
            cons = (cons,)
        cons = tuple(cons)
        object.__setattr__(self, "constraints", cons)
        metrics = [c.metric for c in cons]
        if len(set(metrics)) != len(metrics):
            raise ValueError(f"duplicate constraint metrics: {metrics}")
        if self.objective.metric in metrics:
            raise ValueError(
                f"objective {self.objective.metric!r} cannot also be "
                f"constrained; {_grid_table()}"
            )
        # normalize mapping-valued fields so specs are hashable
        wl = self.workload
        if wl is not None:
            wl = _as_sorted_items(wl, "workload", key_type=int)
            for vid, w in wl:
                w = float(w)
                if vid < 1 or not w == w or w < 0:
                    raise ValueError(
                        f"workload weights must map version ids >= 1 to "
                        f"finite weights >= 0, got {vid}: {w!r}"
                    )
            wl = tuple((vid, float(w)) for vid, w in wl)
        object.__setattr__(self, "workload", wl)
        object.__setattr__(
            self, "options", _as_sorted_items(self.options, "options")
        )
        if self.backend not in ("numpy", "jax"):
            raise ValueError(
                f"unknown backend {self.backend!r}: expected 'numpy' or 'jax'"
            )
        if self.solver is not None:
            if self.solver not in HEURISTIC_SOLVERS:
                raise ValueError(
                    f"solver={self.solver!r} is not a forcible heuristic "
                    f"(accepted: {list(HEURISTIC_SOLVERS)}); grid solvers "
                    f"are picked automatically from (objective, constraints)"
                )
            if self.objective.metric != "storage" or cons:
                raise ValueError(
                    f"heuristic solver {self.solver!r} takes "
                    f"objective=storage and no constraints (its knobs go in "
                    f"options=); got objective={self.objective.metric!r}, "
                    f"constraints={metrics}"
                )
            if wl is not None:
                raise ValueError(
                    f"heuristic solver {self.solver!r} cannot honor workload "
                    f"weights; use Problem 3 or 5 (LMG) for workload-aware "
                    f"optimization"
                )
        else:
            pid = self.problem_id()  # raises for off-grid combinations
            if wl is not None and pid not in _WORKLOAD_PROBLEMS:
                raise ValueError(
                    f"Problem {pid} ({self.solver_name()}) cannot honor "
                    f"workload weights — only Problems "
                    f"{list(_WORKLOAD_PROBLEMS)} (LMG-based) are "
                    f"workload-aware; drop the workload or change the spec"
                )

    def __hash__(self) -> int:
        # option *values* may be unhashable execution hints (precomputed
        # base/spt StorageSolutions); collapse those to a constant so the
        # spec itself always hashes — equal specs still hash equal, and the
        # declarative fields keep their full discrimination
        def h(v: Any) -> int:
            try:
                return hash(v)
            except TypeError:
                return 0
        return hash((
            self.objective, self.constraints, self.workload, self.solver,
            self.backend, self.pallas,
            tuple((k, h(v)) for k, v in self.options),
        ))

    # ------------------------------------------------------------- accessors
    def problem_id(self) -> Optional[int]:
        """The paper problem this spec maps to (None for forced heuristics)."""
        if self.solver is not None:
            return None
        key = (
            self.objective.metric,
            tuple(sorted(c.metric for c in self.constraints)),
        )
        pid = _GRID.get(key)
        if pid is None:
            raise ValueError(
                f"objective={self.objective.metric!r} with constraints on "
                f"{list(key[1])} is off the paper grid; {_grid_table()}"
            )
        return pid

    def solver_name(self) -> str:
        """The solver this spec resolves to (forced heuristic or grid pick)."""
        if self.solver is not None:
            return self.solver
        return {1: "mca", 2: "spt", 3: "lmg", 4: "mp+bisect",
                5: "lmg+binsearch", 6: "mp"}[self.problem_id()]

    def supports_workload(self) -> bool:
        """True when this grid point's solver honors workload weights."""
        return self.solver is None and self.problem_id() in _WORKLOAD_PROBLEMS

    def bound(self, metric: str) -> Optional[float]:
        """The constraint bound on ``metric``, or None."""
        for c in self.constraints:
            if c.metric == metric:
                return c.bound
        return None

    def weights(self) -> Optional[Dict[int, float]]:
        """The workload as a plain ``{vid: weight}`` dict (or None)."""
        if self.workload is None:
            return None
        return dict(self.workload)

    def options_dict(self) -> Dict[str, Any]:
        return dict(self.options)

    def with_workload(self, weights: Optional[Mapping[int, float]]) -> "OptimizeSpec":
        """A copy of this spec with the workload replaced (re-validated)."""
        return dataclasses.replace(self, workload=weights)

    # ---------------------------------------------------------- constructors
    @classmethod
    def problem(
        cls,
        n: int,
        *,
        beta: Optional[float] = None,
        theta: Optional[float] = None,
        workload: Optional[Mapping[int, float]] = None,
        backend: str = "numpy",
        pallas: bool = False,
        **options: Any,
    ) -> "OptimizeSpec":
        """The spec for paper problem ``n`` (1-6).

        ``beta`` is the storage budget (Problems 3/4); ``theta`` the
        recreation bound (Problems 5/6); extra kwargs become solver options.
        """
        def need(name: str, value: Optional[float]) -> float:
            if value is None:
                raise ValueError(f"Problem {n} requires {name}=")
            return value

        def reject(name: str, value: Optional[float]) -> None:
            if value is not None:
                raise ValueError(f"Problem {n} does not take {name}=")

        if n == 1:
            reject("beta", beta), reject("theta", theta)
            obj, cons = Objective.storage(), ()
        elif n == 2:
            reject("beta", beta), reject("theta", theta)
            obj, cons = Objective.every_recreation(), ()
        elif n == 3:
            reject("theta", theta)
            obj = Objective.sum_recreation()
            cons = (Constraint.storage_at_most(need("beta", beta)),)
        elif n == 4:
            reject("theta", theta)
            obj = Objective.max_recreation()
            cons = (Constraint.storage_at_most(need("beta", beta)),)
        elif n == 5:
            reject("beta", beta)
            obj = Objective.storage()
            cons = (Constraint.sum_recreation_at_most(need("theta", theta)),)
        elif n == 6:
            reject("beta", beta)
            obj = Objective.storage()
            cons = (Constraint.max_recreation_at_most(need("theta", theta)),)
        else:
            raise ValueError(f"paper problems are 1..6, got {n}")
        return cls(
            objective=obj, constraints=cons, workload=workload,
            backend=backend, pallas=pallas, options=options,
        )

    @classmethod
    def heuristic(
        cls,
        solver: str,
        *,
        backend: str = "numpy",
        pallas: bool = False,
        **options: Any,
    ) -> "OptimizeSpec":
        """A spec forcing one of the balance heuristics (:data:`HEURISTIC_SOLVERS`)."""
        return cls(
            objective=Objective.storage(), solver=solver,
            backend=backend, pallas=pallas, options=options,
        )

    def describe(self) -> str:
        cons = ", ".join(f"{c.metric}<={c.bound:g}" for c in self.constraints)
        parts = [f"min {self.objective.metric}"]
        if cons:
            parts.append(f"s.t. {cons}")
        if self.workload is not None:
            parts.append(f"workload({len(self.workload)} weights)")
        if self.solver:
            parts.append(f"solver={self.solver}")
        pid = self.problem_id()
        if pid is not None:
            parts.append(f"[Problem {pid}]")
        return " ".join(parts)


@dataclasses.dataclass
class OptimizeResult:
    """What :func:`repro.core.problems.optimize` returns: the solution plus
    everything needed to audit how it was obtained.

    ``objective_value`` is the spec's objective metric evaluated on the
    solution (``every_recreation`` reports the sum — the SPT minimizes each
    term, so the sum is the natural scalar); ``objective_values`` always
    carries all three metrics (plus ``weighted_sum_recreation`` when a
    workload was attached).  ``constraint_slack[metric] = bound - achieved``
    is >= -tolerance by construction — ``optimize`` re-validates every
    constraint on the returned tree and refuses to return a violating
    solution.  ``backend_used`` differs from ``spec.backend`` exactly when a
    documented fallback fired (directed MCA, degree skew); the reason is in
    ``diagnostics["backend_fallback"]``.
    """

    solution: StorageSolution
    spec: OptimizeSpec
    problem: Optional[int]
    solver: str
    backend_used: str
    objective_value: float
    objective_values: Dict[str, float]
    constraint_slack: Dict[str, float]
    wall_time_s: float
    diagnostics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        pid = f"P{self.problem}" if self.problem else self.solver
        slack = ", ".join(
            f"{m} slack={s:.4g}" for m, s in self.constraint_slack.items()
        )
        return (
            f"[{pid}/{self.solver}/{self.backend_used}] "
            f"{self.spec.objective.metric}={self.objective_value:.6g}"
            + (f" ({slack})" if slack else "")
            + f" in {self.wall_time_s:.3f}s"
        )
