"""Deterministic, resumable, host-sharded token pipeline.

Production invariants this implements:

* **determinism** — batch ``i`` of host ``h`` is a pure function of
  (seed, i, h): restarts and elastic re-host-counts replay identically;
* **checkpointable state** — the iterator state is a tiny pytree committed
  to the version store next to the weights, so restore resumes mid-epoch
  with no data loss or repetition;
* **versioned corpora** — a dataset is a set of shard payloads in the
  VersionStore; switching corpus versions (cleaning/dedup/mixture updates)
  is a version-graph checkout, and storage is delta-optimized like any
  other artifact (the paper's "Data Science Dataset Versions" scenario).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from ..store import VersionStore


@dataclasses.dataclass
class PipelineState:
    step: int = 0
    epoch: int = 0

    def to_dict(self) -> Dict[str, int]:
        return {"step": self.step, "epoch": self.epoch}

    @classmethod
    def from_dict(cls, d) -> "PipelineState":
        return cls(step=int(d["step"]), epoch=int(d["epoch"]))


class SyntheticTokenPipeline:
    """Seeded synthetic LM batches (markov-ish stream with local structure,
    so cross-entropy actually decreases during the example runs)."""

    def __init__(
        self,
        *,
        vocab: int,
        seq_len: int,
        global_batch: int,
        host_id: int = 0,
        n_hosts: int = 1,
        seed: int = 0,
        state: Optional[PipelineState] = None,
    ) -> None:
        assert global_batch % n_hosts == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // n_hosts
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.seed = seed
        self.state = state or PipelineState()

    def _batch_rng(self, step: int) -> np.random.RandomState:
        return np.random.RandomState(
            (self.seed * 1_000_003 + step * 65_537 + self.host_id) % (2**31)
        )

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._batch_rng(self.state.step)
        B, S, V = self.local_batch, self.seq_len, self.vocab
        # structured stream: short arithmetic token runs + repeats => learnable
        starts = rng.randint(0, V, size=(B, 1))
        deltas = rng.randint(1, 4, size=(B, 1))
        ramp = (starts + deltas * np.arange(S)[None, :]) % V
        noise_mask = rng.rand(B, S) < 0.1
        noise = rng.randint(0, V, size=(B, S))
        tokens = np.where(noise_mask, noise, ramp).astype(np.int32)
        self.state.step += 1
        return {"tokens": tokens}

    # resumability -----------------------------------------------------------
    def snapshot(self) -> Dict[str, int]:
        return self.state.to_dict()

    def restore(self, snap: Dict[str, int]) -> None:
        self.state = PipelineState.from_dict(snap)


class VersionedDatasetPipeline:
    """Reads tokenized shards from a VersionStore version (a committed
    dataset), host-sharded and resumable."""

    def __init__(
        self,
        store: VersionStore,
        version_id: int,
        *,
        seq_len: int,
        global_batch: int,
        host_id: int = 0,
        n_hosts: int = 1,
        state: Optional[PipelineState] = None,
    ) -> None:
        self.store = store
        self.version_id = version_id
        flat = store.checkout(version_id)
        shards = [flat[k] for k in sorted(flat) if k.startswith("shard")]
        if not shards:
            raise ValueError("dataset version has no 'shard*' arrays")
        stream = np.concatenate([s.reshape(-1) for s in shards]).astype(np.int32)
        self.stream = stream
        self.seq_len = seq_len
        self.local_batch = global_batch // n_hosts
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.state = state or PipelineState()
        self.tokens_per_batch = self.local_batch * (seq_len + 1)

    def next_batch(self) -> Dict[str, np.ndarray]:
        n = self.stream.size
        need = self.tokens_per_batch
        # host-interleaved contiguous windows
        offset = (
            (self.state.step * self.n_hosts + self.host_id) * need
        ) % max(1, n - need)
        window = self.stream[offset : offset + need]
        tokens = window[: self.local_batch * self.seq_len].reshape(
            self.local_batch, self.seq_len
        )
        self.state.step += 1
        if (self.state.step * self.n_hosts * need) // max(1, n) > self.state.epoch:
            self.state.epoch += 1
        return {"tokens": tokens.copy()}

    def snapshot(self) -> Dict[str, int]:
        return {**self.state.to_dict(), "dataset_version": self.version_id}

    def restore(self, snap) -> None:
        self.state = PipelineState.from_dict(snap)
