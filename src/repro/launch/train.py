"""End-to-end training driver with versioned fault-tolerant checkpointing.

CPU-runnable at reduced scale (the quickstart/examples use it directly);
the same loop drives pod-scale runs — only mesh/shardings/batch change.

Fault-tolerance loop structure:
  * resume: restore (params, opt, data-iterator state) from the newest
    version in the store, re-sharded onto the current mesh (elastic);
  * run: jitted train_step with donated state;
  * checkpoint: async versioned delta-commit every ``save_every`` steps
    (cheap deltas ⇒ frequent saves ⇒ small loss window);
  * preemption: the guard flips on SIGTERM; the loop emergency-saves
    synchronously and exits 42 (the launcher's "restart me" code);
  * repack: every ``repack_every`` saves, re-optimize the storage graph with
    the paper's solver (Problem 6 with the restore-latency SLA θ).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import PreemptionGuard, VersionedCheckpointManager
from ..configs import ARCHS
from ..data.pipeline import SyntheticTokenPipeline
from ..models.registry import get_model
from ..training.optimizer import OptimizerConfig, init_opt_state
from ..training.train_loop import TrainConfig, make_train_step


@dataclasses.dataclass
class RunConfig:
    arch: str = "minicpm-2b"
    reduced: bool = True
    steps: int = 50
    seq_len: int = 128
    global_batch: int = 8
    save_every: int = 10
    repack_every: int = 0
    max_restore_cost_s: float = 60.0
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0
    schedule: str = "cosine"
    grad_accum: int = 1
    compress_grads: bool = False


def train(run: RunConfig, *, guard: Optional[PreemptionGuard] = None,
          log_every: int = 10) -> Dict[str, Any]:
    cfg = ARCHS[run.arch].reduced() if run.reduced else ARCHS[run.arch]
    bundle = get_model(cfg)
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(
            peak_lr=1e-3, schedule=run.schedule, warmup_steps=10,
            total_steps=run.steps,
        ),
        grad_accum=run.grad_accum,
        compress_grads=run.compress_grads,
    )
    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))

    pipe = SyntheticTokenPipeline(
        vocab=cfg.vocab, seq_len=run.seq_len, global_batch=run.global_batch,
        seed=run.seed,
    )
    mgr = VersionedCheckpointManager(
        run.ckpt_dir, max_restore_cost_s=run.max_restore_cost_s,
    )
    guard = guard or PreemptionGuard()

    # ---- resume or init -----------------------------------------------------
    start_step = 0
    params_t = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(run.seed)))
    state_template = {
        "params": params_t,
        "opt": jax.eval_shape(lambda: init_opt_state(params_t)),
        "data": {"step": jax.ShapeDtypeStruct((), jnp.int32),
                 "epoch": jax.ShapeDtypeStruct((), jnp.int32)},
    }
    if mgr.latest_step() is not None:
        full = mgr.restore(template=state_template)
        state = {"params": full["params"], "opt": full["opt"], "error_fb": None}
        pipe.restore({k: int(v) for k, v in full["data"].items()})
        start_step = mgr.latest_step() + 1
        print(f"[train] resumed from step {start_step - 1} "
              f"(restore cost {mgr.restore_cost_s():.3f}s modelled)")
    else:
        params = bundle.init(jax.random.PRNGKey(run.seed))
        state = {"params": params, "opt": init_opt_state(params), "error_fb": None}

    # ---- loop ---------------------------------------------------------------
    losses = []
    t_start = time.monotonic()
    preempted = False
    for step in range(start_step, run.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == run.steps - 1:
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if guard.preempted:
            snap = pipe.snapshot()
            mgr.emergency_save(step, _save_state(state, snap))
            print(f"[train] PREEMPTED at step {step}: emergency checkpoint saved")
            preempted = True
            break
        if run.save_every and (step + 1) % run.save_every == 0:
            mgr.save(step, _save_state(state, pipe.snapshot()))
            if run.repack_every and ((step + 1) // run.save_every) % run.repack_every == 0:
                stats = mgr.repack()
                print(f"[train] repack: {stats['before']['storage_bytes']/1e6:.1f}MB "
                      f"-> {stats['after']['storage_bytes']/1e6:.1f}MB, "
                      f"max restore {stats['after']['max_recreation_s']:.3f}s")
    mgr.wait()
    wall = time.monotonic() - t_start
    result = {
        "losses": losses,
        "preempted": preempted,
        "steps_done": len(losses),
        "wall_s": wall,
        "manager": mgr,
        "final_state": state,
        "pipe": pipe,
        "bundle": bundle,
    }
    return result


def _save_state(state, data_snap) -> Dict[str, Any]:
    return {
        "params": state["params"],
        "opt": state["opt"],
        "data": {
            "step": np.int32(data_snap["step"]),
            "epoch": np.int32(data_snap["epoch"]),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (pod-scale) config instead of reduced")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    run = RunConfig(
        arch=args.arch, reduced=not args.full_config, steps=args.steps,
        seq_len=args.seq_len, global_batch=args.global_batch,
        save_every=args.save_every, ckpt_dir=args.ckpt_dir,
        grad_accum=args.grad_accum, compress_grads=args.compress_grads,
    )
    guard = PreemptionGuard(install_signal_handlers=True)
    out = train(run, guard=guard)
    print(f"[train] done: {out['steps_done']} steps in {out['wall_s']:.1f}s, "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    raise SystemExit(42 if out["preempted"] else 0)


if __name__ == "__main__":
    main()
