"""Serving driver: load (or init) a model, run batched generation.

CPU-runnable on reduced configs; the dry-run exercises the pod-scale
prefill/decode lowering of the very same bundle functions.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import ARCHS
from ..models.registry import get_model
from ..serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="minicpm-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        bundle, params,
        max_len=args.prompt_len + args.max_new_tokens,
        batch=args.batch, temperature=args.temperature,
    )
    rng = np.random.RandomState(0)
    batch = {"tokens": rng.randint(0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.is_encdec:
        batch["frames"] = rng.randn(args.batch, args.prompt_len, cfg.d_model).astype(np.float32)
    res = engine.generate(batch, max_new_tokens=args.max_new_tokens)
    tps = res.steps * args.batch / max(res.decode_s, 1e-9)
    print(f"[serve] {args.arch}: prefill {res.prefill_s*1e3:.1f}ms, "
          f"{res.steps} decode steps, {tps:.1f} tok/s (CPU, reduced config)")
    print("[serve] sample tokens:", res.tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
