import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds ShapeDtypeStruct inputs (no allocation) and NamedShardings from
     the logical-axis rules,
  2. ``jax.jit(step).lower(...).compile()`` against the production mesh —
     16×16 single-pod and 2×16×16 multi-pod,
  3. prints ``compiled.memory_analysis()`` (fits-per-device proof) and the
     loop-aware roofline terms (hlo_analysis — see that module for why raw
     cost_analysis is insufficient),
  4. emits one JSON record per cell (consumed by EXPERIMENTS.md tooling).

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out dryrun_results.jsonl
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES
from ..configs.base import ModelConfig, ShapeSpec
from ..distributed.sharding import (
    DEFAULT_RULES,
    logical_sharding,
    tree_spec,
)
from ..models.registry import count_params, get_model
from ..training.optimizer import OptimizerConfig
from ..training.train_loop import TrainConfig, make_train_step
from .hlo_analysis import analyze_hlo
from .input_specs import (
    cache_specs,
    decode_token_specs,
    param_specs,
    train_batch_specs,
)
from .mesh import make_production_mesh

# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def default_rules() -> Dict:
    rules = dict(DEFAULT_RULES)
    rules["kv_seq"] = "model"  # decode caches shard their seq axis over TP
    return rules


def _logits_sharding(cfg: ModelConfig, shape: ShapeSpec, mesh, rules):
    from ..distributed.sharding import spec_for

    spec = spec_for(
        ("batch", None, "vocab"),
        (shape.global_batch, 1, cfg.padded_vocab),
        mesh=mesh, rules=rules, strict=True,
    )
    return NamedSharding(mesh, spec)


def _opt_state_specs(param_sds: Any, param_axes: Any):
    f32 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), param_sds
    )
    return (
        {"mu": f32, "nu": f32, "step": jax.ShapeDtypeStruct((), jnp.int32)},
        {"mu": param_axes, "nu": param_axes, "step": ()},
    )


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool,
    rules: Optional[Dict] = None,
    tcfg: Optional[TrainConfig] = None,
    mla_compressed: bool = False,
    moe_impl: str = "dispatch",
    rwkv_impl: str = "scan",
    verbose: bool = True,
) -> Dict[str, Any]:
    cfg = ARCHS[arch]
    import dataclasses as _dc
    if mla_compressed and cfg.q_lora_rank:
        cfg = _dc.replace(cfg, mla_compressed_cache=True)
    if moe_impl != "dispatch" and cfg.n_experts:
        cfg = _dc.replace(cfg, moe_impl=moe_impl)
    if rwkv_impl != "scan" and cfg.family == "ssm":
        cfg = _dc.replace(cfg, rwkv_impl=rwkv_impl)
    shape = SHAPES[shape_name]
    if shape_name not in cfg.supported_shapes:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "unsupported (per-spec skip, see DESIGN.md §7)"}
    rules = rules or default_rules()
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = get_model(cfg)
    t0 = time.time()

    param_sds, param_axes = param_specs(bundle)
    param_sh = tree_spec(param_axes, param_sds, mesh=mesh, rules=rules)
    repl = NamedSharding(mesh, P())

    with logical_sharding(mesh, rules):
        if shape.kind == "train":
            # grad_accum=4: production microbatching — peak activation memory
            # (the per-layer saved-carry stacks) drops 4x; global batch fixed.
            tcfg = tcfg or TrainConfig(optimizer=OptimizerConfig(), grad_accum=4)
            step_fn = make_train_step(bundle, tcfg)
            batch_sds, batch_axes = train_batch_specs(cfg, shape)
            batch_sh = tree_spec(batch_axes, batch_sds, mesh=mesh, rules=rules)
            opt_sds, opt_axes = _opt_state_specs(param_sds, param_axes)
            opt_sh = tree_spec(opt_axes, opt_sds, mesh=mesh, rules=rules)
            state_sds = {"params": param_sds, "opt": opt_sds, "error_fb": None}
            state_sh = {"params": param_sh, "opt": opt_sh, "error_fb": None}
            metrics_sh = {"loss": repl, "lr": repl, "grad_norm": repl}
            jitted = jax.jit(
                step_fn,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, metrics_sh),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds, batch_axes = train_batch_specs(cfg, shape)
            batch_sh = tree_spec(batch_axes, batch_sds, mesh=mesh, rules=rules)
            cache_sds, cache_axes_ = cache_specs(bundle, shape)
            cache_sh = tree_spec(cache_axes_, cache_sds, mesh=mesh, rules=rules)
            logits_sh = _logits_sharding(cfg, shape, mesh, rules)
            jitted = jax.jit(
                bundle.prefill,
                in_shardings=(param_sh, batch_sh, cache_sh),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(param_sds, batch_sds, cache_sds)
        else:  # decode
            tok_sds, tok_axes = decode_token_specs(cfg, shape)
            tok_sh = tree_spec(tok_axes, tok_sds, mesh=mesh, rules=rules)
            cache_sds, cache_axes_ = cache_specs(bundle, shape)
            cache_sh = tree_spec(cache_axes_, cache_sds, mesh=mesh, rules=rules)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            logits_sh = _logits_sharding(cfg, shape, mesh, rules)
            jitted = jax.jit(
                bundle.decode_step,
                in_shardings=(param_sh, tok_sh, cache_sh, repl),
                out_shardings=(logits_sh, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(param_sds, tok_sds, cache_sds, pos_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_cost = analyze_hlo(compiled.as_text())

    chips = 512 if multi_pod else 256
    n_total = count_params(cfg)
    n_active = count_params(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        tokens = shape.global_batch  # one step
        model_flops = 2.0 * n_active * tokens

    # hlo_cost is the per-device SPMD program => per-device seconds directly
    compute_s = hlo_cost.flops / PEAK_FLOPS
    memory_s = hlo_cost.bytes / HBM_BW
    collective_s = hlo_cost.collective_bytes / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "args_bytes_per_dev": mem.argument_size_in_bytes,
            "output_bytes_per_dev": mem.output_size_in_bytes,
            "temp_bytes_per_dev": mem.temp_size_in_bytes,
            "peak_est_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 1e9, 3
            ),
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops"),
            "bytes": ca.get("bytes accessed"),
        },
        "hlo_per_device": {
            "flops": hlo_cost.flops,
            "bytes": hlo_cost.bytes,
            "collective_bytes": hlo_cost.collective_bytes,
            "collectives": {k: v for k, v in sorted(hlo_cost.coll.items())},
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "bound_s": max(compute_s, memory_s, collective_s),
        },
        "model_flops": {
            "n_params": n_total,
            "n_active": n_active,
            "tokens": tokens,
            "model_flops_global": model_flops,
            "useful_flops_ratio": (
                model_flops / (hlo_cost.flops * chips)
                if hlo_cost.flops else None
            ),
            "mfu_upper_bound": (
                model_flops / chips / PEAK_FLOPS
                / max(compute_s, memory_s, collective_s)
                if max(compute_s, memory_s, collective_s) > 0 else None
            ),
        },
        "skipped": False,
    }
    if verbose:
        print(f"--- {arch} × {shape_name} × {rec['mesh']} ---")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB per device")
        print(f"  roofline: compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
              f"collective={collective_s*1e3:.2f}ms -> {dominant}-bound")
        print(f"  useful-flops ratio: {rec['model_flops']['useful_flops_ratio']:.3f}"
              if rec["model_flops"]["useful_flops_ratio"] else "")
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--mla-compressed", action="store_true")
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--rwkv-chunked", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = []
    if args.multi_pod or args.all:
        meshes.append(True)
    if args.single_pod or args.all or not (args.multi_pod or args.single_pod):
        meshes.insert(0, False)

    failures = 0
    out_f = open(args.out, "a") if args.out else None
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = lower_cell(
                        arch, shape, multi_pod=multi,
                        mla_compressed=args.mla_compressed,
                        moe_impl="ep" if args.moe_ep else "dispatch",
                        rwkv_impl="chunked" if args.rwkv_chunked else "scan")
                except Exception as e:  # a failure here is a framework bug
                    failures += 1
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"!!! FAILED {arch} × {shape}: {e}")
                if out_f:
                    out_f.write(json.dumps(rec) + "\n")
                    out_f.flush()
                cells.append(rec)
    done = sum(1 for c in cells if not c.get("skipped") and "error" not in c)
    skipped = sum(1 for c in cells if c.get("skipped"))
    print(f"\n=== dry-run: {done} compiled, {skipped} per-spec skips, "
          f"{failures} failures ===")
    if out_f:
        out_f.close()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
