"""Loop-aware roofline accounting over compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` visits each instruction once —
a ``lax.scan`` over 61 layers contributes its body FLOPs *once*, not 61×
(verified empirically; see EXPERIMENTS.md §Dry-run notes).  Since the whole
framework scans over layers (and microbatches), raw cost_analysis would be
off by >60× on the deep archs.  This module walks the HLO computation graph
from ENTRY, multiplying ``while`` bodies by their trip counts, and produces:

* flops — 2·prod(result)·prod(contracting) for every dot (dominant);
  ~1/elem for elementwise/reduce ops (operand-sized, via the symbol table);
* bytes — result + operand bytes per (post-fusion) instruction: fusion
  internals stay in registers, so call-site traffic is the HBM model;
* collective_bytes — per collective type, max(result, operands) per op,
  weighted by loop trip counts; ``-start``/``-done`` pairs deduplicated.

CPU-backend HLO prints operand *names* without types, so a per-computation
symbol table (instruction → result shapes) resolves operand sizes.  Trip
counts come from the loop condition's s32/s64 constants — exact for
lax.scan / fori_loop lowerings.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_ARRAY_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z]\w*\[[\d,]*\]\S*)\s+([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "tanh", "rsqrt", "sqrt", "power",
    "log", "log-plus-one", "compare", "select", "and", "or", "xor", "not",
    "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz", "clamp",
    "cosine", "sine", "atan2", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite", "erf",
    "cbrt", "logistic", "tan",
}
_REDUCERS = {"reduce", "reduce-window", "select-and-scatter"}
_MEMORY_OPS = {
    "copy", "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "concatenate", "pad", "reshape", "transpose", "broadcast", "iota",
    "convert", "bitcast-convert", "reverse", "slice", "sort", "map",
    "copy-start", "copy-done",
}
_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
    "rng-bit-generator", "rng-get-and-update-state", "custom-call",
}


def _shapes_of(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems_of(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result: List[Tuple[str, List[int]]]
    operands: List[str]
    line: str


def _parse(hlo: str):
    """Split into computations; parse instructions + symbol tables."""
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    body: List[_Instr] = []
    for raw in hlo.splitlines():
        line = raw.strip()
        if cur is None:
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
            if m:
                cur = m.group(2)
                if m.group(1):
                    entry = cur
                body = []
            continue
        if line == "}" or line.startswith("} "):
            comps[cur] = body
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        # operand section: from the op's '(' to its matching ')'
        start = m.end() - 1
        depth = 0
        end = start
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = line[start + 1 : end]
        operands = _OPERAND_RE.findall(operand_str)
        body.append(_Instr(name, op, _shapes_of(type_str), operands, line))
    return comps, entry


def _trip_count(instrs: List[_Instr]) -> int:
    best = 1
    for ins in instrs:
        for m in re.finditer(r"s(?:32|64)\[\]\s+constant\((\d+)\)", ins.line):
            best = max(best, int(m.group(1)))
    return best


def top_contributors(hlo: str, k: int = 20, key: str = "bytes"):
    """Debug view: the k most expensive leaf instructions, weighted by loop
    trip counts.  ``key`` ∈ {bytes, flops, coll}.  Returns rows of
    (Cost, multiplier, op, line)."""
    rows: list = []
    analyze_hlo(hlo, _debug_rows=rows)
    attr = "collective_bytes" if key == "coll" else key
    rows.sort(key=lambda r: -getattr(r[0], attr))
    return rows[:k]


def analyze_hlo(hlo: str, _debug_rows: Optional[list] = None) -> Cost:
    comps, entry = _parse(hlo)
    if entry is None:
        return Cost()
    symtab: Dict[str, Dict[str, List[Tuple[str, List[int]]]]] = {
        c: {i.name: i.result for i in instrs} for c, instrs in comps.items()
    }
    cache: Dict[str, Cost] = {}

    def operand_shapes(comp: str, ins: _Instr):
        out = []
        for name in ins.operands:
            shapes = symtab[comp].get(name)
            if shapes:
                out.extend(shapes)
        return out

    def attr_comp(line: str, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w\.\-]+)", line)
        return m.group(1) if m else None

    def _fusion_result_bytes(ins: _Instr, callee: str) -> float:
        """A fusion whose root is a dynamic-update-slice (possibly behind
        precision converts) writes only the update slice, not the whole
        buffer — the scan ``ys`` in-place pattern."""
        callee_instrs = comps.get(callee, [])
        by_name = {ci.name: ci for ci in callee_instrs}
        root = next((ci for ci in callee_instrs if "ROOT" in ci.line), None)
        depth = 0
        while root is not None and depth < 4:
            if root.op == "dynamic-update-slice":
                per = [
                    _bytes_of(by_name[n].result)
                    for n in root.operands
                    if n in by_name and _bytes_of(by_name[n].result) > 0
                ]
                per = [b for b in per if b < _bytes_of(root.result)]
                if per:
                    return min(per)
                break
            if root.op in ("convert", "bitcast", "copy") and root.operands:
                nxt = by_name.get(root.operands[0])
                root = nxt
                depth += 1
                continue
            break
        return _bytes_of(ins.result)

    def _fusion_operand_bytes(comp: str, ins: _Instr, callee: str) -> float:
        """Charge each fusion operand by what the fused body *touches*: a
        parameter consumed only through (dynamic-)slice/gather reads only
        the slice (e.g. one layer of a stacked (L, ...) scan buffer per
        trip), not the whole operand.  Precision ``convert``/``bitcast``
        chains are looked through — the CPU backend materializes f32 copies
        of bf16 buffers around mixed-precision dots/updates that a TPU's MXU
        handles natively (EXPERIMENTS.md §Dry-run notes)."""
        total = 0.0
        callee_instrs = comps.get(callee, [])
        by_name = {ci.name: ci for ci in callee_instrs}
        param_names = {}
        for ci in callee_instrs:
            if ci.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ci.line)
                if m:
                    param_names[int(m.group(1))] = ci.name

        def consumers_of(name):
            return [ci for ci in callee_instrs if name in ci.operands]

        def traffic(name: str, full: float, depth: int = 0) -> float:
            """Charge for one value given how it is consumed; None-able."""
            cons = consumers_of(name)
            if not cons or depth > 4:
                return full
            charge = 0.0
            for ci in cons:
                if ci.op in ("dynamic-slice", "slice", "gather"):
                    charge += _bytes_of(ci.result)
                elif ci.op == "dynamic-update-slice":
                    # in-place write: the update operand is the traffic
                    per = [
                        _bytes_of(by_name[n].result)
                        for n in ci.operands
                        if n in by_name and n != name and _bytes_of(by_name[n].result) > 0
                    ]
                    charge += 2.0 * (min(per) if per else full)
                elif ci.op in ("convert", "bitcast", "copy"):
                    charge += traffic(ci.name, full, depth + 1)
                else:
                    return full  # genuinely consumed whole
            return min(charge, full * len(cons))

        for idx, opname in enumerate(ins.operands):
            full = _bytes_of(symtab[comp].get(opname, []))
            pname = param_names.get(idx)
            if pname is None or full == 0:
                total += full
            else:
                total += traffic(pname, full)
        return total

    def cost_of(comp: str) -> Cost:
        if comp in cache:
            return cache[comp]
        cache[comp] = Cost()  # cycle guard
        total = Cost()
        for ins in comps.get(comp, ()):
            total += instr_cost(comp, ins)
        cache[comp] = total
        return total

    def instr_cost(comp: str, ins: _Instr) -> Cost:
        c = Cost()
        op = ins.op
        if op == "while":
            body = attr_comp(ins.line, "body")
            cond = attr_comp(ins.line, "condition")
            trips = _trip_count(comps.get(cond, [])) if cond else 1
            if body:
                c += cost_of(body).scaled(trips)
            if cond:
                c += cost_of(cond).scaled(trips)
            return c
        if op in ("fusion", "call", "async-start"):
            callee = attr_comp(ins.line, "calls") or attr_comp(ins.line, "to_apply")
            if callee:
                sub = cost_of(callee)
                # fusion internals stay on-chip: charge flops + collectives,
                # model HBM traffic as the call-site operands + results
                c += Cost(flops=sub.flops, bytes=0.0, coll=dict(sub.coll))
                c.bytes += _fusion_result_bytes(ins, callee)
                c.bytes += _fusion_operand_bytes(comp, ins, callee)
            else:
                c.bytes += _bytes_of(ins.result) + _bytes_of(operand_shapes(comp, ins))
            return c
        if op == "conditional":
            branches = re.findall(
                r"(?:branch_computations=\{|true_computation=|false_computation=)"
                r"%?([\w\.\-]+)", ins.line)
            for br in branches:
                c += cost_of(br)
            c.bytes += _bytes_of(ins.result)
            return c
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                return c
            b = max(_bytes_of(ins.result), _bytes_of(operand_shapes(comp, ins)))
            c.coll[base] = c.coll.get(base, 0.0) + b
            c.bytes += b
            return c
        if op == "dot":
            res_elems = _elems_of(ins.result)
            k = 1.0
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
            ops_shapes = operand_shapes(comp, ins)
            if m and m.group(1) and ops_shapes:
                lhs = ops_shapes[0][1]
                for d in m.group(1).split(","):
                    di = int(d)
                    if di < len(lhs):
                        k *= lhs[di]
            c.flops += 2.0 * res_elems * k
            c.bytes += _bytes_of(ins.result) + _bytes_of(ops_shapes)
            return c
        if op == "convolution":
            c.flops += 2.0 * _elems_of(ins.result)
            c.bytes += _bytes_of(ins.result) + _bytes_of(operand_shapes(comp, ins))
            return c
        if op in _ELEMENTWISE or op in _REDUCERS:
            opnd = operand_shapes(comp, ins)
            c.flops += max(_elems_of(ins.result), _elems_of(opnd))
            c.bytes += _bytes_of(ins.result) + _bytes_of(opnd)
            return c
        if op in ("dynamic-slice", "slice", "gather"):
            # reads only the slice it produces (not the full operand)
            c.bytes += 2.0 * _bytes_of(ins.result)
            return c
        if op in ("dynamic-update-slice", "scatter"):
            # in-place update: traffic ~ the update operand, not the buffer
            per_op = [_bytes_of(symtab[comp].get(n, [])) for n in ins.operands]
            per_op = [b for b in per_op if b > 0]
            c.bytes += 2.0 * (min(per_op) if per_op else _bytes_of(ins.result))
            return c
        if op in ("broadcast", "iota"):
            c.bytes += _bytes_of(ins.result)
            return c
        if op in _MEMORY_OPS:
            c.bytes += _bytes_of(ins.result) + _bytes_of(operand_shapes(comp, ins))
            return c
        # unknown / skip ops contribute nothing
        return c

    total = cost_of(entry)

    if _debug_rows is not None:
        # attribute weighted costs to leaf instructions (debug/profiling view)
        mult: Dict[str, float] = {entry: 1.0}

        def walk(comp: str, m: float, seen: set) -> None:
            if comp in seen:
                return
            for ins in comps.get(comp, ()):
                if ins.op == "while":
                    body = attr_comp(ins.line, "body")
                    cond = attr_comp(ins.line, "condition")
                    t = _trip_count(comps.get(cond, [])) if cond else 1
                    for sub in (body, cond):
                        if sub:
                            mult[sub] = mult.get(sub, 0.0) + m * t
                            walk(sub, m * t, seen | {comp})
                elif ins.op in ("fusion", "call", "conditional", "async-start"):
                    callee = attr_comp(ins.line, "calls") or attr_comp(ins.line, "to_apply")
                    if callee:
                        mult[callee] = mult.get(callee, 0.0) + m
                        walk(callee, m, seen | {comp})

        walk(entry, 1.0, set())
        for comp, instrs in comps.items():
            m = mult.get(comp, 0.0)
            if not m:
                continue
            for ins in instrs:
                if ins.op == "while":
                    continue
                if ins.op in ("fusion", "call", "async-start"):
                    callee = attr_comp(ins.line, "calls") or attr_comp(
                        ins.line, "to_apply")
                    if callee:
                        b = (_fusion_result_bytes(ins, callee)
                             + _fusion_operand_bytes(comp, ins, callee))
                    else:
                        b = _bytes_of(ins.result)
                    c = Cost(bytes=b)
                else:
                    c = instr_cost(comp, ins)
                if c.flops or c.bytes or c.coll:
                    _debug_rows.append((c.scaled(m), m, ins.op, ins.line[:140]))

    return total
