"""Production mesh construction.

A function (never module-level) so importing this module touches no jax
device state.  Shapes: single pod = (16, 16) = 256 chips (data × model);
multi-pod = (2, 16, 16) = 512 chips with the extra leading "pod" axis —
data parallelism spans ("pod", "data"), tensor/expert parallelism "model".
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1×1 mesh over the local device (tests/examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto),
    )
