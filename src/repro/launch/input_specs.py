"""ShapeDtypeStruct stand-ins for every (arch × shape × step-kind) cell.

No device allocation: the dry-run lowers/compiles against these specs only.
Returns (specs, logical_axes) pairs so the launcher can derive shardings
from the same rules table the model uses.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..models.registry import ModelBundle

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Dict, Dict]:
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"tokens": SDS((B, S), jnp.int32)}
    axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
    if cfg.is_encdec:
        specs["frames"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        axes["frames"] = ("batch", "seq", "act_embed")
    return specs, axes


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Dict, Dict]:
    return train_batch_specs(cfg, shape)


def decode_token_specs(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Any, Any]:
    B = shape.global_batch
    return SDS((B, 1), jnp.int32), ("batch", None)


def cache_specs(bundle: ModelBundle, shape: ShapeSpec) -> Tuple[Any, Any]:
    """Decode-cache ShapeDtypeStructs via eval_shape (no allocation)."""
    cfg = bundle.cfg
    B, S = shape.global_batch, shape.seq_len

    def build():
        if cfg.is_encdec:
            return bundle.init_cache(B, S, enc_len=S)
        return bundle.init_cache(B, S)

    cache = jax.eval_shape(build)
    axes = bundle.cache_axes(cache)
    return cache, axes


def param_specs(bundle: ModelBundle) -> Tuple[Any, Any]:
    params = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    return params, bundle.param_axes(params)
