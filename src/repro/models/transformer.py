"""Decoder LM spine shared by all 10 architectures.

Layers are partitioned into **segments**: maximal runs of a repeating unit
(e.g. RecurrentGemma's (rglru, rglru, attn) triple, DeepSeek's 3 dense +
58 MoE split).  Each segment stacks its parameters over the repeat axis and
executes under one ``lax.scan`` — compile time and HLO size are O(#segments),
not O(#layers).  Per-layer remat (``jax.checkpoint``) wraps the scanned body
in training.

Block kinds: ``attn`` (GQA, optional sliding window), ``mla`` (DeepSeek),
``rglru`` (Griffin), ``rwkv`` (RWKV-6).  All but ``rwkv`` pair with an MLP or
MoE; ``rwkv`` carries its own channel-mix.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import attention as attn_lib
from . import moe as moe_lib
from . import moe_ep as moe_ep_lib
from . import recurrent as rec_lib
from .layers import Params, mlp_gelu, mlp_swiglu, rms_norm


# ------------------------------------------------------------------ segments
@dataclasses.dataclass(frozen=True)
class Segment:
    unit: Tuple[Tuple[str, bool], ...]  # ((kind, uses_moe), ...)
    repeats: int


def segments(cfg: ModelConfig) -> List[Segment]:
    units = [
        (kind, cfg.layer_uses_moe(i)) for i, kind in enumerate(cfg.blocks())
    ]
    segs: List[Segment] = []
    P = len(cfg.layer_pattern)
    i = 0
    n = len(units)
    while i < n:
        if P > 1 and i + P <= n:
            pat = tuple(units[i : i + P])
            r = 1
            while i + (r + 1) * P <= n and tuple(units[i + r * P : i + (r + 1) * P]) == pat:
                r += 1
            if all(pat == tuple(units[i : i + P]) for _ in range(1)):
                segs.append(Segment(unit=pat, repeats=r))
                i += r * P
                continue
        # maximal run of a single identical unit
        u = units[i]
        r = 1
        while i + r < n and units[i + r] == u:
            r += 1
        segs.append(Segment(unit=(u,), repeats=r))
        i += r
    return segs


# ---------------------------------------------------------------- block init
def _nrm(key, shape, std, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def block_init(cfg: ModelConfig, kind: str, use_moe: bool, key) -> Params:
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    std = 0.02
    ks = iter(jax.random.split(key, 48))
    p: Params = {"norm1": jnp.zeros((D,), jnp.bfloat16)}
    if kind == "attn":
        p.update(
            wq=_nrm(next(ks), (D, H, hd), std),
            wk=_nrm(next(ks), (D, KV, hd), std),
            wv=_nrm(next(ks), (D, KV, hd), std),
            wo=_nrm(next(ks), (H, hd, D), std / max(1, cfg.n_layers) ** 0.5),
        )
        if cfg.qk_norm:
            p["q_norm"] = jnp.zeros((hd,), jnp.bfloat16)
            p["k_norm"] = jnp.zeros((hd,), jnp.bfloat16)
    elif kind == "mla":
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        rd, nd, vd = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
        p.update(
            wq_a=_nrm(next(ks), (D, qr), std),
            q_norm=jnp.zeros((qr,), jnp.bfloat16),
            wq_b=_nrm(next(ks), (qr, H, nd + rd), std),
            wkv_a=_nrm(next(ks), (D, kvr + rd), std),
            kv_norm=jnp.zeros((kvr,), jnp.bfloat16),
            wkv_b=_nrm(next(ks), (kvr, H, nd + vd), std),
            wo=_nrm(next(ks), (H, vd, D), std / max(1, cfg.n_layers) ** 0.5),
        )
    elif kind == "rglru":
        W, K = cfg.lru_width, cfg.conv_width
        p.update(
            w_rec_in=_nrm(next(ks), (D, W), std),
            w_gate_in=_nrm(next(ks), (D, W), std),
            w_out=_nrm(next(ks), (W, D), std),
            conv_w=_nrm(next(ks), (K, W), std),
            a_gate=_nrm(next(ks), (W,), std, jnp.float32),
            a_gate_bias=jnp.zeros((W,), jnp.float32),
            i_gate=_nrm(next(ks), (W,), std, jnp.float32),
            i_gate_bias=jnp.zeros((W,), jnp.float32),
            **{"lambda": jax.random.uniform(next(ks), (W,), jnp.float32, 0.0, 1.0)},
        )
    elif kind == "rwkv":
        hd_r = cfg.rwkv_head_dim
        sl, dl = cfg.rwkv_shift_lora, cfg.rwkv_decay_lora
        p.update(
            norm2=jnp.zeros((D,), jnp.bfloat16),
            mu_x=_nrm(next(ks), (D,), std, jnp.float32),
            mu_rkvwg=_nrm(next(ks), (5, D), std, jnp.float32),
            shift_w1=_nrm(next(ks), (D, 5 * sl), std),
            shift_w2=_nrm(next(ks), (5, sl, D), std),
            w_r=_nrm(next(ks), (D, D), std),
            w_k=_nrm(next(ks), (D, D), std),
            w_v=_nrm(next(ks), (D, D), std),
            w_g=_nrm(next(ks), (D, D), std),
            w_o=_nrm(next(ks), (D, D), std / max(1, cfg.n_layers) ** 0.5),
            w0=_nrm(next(ks), (D,), std, jnp.float32),
            decay_w1=_nrm(next(ks), (D, dl), std),
            decay_w2=_nrm(next(ks), (dl, D), std),
            u=_nrm(next(ks), (D,), std, jnp.float32),
            ln_x_scale=jnp.ones((D,), jnp.float32),
            ln_x_bias=jnp.zeros((D,), jnp.float32),
            ffn_mu_k=_nrm(next(ks), (D,), std, jnp.float32),
            ffn_mu_r=_nrm(next(ks), (D,), std, jnp.float32),
            ffn_k=_nrm(next(ks), (D, cfg.d_ff), std),
            ffn_v=_nrm(next(ks), (cfg.d_ff, D), std),
            ffn_r=_nrm(next(ks), (D, D), std),
        )
        return p  # rwkv has no separate mlp
    else:
        raise ValueError(kind)

    # paired MLP / MoE
    p["norm2"] = jnp.zeros((D,), jnp.bfloat16)
    if use_moe:
        E, Fe = cfg.n_experts, cfg.expert_d_ff
        p["moe"] = {
            "router": _nrm(next(ks), (D, E), std, jnp.float32),
            "w_gate": _nrm(next(ks), (E, D, Fe), std),
            "w_up": _nrm(next(ks), (E, D, Fe), std),
            "w_down": _nrm(next(ks), (E, Fe, D), std / max(1, cfg.n_layers) ** 0.5),
        }
        if cfg.n_shared_experts:
            Fs = Fe * cfg.n_shared_experts
            p["moe"].update(
                shared_w_gate=_nrm(next(ks), (D, Fs), std),
                shared_w_up=_nrm(next(ks), (D, Fs), std),
                shared_w_down=_nrm(next(ks), (Fs, D), std),
            )
    else:
        F = cfg.d_ff
        if cfg.mlp_kind == "swiglu":
            p["mlp"] = {
                "w_gate": _nrm(next(ks), (D, F), std),
                "w_up": _nrm(next(ks), (D, F), std),
                "w_down": _nrm(next(ks), (F, D), std / max(1, cfg.n_layers) ** 0.5),
            }
        else:
            p["mlp"] = {
                "w_in": _nrm(next(ks), (D, F), std),
                "w_out": _nrm(next(ks), (F, D), std / max(1, cfg.n_layers) ** 0.5),
            }
    return p


_AXES_BY_NAME = {
    "norm1": (None,), "norm2": (None,), "q_norm": (None,), "k_norm": (None,),
    "kv_norm": (None,),
    "wq": ("embed", "heads", None), "wk": ("embed", "kv_heads", None),
    "wv": ("embed", "kv_heads", None), "wo": ("heads", None, "embed"),
    "wq_a": ("embed", None), "wq_b": (None, "heads", None),
    "wkv_a": ("embed", None), "wkv_b": (None, "heads", None),
    "w_rec_in": ("embed", "lru"), "w_gate_in": ("embed", "lru"),
    "w_out": ("lru", "embed"), "conv_w": (None, "lru"),
    "a_gate": ("lru",), "a_gate_bias": ("lru",), "i_gate": ("lru",),
    "i_gate_bias": ("lru",), "lambda": ("lru",),
    "mu_x": (None,), "mu_rkvwg": (None, None),
    "shift_w1": ("embed", None), "shift_w2": (None, None, None),
    "w_r": ("embed", None), "w_k": ("embed", None), "w_v": ("embed", None),
    "w_g": ("embed", None), "w_o": ("embed", None),
    "w0": (None,), "decay_w1": ("embed", None), "decay_w2": (None, None),
    "u": (None,), "ln_x_scale": (None,), "ln_x_bias": (None,),
    "ffn_mu_k": (None,), "ffn_mu_r": (None,),
    "ffn_k": ("embed", "mlp"), "ffn_v": ("mlp", "embed"), "ffn_r": ("embed", None),
    "mlp": {
        "w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
        "w_down": ("mlp", "embed"), "w_in": ("embed", "mlp"),
        "w_out": ("mlp", "embed"),
    },
    "moe": {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None), "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
        "shared_w_gate": ("embed", "mlp"), "shared_w_up": ("embed", "mlp"),
        "shared_w_down": ("mlp", "embed"),
    },
}


def block_axes(p: Params, *, stacked: bool, moe_impl: str = "dispatch") -> Any:
    """Logical axes pytree matching a block's params (optionally +stack dim)."""

    def of(name_path, leaf):
        table = _AXES_BY_NAME
        for name in name_path[:-1]:
            table = table[name]
        axes = table[name_path[-1]]
        if moe_impl == "ep" and name_path[0] == "moe" and axes[0] == "experts":
            # EP: experts over the (data, model) mesh; the inner dim ZeRO-3
            # shards over "pod" and is regathered inside the shard_map body
            axes = ("experts_ep", "expert_fsdp") + (None,) * (len(axes) - 2)
        return (("stack",) + tuple(axes)) if stacked else tuple(axes)

    out = {}
    for k, v in p.items():
        if isinstance(v, dict):
            out[k] = {k2: of((k, k2), v2) for k2, v2 in v.items()}
        else:
            out[k] = of((k,), v)
    return out


# --------------------------------------------------------------- block apply
def block_apply(
    cfg: ModelConfig,
    kind: str,
    use_moe: bool,
    p: Params,
    x: jnp.ndarray,
    *,
    mode: str,                      # train | prefill | decode
    cache: Optional[Dict] = None,
    pos: Any = 0,
    window: int = 0,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache: Optional[Dict] = None
    if kind == "attn":
        w = window or cfg.attn_window
        if mode == "train":
            a = attn_lib.gqa_forward(cfg, p, h, window=w)
        elif mode == "prefill":
            a, new_cache = attn_lib.gqa_prefill(cfg, p, h, cache, window=w)
        else:
            a, new_cache = attn_lib.gqa_decode(cfg, p, h, cache, pos, window=w)
        x = x + a
    elif kind == "mla":
        if mode == "train":
            a = attn_lib.mla_forward(cfg, p, h)
        elif mode == "prefill":
            a, new_cache = attn_lib.mla_prefill(cfg, p, h, cache)
        else:
            a, new_cache = attn_lib.mla_decode(cfg, p, h, cache, pos)
        x = x + a
    elif kind == "rglru":
        a, new_cache = rec_lib.rglru_block(cfg, p, h, cache)
        x = x + a
    elif kind == "rwkv":
        # rwkv block manages both sublayers + its own norms
        y, new_cache = rec_lib.rwkv_block(
            cfg, {**p}, x, cache
        )
        return y, new_cache
    else:
        raise ValueError(kind)

    h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
    if use_moe:
        moe_fn = (moe_ep_lib.moe_block_ep if cfg.moe_impl == "ep"
                  else moe_lib.moe_block)
        x = x + moe_fn(cfg, p["moe"], h2)
    else:
        mlp = mlp_swiglu if cfg.mlp_kind == "swiglu" else mlp_gelu
        x = x + mlp(h2, p["mlp"])
    return x, new_cache


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     *, window: int = 0) -> Dict:
    if kind == "attn":
        w = window or (cfg.attn_window if len(cfg.layer_pattern) > 1 else 0)
        return attn_lib.gqa_init_cache(cfg, batch, max_len, window=w)
    if kind == "mla":
        return attn_lib.mla_init_cache(cfg, batch, max_len)
    if kind == "rglru":
        return rec_lib.rglru_init_state(cfg, batch)
    if kind == "rwkv":
        return rec_lib.rwkv_init_state(cfg, batch)
    raise ValueError(kind)


# ------------------------------------------------------------------- the LM
def init_params(cfg: ModelConfig, rng) -> Params:
    D, V = cfg.d_model, cfg.padded_vocab
    keys = jax.random.split(rng, 4 + len(segments(cfg)))
    p: Params = {
        "embed": _nrm(keys[0], (V, D), 0.02),
        "final_norm": jnp.zeros((D,), jnp.bfloat16),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _nrm(keys[1], (D, V), 0.02)
    segs = segments(cfg)
    p["segments"] = []
    for si, seg in enumerate(segs):
        seg_keys = jax.random.split(keys[3 + si], seg.repeats * len(seg.unit))
        stacked = []
        for j, (kind, use_moe) in enumerate(seg.unit):
            per_rep = [
                block_init(cfg, kind, use_moe, seg_keys[r * len(seg.unit) + j])
                for r in range(seg.repeats)
            ]
            stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep))
        p["segments"].append(stacked)
    if cfg.mtp:
        p["mtp"] = {
            "proj": _nrm(keys[2], (2 * D, D), 0.02),
            "block": block_init(cfg, cfg.blocks()[-1], False, keys[2]),
        }
    return p


def param_axes(cfg: ModelConfig, params: Params) -> Any:
    axes: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": (None,),
    }
    if "lm_head" in params:
        axes["lm_head"] = ("embed", "vocab")
    axes["segments"] = [
        [block_axes(sub, stacked=True, moe_impl=cfg.moe_impl) for sub in seg]
        for seg in params["segments"]
    ]
    if "mtp" in params:
        axes["mtp"] = {
            "proj": ("embed", None),
            "block": block_axes(params["mtp"]["block"], stacked=False,
                                moe_impl=cfg.moe_impl),
        }
    return axes


def _run_segments(cfg: ModelConfig, params: Params, x: jnp.ndarray, *,
                  mode: str, caches=None, pos=0, remat: bool = True):
    segs = segments(cfg)
    new_caches = [] if caches is not None else None
    for si, seg in enumerate(segs):
        p_seg = params["segments"][si]
        c_seg = caches[si] if caches is not None else None

        def step(carry, xs, _seg=seg):
            h = carry
            layer_params = xs[0]
            layer_caches = xs[1] if c_seg is not None else [None] * len(_seg.unit)
            outs = []
            for j, (kind, use_moe) in enumerate(_seg.unit):
                h, nc = block_apply(
                    cfg, kind, use_moe, layer_params[j], h,
                    mode=mode, cache=layer_caches[j], pos=pos,
                )
                outs.append(nc if nc is not None else {})
            return h, tuple(outs)

        if remat and mode == "train":
            # REPRO_REMAT_POLICY: full (default) saves only the layer carry;
            # dots additionally saves matmul outputs (no dot recompute in
            # bwd) — the compute<->memory knob of §Perf/qwen3.
            policy = os.environ.get("REPRO_REMAT_POLICY", "full")
            if policy == "dots":
                body = jax.checkpoint(
                    step,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                )
            else:
                body = jax.checkpoint(step)
        else:
            body = step
        xs = (p_seg, c_seg) if c_seg is not None else (p_seg,)
        x, seg_caches = jax.lax.scan(body, x, xs)
        if new_caches is not None:
            new_caches.append(list(seg_caches))
    return x, new_caches


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jnp.ndarray):
    x = params["embed"][tokens].astype(jnp.bfloat16)
    return shard(x, "batch", "seq", "act_embed")


def lm_logits(cfg: ModelConfig, params: Params, x: jnp.ndarray):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    return shard(logits, "batch", "seq", "vocab")


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                   *, inputs_embeds: Optional[jnp.ndarray] = None,
                   remat: bool = True):
    """Final hidden states (B, S, D) + aux (mtp hidden).  The train loss uses
    this with a *chunked* head so (B, S, V) logits never materialize."""
    x = inputs_embeds if inputs_embeds is not None else embed_tokens(cfg, params, tokens)
    x, _ = _run_segments(cfg, params, x, mode="train", remat=remat)
    aux = {}
    if cfg.mtp and "mtp" in params:
        # predict token t+2 from (h_t, emb(token_{t+1})) through one extra block
        emb_next = embed_tokens(cfg, params, tokens)[:, 1:]
        h_mtp = jnp.concatenate([x[:, :-1], emb_next], axis=-1)
        h_mtp = jnp.einsum("bsd,dk->bsk", h_mtp, params["mtp"]["proj"])
        kind = cfg.blocks()[-1]
        h_mtp, _ = block_apply(cfg, kind, False, params["mtp"]["block"], h_mtp,
                               mode="train")
        aux["mtp_hidden"] = h_mtp
    return x, aux


def forward_train(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
                  *, inputs_embeds: Optional[jnp.ndarray] = None,
                  remat: bool = True):
    """Teacher-forcing logits (B, S, V); tokens (B, S) int32."""
    x, aux_h = forward_hidden(cfg, params, tokens,
                              inputs_embeds=inputs_embeds, remat=remat)
    logits = lm_logits(cfg, params, x)
    aux = {}
    if "mtp_hidden" in aux_h:
        aux["mtp_logits"] = lm_logits(cfg, params, aux_h["mtp_hidden"])
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    caches = []
    for seg in segments(cfg):
        seg_caches = []
        for (kind, _moe) in seg.unit:
            one = block_cache_init(cfg, kind, batch, max_len)
            seg_caches.append(
                jax.tree.map(
                    lambda x: jnp.zeros((seg.repeats,) + x.shape, x.dtype), one
                )
            )
        caches.append(seg_caches)
    return caches


def cache_axes(cfg: ModelConfig, cache: list) -> Any:
    """Logical axes for the decode cache: KV tensors shard over batch (+kv
    heads where divisible); recurrent states shard over batch only."""
    paths = jax.tree_util.tree_flatten_with_path(cache)[0]
    treedef = jax.tree_util.tree_structure(cache)
    leaves = []
    for path, x in paths:
        last = None
        for p in path:
            if hasattr(p, "key"):
                last = str(p.key)
        nd = x.ndim
        if last in ("k", "v") and nd == 5:       # (stack, B, S, KV, hd)
            axes = ("stack", "batch", "kv_seq", "kv_heads", None)
        elif last == "ckv" and nd == 4:          # (stack, B, S, latent)
            axes = ("stack", "batch", "kv_seq", None)
        else:                                     # recurrent states
            axes = tuple(["stack", "batch"] + [None] * (nd - 2))
        leaves.append(axes[:nd])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def prefill(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
            cache: list, *, inputs_embeds: Optional[jnp.ndarray] = None):
    x = inputs_embeds if inputs_embeds is not None else embed_tokens(cfg, params, tokens)
    x, new_caches = _run_segments(cfg, params, x, mode="prefill", caches=cache)
    logits = lm_logits(cfg, params, x[:, -1:])
    return logits, new_caches


def decode_step(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                cache: list, pos):
    """token (B, 1) int32; pos scalar int32 — current write position."""
    x = embed_tokens(cfg, params, token)
    x, new_caches = _run_segments(cfg, params, x, mode="decode", caches=cache,
                                  pos=pos)
    logits = lm_logits(cfg, params, x)
    return logits, new_caches
