"""Mixture-of-Experts with scatter-based dispatch (TPU-native, DESIGN.md §10).

No GShard one-hot dispatch einsums: position-in-expert comes from a cumsum
over a (tokens, E) one-hot, tokens are scattered into per-expert capacity
buffers, experts run as one stacked einsum (EP: experts sharded over
"model"), and results gather back with routing weights.  Capacity overflow
drops tokens (standard dropping MoE, capacity_factor configurable);
dropped tokens fall through via the residual connection.

Shapes (per layer):
  x        (B, S, D)
  router   (D, E)
  experts  w_gate/w_up (E, D, F), w_down (E, F, D)
  buffers  (B, E, C, D) with C = ceil(S·top_k·cf / E)   [B = dispatch groups]
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard

Params = Dict[str, Any]


def capacity(cfg: ModelConfig, seq: int) -> int:
    c = math.ceil(seq * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(1, c)


def _expert_ffn(blocks: jnp.ndarray, p: Params) -> jnp.ndarray:
    """blocks: (B, E, C, D) -> (B, E, C, D), stacked SwiGLU per expert."""
    h = jnp.einsum("becd,edf->becf", blocks, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", blocks, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(blocks.dtype) * u
    return jnp.einsum("becf,efd->becd", h, p["w_down"])


def moe_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)

    # ---- routing ----------------------------------------------------------
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, K)           # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    top_w = top_w.astype(x.dtype)

    # ---- position-in-expert (cumsum over the sequence, per expert) --------
    # one-hot over experts for each of the K choices, summed -> (B, S, E)
    sel = jax.nn.one_hot(top_e, E, dtype=jnp.int32).sum(axis=2)
    pos_base = jnp.cumsum(sel, axis=1) - sel          # tokens before s, per e
    # within-token ordering of the K choices hitting the same expert
    k_onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)      # (B,S,K,E)
    intra = jnp.cumsum(k_onehot, axis=2) - k_onehot            # (B,S,K,E)
    pos = (
        jnp.take_along_axis(pos_base[:, :, None, :], top_e[..., None], axis=3)
        + jnp.take_along_axis(intra, top_e[..., None], axis=3)
    )[..., 0]                                                   # (B, S, K)

    keep = pos < C
    slot = jnp.where(keep, pos, C)                   # C = overflow bin

    # ---- dispatch: scatter tokens into (B, E, C+1, D) ----------------------
    buf = jnp.zeros((B, E, C + 1, D), x.dtype)
    b_idx = jnp.arange(B)[:, None, None]
    buf = buf.at[b_idx, top_e, slot].set(x[:, :, None, :], mode="drop")
    buf = shard(buf[:, :, :C], "batch", "experts", None, None)

    # ---- expert compute (EP over "model") ----------------------------------
    out_buf = _expert_ffn(buf, p)
    out_buf = shard(out_buf, "batch", "experts", None, None)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((B, E, 1, D), out_buf.dtype)], axis=2
    )

    # ---- combine: gather + weighted sum over the K routes ------------------
    gathered = out_buf[b_idx, top_e, slot]           # (B, S, K, D)
    gathered = jnp.where(keep[..., None], gathered, 0)
    y = jnp.einsum("bskd,bsk->bsd", gathered, top_w)

    # ---- shared experts (DeepSeek) -----------------------------------------
    if cfg.n_shared_experts:
        hs = jnp.einsum("bsd,df->bsf", x, p["shared_w_gate"])
        us = jnp.einsum("bsd,df->bsf", x, p["shared_w_up"])
        hs = jax.nn.silu(hs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_w_down"])
    return y


def aux_load_balance_loss(cfg: ModelConfig, logits_f32: jnp.ndarray,
                          top_e: jnp.ndarray) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss (used by the train loop)."""
    E = cfg.n_experts
    gates = jax.nn.softmax(logits_f32, axis=-1)
    me = gates.mean(axis=(0, 1))
    ce = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
    return E * jnp.sum(me * ce)
