"""Expert-parallel MoE via shard_map + all_to_all (the §Perf iteration).

Why: with the pure-pjit scatter/gather dispatch (moe.py), GSPMD cannot infer
a sharded layout for the combine gather at deepseek scale and falls back to
*replicated* (B,S,K,D) intermediates with f32 all-reduces over the whole
mesh — the dry-run measured 1.1e14 collective bytes/device/step (≈3000s of
ICI time; EXPERIMENTS.md §Perf).  The fix is the layout every production
MoE system uses: **experts stationary, tokens move**.

Layout (inside one shard_map over the full mesh):
  * activations arrive sharded batch→(pod, data) and seq→model — the model
    axis carries sequence parallelism through the MoE, so all N = data×model
    devices hold distinct tokens;
  * expert weights are sharded E→(data, model) (1 expert/device at E=256;
    per-device bytes = total/256 — same as FSDP, but **never regathered**);
  * local routing → per-expert staging (E, c_loc, D) → ``all_to_all`` over
    the EP axes → local expert FFN → ``all_to_all`` back → local combine.

Per layer/microbatch the only collectives are the two all-to-alls
(~tokens·k·cf·D/N bytes each) — ~100× less than the baseline's replicated
all-reduces.  EP group = (data, model) when E divides data·model (deepseek),
else (model,) (phi-3.5, E=16); "pod" stays pure DP (experts replicated
across pods).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed import sharding as shlib

Params = Dict[str, Any]


def ep_axes_for(cfg: ModelConfig, mesh) -> Optional[Tuple[str, ...]]:
    """Largest mesh-axis group the expert dim divides; None => fall back."""
    names = mesh.axis_names
    dm = tuple(a for a in ("data", "model") if a in names)
    size_dm = 1
    for a in dm:
        size_dm *= mesh.shape[a]
    if dm and cfg.n_experts % size_dm == 0:
        return dm
    if "model" in names and cfg.n_experts % mesh.shape["model"] == 0:
        return ("model",)
    return None


def moe_block_ep(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Drop-in replacement for moe.moe_block using explicit EP collectives.

    Requires an active logical_sharding context (mesh).  Shared experts run
    outside the shard_map as a plain dense block.
    """
    mesh = shlib._CTX.mesh
    if mesh is None:
        return _moe_local(cfg, p, x)  # single-device path (tests)
    ep = ep_axes_for(cfg, mesh)
    if ep is None:
        from .moe import moe_block  # arch whose E fits no axis: baseline

        return moe_block(cfg, p, x)

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_ep = 1
    for a in ep:
        n_ep *= mesh.shape[a]
    e_loc = E // n_ep

    # batch stays sharded over every DP axis (the EP group is only the
    # all_to_all communicator); seq additionally splits over "model" so all
    # N devices hold distinct tokens (sequence parallelism through the MoE).
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    seq_ax = "model" if "model" in mesh.shape else None
    x_spec = P(dp if dp else None, seq_ax, None)
    # multi-pod: expert weights additionally ZeRO-3-shard their inner dim
    # over "pod" at rest and are all-gathered inside the body per layer —
    # otherwise 671B of experts would be pod-replicated (28 GB/dev args,
    # measured) and never fit; the gather transpose reduce-scatters the
    # gradients back over pod automatically.
    pod_fsdp = "pod" in mesh.shape and "pod" not in ep
    w_spec = P(ep, "pod", None) if pod_fsdp else P(ep, None, None)
    router_spec = P(None, None)

    b_loc = B
    for a in dp:
        b_loc //= mesh.shape[a]
    s_loc = S // (mesh.shape["model"] if seq_ax else 1)
    t_loc = b_loc * s_loc
    c_loc = max(1, math.ceil(t_loc * K * cfg.capacity_factor / E))

    def body(xb, router, wg, wu, wd):
        # xb: (b_loc, s_loc, D); wg/wu: (e_loc, D, F); wd: (e_loc, F, D)
        if pod_fsdp:  # ZeRO-3: regather this layer's experts over the pod axis
            wg = jax.lax.all_gather(wg, "pod", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "pod", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "pod", axis=1, tiled=True)
        xt = xb.reshape(t_loc, D)
        logits = (xt @ router).astype(jnp.float32)          # (T, E)
        gates = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(gates, K)               # (T, K)
        top_w = (top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
                 ).astype(xb.dtype)

        # position-in-expert over the flat (T*K) routing decisions
        flat_e = top_e.reshape(-1)                           # (T*K,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)          # before me, per e
        slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = slot < c_loc
        slot = jnp.where(keep, slot, c_loc)                  # overflow bin

        # stage tokens per destination expert: (E, c_loc+1, D)
        staging = jnp.zeros((E, c_loc + 1, D), xb.dtype)
        tok_idx = jnp.repeat(jnp.arange(t_loc), K)
        staging = staging.at[flat_e, slot].set(xt[tok_idx], mode="drop")
        staging = staging[:, :c_loc]                         # (E, c, D)

        # ---- tokens -> expert owners --------------------------------------
        # (E, c, D) -> (n_ep senders, e_loc, c, D) on the owning device
        recv = jax.lax.all_to_all(
            staging.reshape(n_ep, e_loc, c_loc, D), ep, 0, 0, tiled=False
        )  # (n_ep, e_loc, c, D): dim0 = sender rank
        qs = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_ep * c_loc, D)

        # ---- local expert FFN ----------------------------------------------
        h = jnp.einsum("exd,edf->exf", qs, wg)
        u = jnp.einsum("exd,edf->exf", qs, wu)
        h = jax.nn.silu(h.astype(jnp.float32)).astype(qs.dtype) * u
        out = jnp.einsum("exf,efd->exd", h, wd)              # (e_loc, n_ep*c, D)

        # ---- back to token owners -------------------------------------------
        back = out.reshape(e_loc, n_ep, c_loc, D).transpose(1, 0, 2, 3)
        mine = jax.lax.all_to_all(back, ep, 0, 0, tiled=False)
        # (n_ep, e_loc, c, D): dim0 = expert-owner rank == expert blocks
        out_buf = mine.reshape(E, c_loc, D)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((E, 1, D), out_buf.dtype)], axis=1
        )

        # ---- combine ---------------------------------------------------------
        gathered = out_buf[flat_e, slot]                     # (T*K, D)
        gathered = jnp.where(keep[:, None], gathered, 0)
        y = jnp.einsum("tkd,tk->td",
                       gathered.reshape(t_loc, K, D), top_w)
        return y.reshape(b_loc, s_loc, D)

    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, router_spec, w_spec, w_spec, w_spec),
        out_specs=x_spec,
        check_rep=False,
    )(x, p["router"].astype(x.dtype), p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        hs = jnp.einsum("bsd,df->bsf", x, p["shared_w_gate"])
        us = jnp.einsum("bsd,df->bsf", x, p["shared_w_up"])
        hs = jax.nn.silu(hs.astype(jnp.float32)).astype(x.dtype) * us
        y = y + jnp.einsum("bsf,fd->bsd", hs, p["shared_w_down"])
    return y


def _moe_local(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """No-mesh fallback: identical math, single device (correctness tests)."""
    from .moe import moe_block

    return moe_block(cfg, p, x)
