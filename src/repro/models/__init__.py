from .registry import ModelBundle, count_params, get_model

__all__ = ["get_model", "ModelBundle", "count_params"]
