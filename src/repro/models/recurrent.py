"""Recurrent blocks: RG-LRU (Griffin/RecurrentGemma) and RWKV-6 (Finch).

Both are sub-quadratic: O(S) train/prefill via scans, O(1)-state decode —
they carry the ``long_500k`` cell.

* RG-LRU block (arXiv:2402.19427): two linear branches from the input;
  the recurrent branch runs conv1d(width 4) → RG-LRU; the gate branch is
  GeLU; merged output projects back to d_model.  The RG-LRU recurrence
      h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t),
      a_t = exp(−c·softplus(Λ)·σ(W_a x_t))
  is linear in h, so train/prefill use ``jax.lax.associative_scan``
  (log-depth — a TPU-friendly departure from the sequential GPU scan,
  recorded in DESIGN.md §5).

* RWKV-6 (arXiv:2404.05892): data-dependent token-shift (LoRA), data-
  dependent per-channel decay w_t, matrix-valued state per head
      S_t = diag(w_t) S_{t-1} + kᵀ_t v_t,   out_t = r_t·(S_{t-1} + diag(u)kᵀ_t v_t)
  Sequential ``lax.scan`` over time is the baseline; the chunked parallel
  form is a §Perf iteration.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .layers import Params, group_norm_heads, rms_norm


# ================================================================== RG-LRU ==
_C = 8.0  # Griffin's fixed recurrence sharpness


def _rglru_scan(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t*h_{t-1} + bx_t via associative scan; returns (all h, last h).

    a, bx: (B, S, W) f32; h0: (B, W) f32.
    """
    # fold h0 into the first step
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return hh, hh[:, -1]


def rglru_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Core RG-LRU over a (B, S, W) branch input; returns (y, h_last)."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,w->bsw", xf, p["a_gate"].astype(jnp.float32))
                       + p["a_gate_bias"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,w->bsw", xf, p["i_gate"].astype(jnp.float32))
                       + p["i_gate_bias"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    h, h_last = _rglru_scan(a, gated, h0.astype(jnp.float32))
    return h.astype(x.dtype), h_last


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, buf: jnp.ndarray):
    """Depthwise causal conv1d, width K.  x: (B,S,W); w: (K,W);
    buf: (B,K-1,W) past inputs.  Returns (y, new_buf)."""
    K = w.shape[0]
    ext = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    y = sum(
        ext[:, i : ext.shape[1] - (K - 1 - i)] * w[i][None, None, :]
        for i in range(K)
    )
    new_buf = ext[:, -(K - 1):] if K > 1 else buf
    return y, new_buf


def rglru_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                state: Dict | None) -> Tuple[jnp.ndarray, Dict]:
    """Full Griffin recurrent block.  state: {"h": (B,W), "conv": (B,K-1,W)}."""
    B, S, D = x.shape
    W = cfg.lru_width
    if state is None:
        state = rglru_init_state(cfg, B)
    xr = jnp.einsum("bsd,dw->bsw", x, p["w_rec_in"])
    xg = jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"])
    xr, conv_buf = _causal_conv(xr, p["conv_w"], state["conv"])
    xr = shard(xr, "batch", "seq", "lru")
    y, h_last = rglru_apply(cfg, p, xr, state["h"])
    y = y * jax.nn.gelu(xg.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"])
    return out, {"h": h_last, "conv": conv_buf}


def rglru_init_state(cfg: ModelConfig, batch: int) -> Dict:
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.lru_width), jnp.bfloat16),
    }


# ================================================================== RWKV-6 ==
WKV_CHUNK = 32
_LOG_DECAY_FLOOR = -2.5  # exp(2.5·32)≈5e34 stays inside f32; see _wkv_chunked


def _wkv_chunked(r, k, v, w, u, s0):
    """Chunked-parallel WKV (the §Perf iteration for the ssm family).

    The sequential scan reads+writes the (B,H,64,64) f32 state per *token*
    (dry-run: 85 s/step of HBM time at train_4k).  The chunked form scans
    per *chunk* and turns intra-chunk work into MXU matmuls:

        out_t = r̃_t·S_0 + [(r̃ k̃ᵀ) ⊙ strictly-causal] v + (Σ_d r u k)·v_t
        S_L   = diag(e^{la_L})·(S_0 + k̃ᵀ v)
      with la = cumsum(log w) (per channel), r̃_t = r_t·e^{la_{t-1}},
           k̃_i = k_i·e^{-la_i}.

    Per-step log-decay is clamped to ``_LOG_DECAY_FLOOR`` so e^{-la} stays
    finite in f32 (same trick as flash-linear-attention); channels decaying
    faster than e^{-2.5}/step are numerically dead past one step anyway.
    All shapes (B, S, H, hd) f32; s0 (B, H, hd, hd).
    """
    B, S, H, hd = r.shape
    L = WKV_CHUNK
    nC = S // L
    log_w = jnp.clip(jnp.log(jnp.maximum(w, 1e-38)), _LOG_DECAY_FLOOR, 0.0)

    def to_chunks(x):
        return x.reshape(B, nC, L, H, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, log_w))
    bonus_all = jnp.einsum("bshk,bshk->bsh", r * u[None, None], k)[..., None] * v
    bonusc = to_chunks(bonus_all.reshape(B, S, H, hd))
    mask = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :]).astype(jnp.float32)

    def chunk_step(s, inp):
        rb, kb, vb, lwb, bb = inp          # (B, L, H, hd)
        la = jnp.cumsum(lwb, axis=1)       # inclusive
        la_prev = la - lwb                 # exclusive
        r_t = rb * jnp.exp(la_prev)
        k_t = kb * jnp.exp(-la)
        inter = jnp.einsum("blhk,bhkv->blhv", r_t, s)
        scores = jnp.einsum("blhk,bmhk->bhlm", r_t, k_t)
        intra = jnp.einsum("bhlm,bmhv->blhv", scores * mask[None, None], vb)
        out = inter + intra + bb
        kv = jnp.einsum("blhk,blhv->bhkv", k_t, vb)
        s_new = (s + kv) * jnp.exp(la[:, -1])[..., None]  # la[:,-1]: (B,H,hd_k)
        return s_new, out

    s_final, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, lwc, bonusc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out, s_final


def _token_shift(x: jnp.ndarray, last: jnp.ndarray) -> jnp.ndarray:
    """x_{t-1} stream: shift right by one, seeding with `last`."""
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)


def rwkv_time_mix(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                  state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """RWKV-6 attention analogue.  state: {"s": (B,H,hd,hd) f32, "x_last": (B,D)}."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.rwkv_head_dim
    # token-shift mixing in bf16 (the wkv core below stays f32): the 5-way
    # (B,S,5,D) ddlerp tensors were the #2 HBM term at train_4k — §Perf/rwkv6
    prev = _token_shift(x, state["x_last"].astype(x.dtype))
    dx = prev - x

    # data-dependent token shift (ddlerp, LoRA rank = rwkv_shift_lora)
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["shift_w1"],
                               preferred_element_type=jnp.float32))
    lora = lora.reshape(B, S, 5, cfg.rwkv_shift_lora).astype(x.dtype)
    mix = jnp.einsum("bskr,krd->bskd", lora, p["shift_w2"])
    mu = p["mu_rkvwg"].astype(x.dtype)  # (5, D)
    xs = x[:, :, None, :] + dx[:, :, None, :] * (mu[None, None] + mix)
    xr, xk, xv, xw, xg = [xs[:, :, i] for i in range(5)]

    r = jnp.einsum("bsd,dh->bsh", xr, p["w_r"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", xk, p["w_k"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,dh->bsh", xv, p["w_v"]).reshape(B, S, H, hd)
    g = jnp.einsum("bsd,dh->bsh", xg, p["w_g"])

    # data-dependent decay (LoRA rank rwkv_decay_lora) — f32: enters exp()
    wl = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                             p["decay_w1"].astype(jnp.float32)))
    w = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsr,rd->bsd", wl, p["decay_w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w)).reshape(B, S, H, hd)    # per-channel decay in (0,1)

    u = p["u"].astype(jnp.float32).reshape(H, hd)

    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    if cfg.rwkv_impl == "chunked" and S % WKV_CHUNK == 0 and S > 1:
        out, s_final = _wkv_chunked(rf, kf, vf, w, u,
                                    state["s"].astype(jnp.float32))
    else:
        def step(s, inp):
            rt, kt, vt, wt = inp  # (B,H,hd) each
            kv = kt[..., :, None] * vt[..., None, :]          # (B,H,hd,hd)
            out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
            s = wt[..., :, None] * s + kv
            return s, out

        xs_t = lambda a: a.transpose(1, 0, 2, 3)  # (S,B,H,hd)
        s_final, outs = jax.lax.scan(
            step, state["s"].astype(jnp.float32),
            (xs_t(rf), xs_t(kf), xs_t(vf), xs_t(w)),
        )
        out = outs.transpose(1, 0, 2, 3)                     # (B,S,H,hd)
    out = group_norm_heads(out, p["ln_x_scale"].astype(jnp.float32).reshape(H, hd),
                           p["ln_x_bias"].astype(jnp.float32).reshape(H, hd))
    out = out.reshape(B, S, D).astype(x.dtype) * jax.nn.silu(
        g.astype(jnp.float32)
    ).astype(x.dtype)
    y = jnp.einsum("bsd,dh->bsh", out, p["w_o"])
    return y.astype(x.dtype), {"s": s_final, "x_last": x[:, -1]}


def rwkv_channel_mix(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                     state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """RWKV FFN (channel mix) with simple token shift.  state: {"x_last": (B,D)}."""
    xf = x.astype(jnp.float32)
    prev = _token_shift(xf, state["x_last"].astype(jnp.float32))
    dx = prev - xf
    xk = (xf + dx * p["ffn_mu_k"].astype(jnp.float32)).astype(x.dtype)
    xr = (xf + dx * p["ffn_mu_r"].astype(jnp.float32)).astype(x.dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["ffn_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    kk = shard(kk, "batch", "seq", "mlp")
    vv = jnp.einsum("bsf,fd->bsd", kk, p["ffn_v"])
    rr = jax.nn.sigmoid(
        jnp.einsum("bsd,dh->bsh", xr, p["ffn_r"]).astype(jnp.float32)
    ).astype(x.dtype)
    return vv * rr, {"x_last": x[:, -1]}


def rwkv_block(cfg: ModelConfig, p: Params, x: jnp.ndarray,
               state: Dict | None) -> Tuple[jnp.ndarray, Dict]:
    B = x.shape[0]
    if state is None:
        state = rwkv_init_state(cfg, B)
    a, st_t = rwkv_time_mix(cfg, p, rms_norm(x, p["norm1"], cfg.norm_eps),
                            state["time"])
    x = x + a
    b, st_c = rwkv_channel_mix(cfg, p, rms_norm(x, p["norm2"], cfg.norm_eps),
                               state["chan"])
    return x + b, {"time": st_t, "chan": st_c}


def rwkv_init_state(cfg: ModelConfig, batch: int) -> Dict:
    H, hd = cfg.n_heads, cfg.rwkv_head_dim
    return {
        "time": {
            "s": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "x_last": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
        },
        "chan": {"x_last": jnp.zeros((batch, cfg.d_model), jnp.bfloat16)},
    }
