"""Encoder-decoder backbone (SeamlessM4T-medium).

Per the assignment spec the modality frontend is a **stub**: ``input_specs``
feeds precomputed audio-frame embeddings (B, S_enc, d_model) straight into
the encoder.  The decoder is a standard causal stack with cross-attention
onto the encoder output; decode caches both the self-attn KV and the
(once-computed) cross-attn KV.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import attention as attn_lib
from .layers import Params, chunked_attention, mlp_gelu, mlp_swiglu, rms_norm
from .transformer import _nrm, embed_tokens, lm_logits


def _attn_params(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = iter(jax.random.split(key, 8))
    return {
        "wq": _nrm(next(ks), (D, H, hd), 0.02),
        "wk": _nrm(next(ks), (D, KV, hd), 0.02),
        "wv": _nrm(next(ks), (D, KV, hd), 0.02),
        "wo": _nrm(next(ks), (H, hd, D), 0.02 / max(1, cfg.n_layers) ** 0.5),
    }


def _mlp_params(cfg: ModelConfig, key) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    ks = iter(jax.random.split(key, 4))
    if cfg.mlp_kind == "swiglu":
        return {"w_gate": _nrm(next(ks), (D, F), 0.02),
                "w_up": _nrm(next(ks), (D, F), 0.02),
                "w_down": _nrm(next(ks), (F, D), 0.02)}
    return {"w_in": _nrm(next(ks), (D, F), 0.02),
            "w_out": _nrm(next(ks), (F, D), 0.02)}


def init_params_encdec(cfg: ModelConfig, rng) -> Params:
    D, V = cfg.d_model, cfg.padded_vocab
    k_emb, k_enc, k_dec, k_head = jax.random.split(rng, 4)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers * 2)
    dec_keys = jax.random.split(k_dec, cfg.n_layers * 3)

    def stack(fn, keys, n):
        per = [fn(keys[i]) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    params: Params = {
        "embed": _nrm(k_emb, (V, D), 0.02),
        "final_norm": jnp.zeros((D,), jnp.bfloat16),
        "lm_head": _nrm(k_head, (D, V), 0.02),
        "enc": {
            "attn": stack(lambda k: _attn_params(cfg, k), enc_keys[: cfg.enc_layers], cfg.enc_layers),
            "mlp": stack(lambda k: _mlp_params(cfg, k), enc_keys[cfg.enc_layers :], cfg.enc_layers),
            "norm1": jnp.zeros((cfg.enc_layers, D), jnp.bfloat16),
            "norm2": jnp.zeros((cfg.enc_layers, D), jnp.bfloat16),
            "final_norm": jnp.zeros((D,), jnp.bfloat16),
        },
        "dec": {
            "self": stack(lambda k: _attn_params(cfg, k), dec_keys[: cfg.n_layers], cfg.n_layers),
            "cross": stack(lambda k: _attn_params(cfg, k, cross=True), dec_keys[cfg.n_layers : 2 * cfg.n_layers], cfg.n_layers),
            "mlp": stack(lambda k: _mlp_params(cfg, k), dec_keys[2 * cfg.n_layers :], cfg.n_layers),
            "norm1": jnp.zeros((cfg.n_layers, D), jnp.bfloat16),
            "norm2": jnp.zeros((cfg.n_layers, D), jnp.bfloat16),
            "norm3": jnp.zeros((cfg.n_layers, D), jnp.bfloat16),
        },
    }
    return params


_ATTN_AXES = {"wq": ("embed", "heads", None), "wk": ("embed", "kv_heads", None),
              "wv": ("embed", "kv_heads", None), "wo": ("heads", None, "embed")}
_MLP_AXES_SW = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                "w_down": ("mlp", "embed")}
_MLP_AXES_GE = {"w_in": ("embed", "mlp"), "w_out": ("mlp", "embed")}


def param_axes_encdec(cfg: ModelConfig, params: Params) -> Any:
    st = lambda d: {k: ("stack",) + v for k, v in d.items()}
    mlp_axes = _MLP_AXES_SW if cfg.mlp_kind == "swiglu" else _MLP_AXES_GE
    return {
        "embed": ("vocab", "embed"),
        "final_norm": (None,),
        "lm_head": ("embed", "vocab"),
        "enc": {
            "attn": st(_ATTN_AXES), "mlp": st(mlp_axes),
            "norm1": ("stack", None), "norm2": ("stack", None),
            "final_norm": (None,),
        },
        "dec": {
            "self": st(_ATTN_AXES), "cross": st(_ATTN_AXES), "mlp": st(mlp_axes),
            "norm1": ("stack", None), "norm2": ("stack", None),
            "norm3": ("stack", None),
        },
    }


def _mlp(cfg: ModelConfig, p, x):
    return (mlp_swiglu if cfg.mlp_kind == "swiglu" else mlp_gelu)(x, p)


def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, D) bf16 — the stub frontend's output."""
    enc = params["enc"]
    x = shard(frames.astype(jnp.bfloat16), "batch", "seq", "act_embed")

    def layer(x, lp):
        attn_p, mlp_p, n1, n2 = lp
        h = rms_norm(x, n1, cfg.norm_eps)
        x = x + attn_lib.gqa_forward(cfg, attn_p, h, causal=False)
        h = rms_norm(x, n2, cfg.norm_eps)
        x = x + _mlp(cfg, mlp_p, h)
        return x, ()

    x, _ = jax.lax.scan(
        jax.checkpoint(layer), x,
        (enc["attn"], enc["mlp"], enc["norm1"], enc["norm2"]),
    )
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, cross_p, enc_out: jnp.ndarray):
    D, KV, hd = cfg.d_model, cfg.n_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,dhk->bshk", enc_out, cross_p["wk"].reshape(D, KV, hd))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, cross_p["wv"].reshape(D, KV, hd))
    return k, v


def _decoder(cfg: ModelConfig, params: Params, x: jnp.ndarray,
             enc_out: jnp.ndarray, *, mode: str, caches=None, pos=0):
    dec = params["dec"]

    def layer(carry, lp):
        h_in = carry
        self_p, cross_p, mlp_p, n1, n2, n3, cache = lp
        h = rms_norm(h_in, n1, cfg.norm_eps)
        if mode == "train":
            a, nc = attn_lib.gqa_forward(cfg, self_p, h), {}
        elif mode == "prefill":
            a, nc = attn_lib.gqa_prefill(cfg, self_p, h, cache)
        else:
            a, nc = attn_lib.gqa_decode(cfg, self_p, h, cache, pos)
        x = h_in + a
        h = rms_norm(x, n2, cfg.norm_eps)
        ck, cv = _cross_kv(cfg, cross_p, enc_out)
        x = x + attn_lib.gqa_forward(cfg, cross_p, h, cross_kv=(ck, cv))
        h = rms_norm(x, n3, cfg.norm_eps)
        x = x + _mlp(cfg, mlp_p, h)
        return x, nc

    if caches is not None:
        cache_xs = caches
    else:  # train: dummy per-layer placeholder (sliced but unused)
        cache_xs = {"k": jnp.zeros((cfg.n_layers, 1)), "v": jnp.zeros((cfg.n_layers, 1))}
    body = jax.checkpoint(layer) if mode == "train" else layer
    x, new_caches = jax.lax.scan(
        body, x,
        (dec["self"], dec["cross"], dec["mlp"], dec["norm1"], dec["norm2"],
         dec["norm3"], cache_xs),
    )
    return x, new_caches


def forward_hidden_encdec(cfg: ModelConfig, params: Params,
                          frames: jnp.ndarray, tokens: jnp.ndarray):
    enc_out = encode(cfg, params, frames)
    x = embed_tokens(cfg, params, tokens)
    x, _ = _decoder(cfg, params, x, enc_out, mode="train")
    return x, {}


def forward_train_encdec(cfg: ModelConfig, params: Params,
                         frames: jnp.ndarray, tokens: jnp.ndarray):
    x, _ = forward_hidden_encdec(cfg, params, frames, tokens)
    return lm_logits(cfg, params, x), {}


def init_cache_encdec(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int) -> Dict:
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "self": {
            "k": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), jnp.bfloat16),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), jnp.bfloat16),
        },
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), jnp.bfloat16),
    }


def prefill_encdec(cfg: ModelConfig, params: Params, frames: jnp.ndarray,
                   tokens: jnp.ndarray, cache: Dict):
    enc_out = encode(cfg, params, frames)
    x = embed_tokens(cfg, params, tokens)
    x, new_self = _decoder(cfg, params, x, enc_out, mode="prefill",
                           caches=cache["self"])
    logits = lm_logits(cfg, params, x[:, -1:])
    return logits, {"self": new_self, "enc_out": enc_out}


def decode_step_encdec(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                       cache: Dict, pos):
    x = embed_tokens(cfg, params, token)
    x, new_self = _decoder(cfg, params, x, cache["enc_out"], mode="decode",
                           caches=cache["self"], pos=pos)
    logits = lm_logits(cfg, params, x)
    return logits, {"self": new_self, "enc_out": cache["enc_out"]}
