"""Shared model building blocks: norms, RoPE, MLPs, attention variants.

Conventions:
* params are plain nested dicts of jnp arrays (bf16 weights);
* math that affects stability (norms, softmax, rotary, recurrences) runs
  f32 and is cast back;
* every block takes/returns (B, S, D) activations;
* attention is **chunked/online-softmax** (flash-style lax.scan over KV
  chunks) so prefill at 32k never materializes (S, S) scores;
* sliding-window attention uses the exact block-local form (block = window,
  attend to self + previous block) — O(S·W) not O(S²).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard

Params = Dict[str, Any]

# --------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def group_norm_heads(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
                     eps: float = 64e-5) -> jnp.ndarray:
    """Per-head LayerNorm over head_dim (RWKV's ln_x)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE
def rope_table(positions: jnp.ndarray, dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for positions (any shape) -> (..., dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, hd); cos/sin: (B?, S, hd/2) broadcastable."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    if cos.ndim == 2:    # (S, hd/2) -> (1, S, 1, hd/2)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:  # (B, S, hd/2) -> (B, S, 1, hd/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- MLPs
def mlp_swiglu(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def mlp_gelu(x: jnp.ndarray, p: Params) -> jnp.ndarray:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = shard(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ---------------------------------------------------- chunked flash attention
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def chunked_attention(
    q: jnp.ndarray,          # (B, Sq, H, hd)
    k: jnp.ndarray,          # (B, Sk, KV, hd)
    v: jnp.ndarray,          # (B, Sk, KV, hd)
    *,
    causal: bool,
    q_offset: jnp.ndarray | int = 0,   # global position of q[0]
    kv_len: Optional[jnp.ndarray] = None,  # #valid kv entries (decode caches)
    chunk: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks of ``chunk``
    (default 1024; override via REPRO_ATTN_CHUNK — a §Perf knob: larger
    chunks cut online-softmax carry traffic at the cost of score-buffer
    memory).

    Supports GQA (H a multiple of KV) and ragged caches via ``kv_len``.
    Returns (B, Sq, H, hd) in q.dtype.
    """
    if chunk is None:
        import os as _os
        chunk = int(_os.environ.get("REPRO_ATTN_CHUNK", "1024"))
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    hd_v = v.shape[-1]  # MLA: v head dim differs from q/k
    g = H // KV
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qf = (q.astype(jnp.float32) * scale).reshape(B, Sq, KV, g, hd)

    if Sq == 1:
        # decode: one unchunked softmax over the cache — GSPMD turns this
        # into flash-decoding when the cache's seq axis is sharded (partial
        # max/sum + small all-reduces) instead of all-gathering K/V.
        # bf16 matmul + f32 accumulation: never materialize an f32 cache.
        qh = qf.astype(q.dtype)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, k,
                       preferred_element_type=jnp.float32)
        k_pos = jnp.arange(Sk)
        limit = jnp.broadcast_to(jnp.asarray(Sk if kv_len is None else kv_len), (B,))
        mask = k_pos[None, :] < limit[:, None]
        if causal:
            q_pos = jnp.asarray(q_offset) + jnp.zeros((Sq,), jnp.int32)
            mask = mask[:, None, :] & (k_pos[None, None, :] <= q_pos[None, :, None])
        else:
            mask = mask[:, None, :]
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(q.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, H, hd_v).astype(q.dtype)

    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd_v).transpose(1, 0, 2, 3, 4)

    q_pos = (jnp.asarray(q_offset) + jnp.arange(Sq))[None, :]  # (1, Sq)
    limit = jnp.asarray(Sk if kv_len is None else kv_len)
    limit = jnp.broadcast_to(limit, (B,))

    qh = qf.astype(q.dtype)  # bf16 operand; f32 accumulation via the dot

    def step(carry, inp):
        m, l, o = carry
        ci, kb, vb = inp
        # scores: (B, Sq, KV, g, chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qh, kb,
                       preferred_element_type=jnp.float32)
        k_pos = ci * chunk + jnp.arange(chunk)  # (chunk,)
        valid = k_pos[None, :] < limit[:, None]  # (B, chunk)
        mask = valid[:, None, :]  # (B, 1, chunk)
        if causal:
            mask = mask & (k_pos[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(q.dtype), vb,
                        preferred_element_type=jnp.float32)
        o_new = o * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, Sq, KV, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, g), jnp.float32)
    o0 = jnp.zeros((B, Sq, KV, g, hd_v), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0), (jnp.arange(n_chunks), kc, vc)
    )
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, Sq, H, hd_v).astype(q.dtype)


def block_local_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, window: int,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Exact sliding-window causal attention, O(S·W).

    Queries in block i attend to keys in blocks {i-1, i} with the mask
    ``q_pos - window < k_pos <= q_pos`` — identical to a causal sliding
    window of width ``window`` when blocks are window-sized.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    W = window
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    nb = -(-S // W)
    pad = nb * W - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = (q.astype(jnp.float32) * scale).reshape(B, nb, W, KV, g, hd)
    kb = k.reshape(B, nb, W, KV, hd)
    vb = v.reshape(B, nb, W, KV, hd)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2W, KV, hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    s = jnp.einsum("bnqkgd,bnckd->bnqkgc", qb.astype(q.dtype), k2,
                   preferred_element_type=jnp.float32)
    q_pos = jnp.arange(W)[:, None]          # within-block query pos
    k_pos = jnp.arange(2 * W)[None, :] - W  # relative to block start
    block = jnp.arange(nb)
    # global positions: q = n*W + q_pos; k = n*W + k_pos
    causal = k_pos <= q_pos
    in_window = k_pos > q_pos - W
    first_block_valid = (k_pos >= 0)[None, :, :] | (block[:, None, None] > 0)
    mask = (causal & in_window)[None, :, :] & first_block_valid  # (nb, W, 2W)
    s = jnp.where(mask[None, :, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnqkgc,bnckd->bnqkgd", p.astype(q.dtype), v2,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, nb * W, H, hd)[:, :S]
    return o.astype(q.dtype)
