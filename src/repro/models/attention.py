"""Attention blocks: GQA (with qk-norm / sliding window) and MLA (DeepSeek).

Each block exposes three paths sharing parameters:
* ``forward(p, x, ...)``          — training / teacher forcing (no cache);
* ``prefill(p, x, cache, ...)``   — fills the decode cache, returns outputs;
* ``decode(p, x, cache, pos)``    — single-token step against the cache.

Cache layout (contiguous, pjit-shardable):
* GQA:   {"k": (B, S_max, KV, hd), "v": ...} (+ ring buffer for windowed);
* MLA:   full-cache baseline {"k","v"} per head, or the compressed-latent
  variant {"ckv": (B, S_max, kv_lora + rope_dim)} (mla_compressed_cache) —
  the §Perf iteration that shrinks decode-cache bytes ~10×.
"""

from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .layers import (
    Params,
    apply_rope,
    block_local_attention,
    chunked_attention,
    rms_norm,
    rope_table,
)


def _full_attention(q, k, v, *, causal: bool):
    """Dense attention: Pallas flash kernel when REPRO_FLASH_ATTN=1 and the
    shapes qualify (uniform head dims, block-divisible seqs) — the §Perf
    serving-path optimization; chunked-XLA online softmax otherwise."""
    if os.environ.get("REPRO_FLASH_ATTN") == "1":
        Sq, Sk = q.shape[1], k.shape[1]
        if (q.shape[-1] == v.shape[-1] and Sq % 128 == 0 and Sk % 128 == 0
                and Sq > 1):
            from ..kernels.flash_attention import flash_attention

            bq = 512 if Sq % 512 == 0 else 128
            bk = 512 if Sk % 512 == 0 else 128
            return flash_attention(q, k, v, causal=causal,
                                   block_q=bq, block_k=bk)
    return chunked_attention(q, k, v, causal=causal)


# ===================================================================== GQA ==
def gqa_project_qkv(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(D, H, hd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].reshape(D, KV, hd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].reshape(D, KV, hd))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_table(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def gqa_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                *, window: int = 0, positions=None, cross_kv=None,
                causal: bool = True) -> jnp.ndarray:
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)
    if cross_kv is not None:
        # cross-attention: q from x, k/v precomputed from the encoder
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(D, H, hd))
        k, v = cross_kv
        o = chunked_attention(q, k, v, causal=False)
    else:
        q, k, v = gqa_project_qkv(cfg, p, x, positions)
        if window:
            o = block_local_attention(q, k, v, window=window)
        else:
            o = _full_attention(q, k, v, causal=causal)
    o = shard(o, "batch", "seq", "heads", None)
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, hd, D))


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                   window: int = 0, dtype=jnp.bfloat16) -> Dict:
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, S, KV, hd), dtype),
        "v": jnp.zeros((batch, S, KV, hd), dtype),
    }


def gqa_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: Dict,
                *, window: int = 0) -> Tuple[jnp.ndarray, Dict]:
    B, S, D = x.shape
    positions = jnp.arange(S)
    q, k, v = gqa_project_qkv(cfg, p, x, positions)
    if window:
        o = block_local_attention(q, k, v, window=window)
        W = cache["k"].shape[1]
        # keep the last W positions (ring buffer starts full-aligned)
        kw = jax.lax.dynamic_slice_in_dim(k, max(0, S - W), min(W, S), axis=1)
        vw = jax.lax.dynamic_slice_in_dim(v, max(0, S - W), min(W, S), axis=1)
        pad = W - kw.shape[1]
        if pad:
            kw = jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vw = jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        new_cache = {"k": kw.astype(cache["k"].dtype),
                     "v": vw.astype(cache["v"].dtype)}
    else:
        o = _full_attention(q, k, v, causal=True)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
        }
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, hd, D))
    return out, new_cache


def gqa_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: Dict,
               pos: jnp.ndarray, *, window: int = 0) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, 1, D); pos: scalar current position (tokens generated so far)."""
    B, _, D = x.shape
    q, k, v = gqa_project_qkv(cfg, p, x, jnp.asarray(pos)[None])
    if window:
        W = cache["k"].shape[1]
        slot = jnp.mod(pos, W)
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        # ring buffer holds the last W tokens; rotary phases were applied at
        # absolute positions, so attention over the ring is position-correct.
        kv_len = jnp.minimum(pos + 1, W)
        o = chunked_attention(
            q, new_k, new_v, causal=False, kv_len=kv_len,
            chunk=min(1024, W),
        )
    else:
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
        o = chunked_attention(q, new_k, new_v, causal=False, kv_len=pos + 1)
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, hd, D))
    return out, {"k": new_k, "v": new_v}


# ===================================================================== MLA ==
def _mla_dims(cfg: ModelConfig):
    return (cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank,
            cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim)


def mla_project_q(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions):
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].reshape(qr, H, nd + rd))
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_table(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_project_kv(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions):
    """Returns (k (B,S,H,nd+rd), v (B,S,H,vd)) — the expanded (baseline) form."""
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])  # (B,S,kvr+rd)
    ckv, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    cos, sin = rope_table(positions, rd, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)  # shared across heads
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"].reshape(kvr, H, nd + vd))
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (rd,))], axis=-1
    )
    return k, v


def mla_forward(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                positions=None) -> jnp.ndarray:
    B, S, D = x.shape
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    if positions is None:
        positions = jnp.arange(S)
    q_nope, q_rope = mla_project_q(cfg, p, x, positions)
    k, v = mla_project_kv(cfg, p, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "heads", None)
    o = chunked_attention(q, k, v, causal=True,
                          softmax_scale=1.0 / math.sqrt(nd + rd))
    o = shard(o, "batch", "seq", "heads", None)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, vd, D))


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    if cfg.mla_compressed_cache:
        return {"ckv": jnp.zeros((batch, max_len, kvr + rd), dtype)}
    return {
        "k": jnp.zeros((batch, max_len, H, nd + rd), dtype),
        "v": jnp.zeros((batch, max_len, H, vd), dtype),
    }


def mla_prefill(cfg: ModelConfig, p: Params, x: jnp.ndarray,
                cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    B, S, D = x.shape
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    positions = jnp.arange(S)
    q_nope, q_rope = mla_project_q(cfg, p, x, positions)
    k, v = mla_project_kv(cfg, p, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = chunked_attention(q, k, v, causal=True,
                          softmax_scale=1.0 / math.sqrt(nd + rd))
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, vd, D))
    if cfg.mla_compressed_cache:
        ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
        ckv, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
        ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
        cos, sin = rope_table(positions, rd, cfg.rope_theta)
        k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
        packed = jnp.concatenate([ckv, k_rope], axis=-1)
        new_cache = {"ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], packed.astype(cache["ckv"].dtype), 0, axis=1)}
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1),
        }
    return out, new_cache


def mla_decode(cfg: ModelConfig, p: Params, x: jnp.ndarray, cache: Dict,
               pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    B, _, D = x.shape
    H, qr, kvr, rd, nd, vd = _mla_dims(cfg)
    positions = jnp.asarray(pos)[None]
    q_nope, q_rope = mla_project_q(cfg, p, x, positions)
    if cfg.mla_compressed_cache:
        # absorbed-weight decode: attend in the 512-d latent space
        ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
        ckv, k_rope = ckv_full[..., :kvr], ckv_full[..., kvr:]
        ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
        cos, sin = rope_table(positions, rd, cfg.rope_theta)
        k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
        packed = jnp.concatenate([ckv, k_rope], axis=-1)
        new_cache = {"ckv": jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], packed.astype(cache["ckv"].dtype), pos, axis=1)}
        wkv_b = p["wkv_b"].reshape(kvr, H, nd + vd)
        w_k_nope, w_v = wkv_b[..., :nd], wkv_b[..., nd:]
        # fold W^UK into q: q_lat (B,1,H,kvr)
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, w_k_nope)
        ck = new_cache["ckv"].astype(jnp.float32)  # (B, S, kvr+rd)
        scores = (
            jnp.einsum("bshr,btr->bsht", q_lat.astype(jnp.float32), ck[..., :kvr])
            + jnp.einsum("bshk,btk->bsht", q_rope.astype(jnp.float32), ck[..., kvr:])
        ) / math.sqrt(nd + rd)
        t_pos = jnp.arange(ck.shape[1])
        scores = jnp.where(t_pos[None, None, None, :] <= pos, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bsht,btr->bshr", w, ck[..., :kvr])  # (B,1,H,kvr)
        o = jnp.einsum("bshr,rhv->bshv", o_lat, w_v.astype(jnp.float32))
        o = o.astype(x.dtype)
    else:
        k, v = mla_project_kv(cfg, p, x, positions)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1),
        }
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = chunked_attention(q, new_cache["k"], new_cache["v"], causal=False,
                              kv_len=pos + 1,
                              softmax_scale=1.0 / math.sqrt(nd + rd))
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].reshape(H, vd, D)), new_cache
