"""Arch registry: one ModelBundle API over every family.

``get_model(cfg)`` returns a bundle of pure functions the launcher, trainer,
server and dry-run all share.  Batches:

* LM families:       {"tokens": (B, S) i32}  (+ labels handled by the trainer)
* enc-dec (audio):   {"frames": (B, S_enc, D) bf16, "tokens": (B, S_dec) i32}

``count_params`` derives N (total and active) analytically from the config —
the roofline's MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference) term.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec as ed
from . import transformer as tf


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[Any], Any]
    param_axes: Callable[[Any], Any]
    train_logits: Callable[..., Any]     # (params, batch) -> (logits, aux)
    train_hidden: Callable[..., Any]     # (params, batch) -> (hidden, aux)
    head: Callable[..., Any]             # (params, hidden_chunk) -> logits
    init_cache: Callable[..., Any]       # (batch, max_len, **kw) -> cache
    cache_axes: Callable[[Any], Any]
    prefill: Callable[..., Any]          # (params, batch, cache) -> (logits, cache)
    decode_step: Callable[..., Any]      # (params, token, cache, pos) -> ...


def get_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.is_encdec:
        return ModelBundle(
            cfg=cfg,
            init=lambda rng: ed.init_params_encdec(cfg, rng),
            param_axes=lambda p: ed.param_axes_encdec(cfg, p),
            train_logits=lambda p, batch: ed.forward_train_encdec(
                cfg, p, batch["frames"], batch["tokens"]
            ),
            train_hidden=lambda p, batch: ed.forward_hidden_encdec(
                cfg, p, batch["frames"], batch["tokens"]
            ),
            head=lambda p, h: tf.lm_logits(cfg, p, h),
            init_cache=lambda batch, max_len, enc_len=None: ed.init_cache_encdec(
                cfg, batch, max_len, enc_len or max_len
            ),
            cache_axes=lambda c: {
                "self": {
                    "k": ("stack", "batch", "kv_seq", "kv_heads", None),
                    "v": ("stack", "batch", "kv_seq", "kv_heads", None),
                },
                "enc_out": ("batch", "seq", "act_embed"),
            },
            prefill=lambda p, batch, cache: ed.prefill_encdec(
                cfg, p, batch["frames"], batch["tokens"], cache
            ),
            decode_step=lambda p, tok, cache, pos: ed.decode_step_encdec(
                cfg, p, tok, cache, pos
            ),
        )
    return ModelBundle(
        cfg=cfg,
        init=lambda rng: tf.init_params(cfg, rng),
        param_axes=lambda p: tf.param_axes(cfg, p),
        train_logits=lambda p, batch: tf.forward_train(
            cfg, p, batch["tokens"],
            inputs_embeds=batch.get("inputs_embeds"),
        ),
        train_hidden=lambda p, batch: tf.forward_hidden(
            cfg, p, batch["tokens"],
            inputs_embeds=batch.get("inputs_embeds"),
        ),
        head=lambda p, h: tf.lm_logits(cfg, p, h),
        init_cache=lambda batch, max_len, **kw: tf.init_cache(cfg, batch, max_len),
        cache_axes=lambda c: tf.cache_axes(cfg, c),
        prefill=lambda p, batch, cache: tf.prefill(
            cfg, p, batch["tokens"], cache,
            inputs_embeds=batch.get("inputs_embeds"),
        ),
        decode_step=lambda p, tok, cache, pos: tf.decode_step(cfg, p, tok, cache, pos),
    )


# --------------------------------------------------------------- param count
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    D, V, H, KV = cfg.d_model, cfg.vocab, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    total = V * D  # embedding
    if not cfg.tie_embeddings:
        total += D * V

    def attn_p() -> int:
        return D * H * hd + 2 * D * KV * hd + H * hd * D

    def mla_p() -> int:
        qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
        rd, nd, vd = cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
        return (D * qr + qr * H * (nd + rd) + D * (kvr + rd)
                + kvr * H * (nd + vd) + H * vd * D)

    def mlp_p() -> int:
        return (3 if cfg.mlp_kind == "swiglu" else 2) * D * cfg.d_ff

    def moe_p(active: bool) -> int:
        E, K, Fe = cfg.n_experts, cfg.top_k, cfg.expert_d_ff
        routed = (K if active else E) * 3 * D * Fe
        shared = cfg.n_shared_experts * 3 * D * Fe
        return D * E + routed + shared

    def rglru_p() -> int:
        W = cfg.lru_width
        return 3 * D * W + cfg.conv_width * W + 5 * W

    def rwkv_p() -> int:
        sl, dl = cfg.rwkv_shift_lora, cfg.rwkv_decay_lora
        time = 5 * D * D + D * 5 * sl + 5 * sl * D + D * dl + dl * D
        ffn = 2 * D * cfg.d_ff + D * D
        return time + ffn

    for i, kind in enumerate(cfg.blocks()):
        if kind == "attn":
            total += attn_p()
        elif kind == "mla":
            total += mla_p()
        elif kind == "rglru":
            total += rglru_p()
        elif kind == "rwkv":
            total += rwkv_p()
            continue  # rwkv includes its ffn
        if cfg.layer_uses_moe(i):
            total += moe_p(active_only)
        else:
            total += mlp_p()
    if cfg.is_encdec:
        total += cfg.enc_layers * (attn_p() + mlp_p())
        total += cfg.n_layers * attn_p()  # cross-attention stacks
    if cfg.mtp:
        total += 2 * D * D + attn_p() + mlp_p()
    return int(total)
