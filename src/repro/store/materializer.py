"""Materialization subsystem: planned, cached, batched checkouts.

The paper's whole premise is that recreation cost Φ is paid at *checkout*
time; this module is the layer that actually pays it well.  Every checkout in
the codebase routes through a :class:`Materializer`, which composes three
parts:

* :class:`CheckoutPlanner` — turns one or many requested version ids into an
  explicit :class:`CheckoutPlan` over the storage graph.  A batch is
  topologically ordered (bases before dependents) with shared chain prefixes
  deduplicated, so ``checkout_many([v1..vk])`` decodes each intermediate
  FlatTree exactly once while staying bit-identical to k independent
  checkouts.  The walk is bounded, so corrupted ``stored_base`` metadata
  raises instead of looping.

* :class:`MaterializationCache` — a byte-budgeted LRU of materialized
  FlatTrees keyed by vid, each entry tagged with the fingerprint it was
  decoded under.  Under the default **append-aware** discipline
  (``cache_invalidation="chain"``) the tag is the vid's own *decode-chain*
  fingerprint — a hash over just the ``(vid, stored_base, object_key)``
  triples along its storage chain — so a commit (which appends triples but
  rewrites none) leaves every warm entry valid and interleaved save+serve
  traffic stays warm; only an operation that rewrites chains (repack, which
  purges wholesale) invalidates.  ``cache_invalidation="global"`` keeps the
  legacy discipline: one whole-graph fingerprint, any commit or repack
  rotates it and the cache drops everything.  Either way a stale tree can
  never be served — an entry is only returned when its tag matches the live
  fingerprint.  Cached arrays are marked read-only — a caller mutating a
  checkout result in place would otherwise silently corrupt every future
  checkout of that version.  Cache lookups, inserts and evictions take an
  internal lock so the service tier's reader threads can share one cache.

* :class:`Materializer` — executes plans against the :class:`ObjectStore`,
  feeding every decoded tree (intermediates included — they are exactly the
  hot chain prefixes) through the cache, and exposes hit/decode statistics
  plus ``prefetch`` for repack-time cache warming.

The cache budget is the ``cache_budget_bytes`` knob on
:class:`~repro.store.version_store.VersionStore` (default 256 MiB; 0 disables
caching but keeps within-batch prefix sharing).
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import threading
from typing import (
    TYPE_CHECKING,
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Tuple,
)

from ..obs.tracer import span as _span
from .delta import (
    BlockedTree,
    FlatTree,
    apply_delta,
    apply_delta_chains,
    decode_full,
)

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (store owns us)
    from .version_store import VersionStore


def tree_nbytes(flat: FlatTree) -> int:
    """Resident bytes of a materialized tree (what the cache budget counts).

    Charges every leaf in full even though ``apply_delta`` shares unchanged
    leaves by reference between chain-adjacent trees — the budget over-, not
    under-estimates resident memory, so eviction errs toward too early.
    """
    return sum(a.nbytes for a in flat.values())


def _freeze(flat: FlatTree) -> FlatTree:
    """Mark every leaf read-only.  Materialized trees alias arrays — across
    the cache, and across batch results via ``apply_delta``'s unchanged-leaf
    passthrough — so an in-place write to one checkout result would silently
    corrupt others; numpy turns that into a ValueError instead."""
    for arr in flat.values():
        arr.flags.writeable = False
    return flat


def storage_fingerprint(versions: Dict[int, Any]) -> str:
    """Hash of the whole storage graph: every (vid, stored_base, object_key).

    Any commit, repack, or metadata edit changes at least one triple, so a
    cache keyed by this fingerprint can never serve a stale tree.
    """
    h = hashlib.sha256()
    for vid in sorted(versions):
        meta = versions[vid]
        h.update(
            f"{vid}:{meta.stored_base}:{meta.object_key};".encode()
        )
    return h.hexdigest()


# ------------------------------------------------------------------- planner
@dataclasses.dataclass(frozen=True)
class CheckoutStep:
    """One decode in a plan: full decode (base None) or delta apply."""

    vid: int
    base: Optional[int]
    object_key: str


@dataclasses.dataclass
class CheckoutPlan:
    """Topologically ordered decode schedule for a batch of checkouts.

    ``steps`` lists every decode needed, bases strictly before dependents,
    each vid at most once — shared chain prefixes across the batch appear a
    single time.  ``from_cache`` are vids (requested or chain bases) the
    planner found already materialized; they need no decoding at all.
    """

    requested: List[int]
    steps: List[CheckoutStep]
    from_cache: List[int]

    @property
    def decode_count(self) -> int:
        return len(self.steps)


class CheckoutPlanner:
    """Plans checkouts over the storage graph (a forest: ≤1 base per vid)."""

    def __init__(self, store: "VersionStore") -> None:
        self._store = store

    def plan(
        self, vids: Sequence[int], *, cached: Iterable[int] = ()
    ) -> CheckoutPlan:
        """Plan a batch checkout of ``vids``.

        ``cached`` names vids whose trees are already materialized; chains
        are walked only down to the nearest cached vid (or a full object).
        The walk is bounded by the version count, so a ``stored_base`` cycle
        in corrupted metadata raises ``RuntimeError`` instead of hanging.
        """
        versions = self._store.versions
        cached_set = set(cached)
        needed: Dict[int, CheckoutStep] = {}
        from_cache: List[int] = []
        order: List[int] = []  # needed vids, deepest-base-first per chain

        for vid in vids:
            if vid not in versions:
                raise KeyError(f"unknown version id {vid}")
            chain: List[int] = []
            v: Optional[int] = vid
            while v is not None and v not in needed and v not in cached_set:
                meta = versions[v]
                chain.append(v)
                v = meta.stored_base
                if len(chain) > len(versions):
                    raise RuntimeError("storage graph cycle")
            if v is not None and v in cached_set and v not in from_cache:
                from_cache.append(v)
            for v in reversed(chain):
                meta = versions[v]
                needed[v] = CheckoutStep(
                    vid=v, base=meta.stored_base, object_key=meta.object_key
                )
                order.append(v)

        return CheckoutPlan(
            requested=list(vids),
            steps=[needed[v] for v in order],
            from_cache=from_cache,
        )


# --------------------------------------------------------------------- cache
class MaterializationCache:
    """Byte-budgeted LRU of FlatTrees keyed by vid, fingerprint-validated.

    Every entry is tagged with the fingerprint it was decoded under; a
    lookup returns it only when the caller's fingerprint matches, so a stale
    tree can never be served.  The tag discipline belongs to the
    :class:`Materializer`:

    * *chain* (append-aware) — tags are per-vid decode-chain fingerprints;
      a mismatched entry is dropped individually (``invalidations`` counts
      them) while the rest of the cache stays warm.  Commits never rotate
      chain fingerprints of existing versions, so interleaved commit+serve
      traffic keeps its hits; ``purge`` (repack) still drops everything.
    * *global* (legacy) — tags are implicit: :meth:`ensure_fingerprint`
      adopts one whole-graph fingerprint at a time and the first operation
      under a new one drops every entry.

    Entries are evicted least-recently-used once resident bytes exceed
    ``budget_bytes``; a tree larger than the whole budget is simply not
    cached.  All access goes through one internal lock — the service tier's
    reader threads share this cache concurrently.
    """

    def __init__(self, budget_bytes: int) -> None:
        self.budget_bytes = int(budget_bytes)
        self._lock = threading.RLock()
        self._fp: Optional[str] = None
        self._entries: (
            "collections.OrderedDict[int, Tuple[FlatTree, int, Optional[str]]]"
        ) = collections.OrderedDict()
        self.current_bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.purges = 0

    # -- fingerprint handling ------------------------------------------------
    @property
    def epoch(self) -> Optional[str]:
        """The live whole-graph fingerprint (global discipline only)."""
        return self._fp

    def ensure_fingerprint(self, fp: str) -> None:
        """Adopt ``fp`` as the live storage graph, clearing stale entries."""
        with self._lock:
            if fp != self._fp:
                if self._entries:
                    self.invalidations += 1
                self._entries.clear()
                self.current_bytes = 0
                self._fp = fp

    def purge(self) -> None:
        """Drop every entry (repack rewrites chains wholesale; a full purge
        beats lazily discovering n stale tags one lookup at a time)."""
        with self._lock:
            if self._entries:
                self.purges += 1
            self._entries.clear()
            self.current_bytes = 0
            self._fp = None

    def valid_vids(self, fp_of: Callable[[int], Optional[str]]) -> List[int]:
        """Vids whose entry tag matches ``fp_of(vid)``, dropping the rest.

        An *explicit* validation sweep over every entry — the serving path
        validates lazily at ``get`` instead; this is for introspection (the
        service's warm-set accounting) and tests pinning tag semantics.
        """
        with self._lock:
            stale = [
                vid
                for vid, ent in self._entries.items()
                if ent[2] != fp_of(vid)
            ]
            for vid in stale:
                _, nbytes, _ = self._entries.pop(vid)
                self.current_bytes -= nbytes
                self.invalidations += 1
            return list(self._entries.keys())

    # -- lookup / insert -----------------------------------------------------
    def vids(self) -> List[int]:
        with self._lock:
            return list(self._entries.keys())

    def __contains__(self, vid: int) -> bool:
        with self._lock:
            return vid in self._entries

    def probe(self, vid: int, fp: Optional[str] = None) -> bool:
        """Non-counting validity check: is ``vid`` servable under ``fp``?"""
        with self._lock:
            ent = self._entries.get(vid)
            return ent is not None and ent[2] == fp

    def get(
        self, vid: int, fp: Optional[str] = None, *, count: bool = True
    ) -> Optional[FlatTree]:
        with self._lock:
            ent = self._entries.get(vid)
            if ent is not None and ent[2] != fp:
                # stale chain tag: this entry alone is dead, drop it
                self._entries.pop(vid)
                self.current_bytes -= ent[1]
                self.invalidations += 1
                ent = None
            if ent is None:
                if count:
                    self.misses += 1
                return None
            self._entries.move_to_end(vid)
            if count:
                self.hits += 1
            return ent[0]

    def put(
        self, vid: int, tree: FlatTree, fp: Optional[str] = None
    ) -> None:
        if self.budget_bytes <= 0:
            return
        nbytes = tree_nbytes(tree)
        if nbytes > self.budget_bytes:
            return
        with self._lock:
            if vid in self._entries:
                self.current_bytes -= self._entries.pop(vid)[1]
            self._entries[vid] = (tree, nbytes, fp)
            self.current_bytes += nbytes
            while self.current_bytes > self.budget_bytes:
                _, (_, old_bytes, _) = self._entries.popitem(last=False)
                self.current_bytes -= old_bytes
                self.evictions += 1

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "purges": self.purges,
                "entries": len(self._entries),
                "current_bytes": self.current_bytes,
                "budget_bytes": self.budget_bytes,
            }


# --------------------------------------------------------------- materializer
@dataclasses.dataclass(eq=False)
class _Segment:
    """A maximal run of delta steps fused into one chain application.

    ``base`` is the tree the run starts from (cached, full-decoded, or an
    earlier segment's terminal); ``steps`` are applied in chain order and
    only the terminal vid's tree is materialized host-side.
    """

    base: int
    steps: List[CheckoutStep]

    @property
    def terminal(self) -> int:
        return self.steps[-1].vid


class Materializer:
    """Executes checkout plans against the object store, through the cache.

    With ``fuse_chains`` (the default) delta chains run through the
    device-resident pipeline (:func:`repro.store.delta.apply_delta_chains`):
    intermediate trees stay in blocked device form between steps, runs of
    steps through vids nobody else needs collapse into single fused Pallas
    dispatches, and same-shaped leaves across a batch's chains share kernel
    launches.  Segment *endpoints* — vids that must exist as host trees —
    are the requested vids, vids with multiple dependents in the plan, and
    (when the cache budget is positive) every vid, so caching semantics are
    unchanged: a warm cache sees exactly the trees it would have seen
    stepwise.  ``fuse_chains=False`` keeps the legacy one-hop-at-a-time
    path; both are bit-identical.
    """

    def __init__(
        self,
        store: "VersionStore",
        *,
        budget_bytes: int,
        fuse_chains: bool = True,
        invalidation: str = "chain",
    ) -> None:
        if invalidation not in ("chain", "global"):
            raise ValueError(
                f"invalidation must be 'chain' or 'global', got {invalidation!r}"
            )
        self._store = store
        self.planner = CheckoutPlanner(store)
        self.cache = MaterializationCache(budget_bytes)
        self.fuse_chains = bool(fuse_chains)
        self.invalidation = invalidation
        # decode counters; guarded by _stats_lock — reader-pool threads
        # share one Materializer and the serving benchmark reads these
        self._stats_lock = threading.Lock()
        self.full_decodes = 0
        self.delta_applies = 0
        self.fused_segments = 0
        self.fused_stats: Dict[str, int] = {}

    # -- fingerprint discipline ----------------------------------------------
    def _entry_fp(self, vid: int) -> Optional[str]:
        """The tag a cache entry for ``vid`` must carry to be servable."""
        if self.invalidation == "chain":
            return self._store.chain_fingerprint(vid)
        return None  # global mode: validity is the epoch, entries untagged

    def _cached_vids(self) -> List[int]:
        """Vids the planner may treat as materialized (global mode rotates
        the epoch first, purging on change).  The list is *optimistic* in
        chain mode: entries are validated lazily at lookup — ``get`` drops a
        stale tag, and the execute paths rebuild anything that vanished
        between plan and execute — so a plan never pays a full-cache
        fingerprint sweep."""
        if self.invalidation == "global":
            self.cache.ensure_fingerprint(self._store.storage_fingerprint())
        return self.cache.vids()

    def probe(self, vid: int) -> bool:
        """Non-counting warm check: would ``checkout(vid)`` be a cache hit?"""
        try:
            if self.invalidation == "global":
                return (
                    self.cache.epoch == self._store.storage_fingerprint()
                    and vid in self.cache
                )
            return self.cache.probe(vid, self._store.chain_fingerprint(vid))
        except (KeyError, RuntimeError):
            return False  # unknown vid / corrupted chain: not servable

    # -- public API ----------------------------------------------------------
    def checkout(self, vid: int) -> FlatTree:
        """Materialize one version (bit-identical to the raw chain walk)."""
        return self.checkout_many([vid])[0]

    def checkout_many(self, vids: Sequence[int]) -> List[FlatTree]:
        """Materialize a batch, decoding each shared chain prefix once.

        Returns one tree per requested vid, in request order, bit-identical
        to ``[checkout(v) for v in vids]``.  Returned dicts are fresh (safe
        to add/remove keys) but the arrays are shared with the cache and
        read-only.
        """
        with _span("mat.checkout_many", vids=len(vids)) as msp:
            with _span("mat.plan") as psp:
                plan = self.planner.plan(vids, cached=self._cached_vids())
                if psp:
                    psp.set(
                        steps=plan.decode_count,
                        from_cache=len(plan.from_cache),
                    )
            trees = self._execute(plan)
            out: List[FlatTree] = []
            for vid in plan.requested:
                tree = trees.get(vid)
                if tree is None:
                    tree = self.cache.get(
                        vid, self._entry_fp(vid), count=False
                    )
                if tree is None:
                    # the planner saw this vid cached but its entry was
                    # evicted (concurrent checkout sharing the cache) or its
                    # chain tag went stale between plan and execute: rebuild
                    tree = self._materialize_chain(vid, trees)
                out.append(dict(tree))
            if msp:
                planned = {s.vid for s in plan.steps}
                msp.set(
                    decode_steps=plan.decode_count,
                    cache_hits=sum(
                        1 for v in plan.requested if v not in planned
                    ),
                )
            return out

    def prefetch(self, vids: Sequence[int]) -> int:
        """Warm the cache with ``vids`` (hottest first); returns trees cached.

        Used after ``repack(use_access_frequencies=True)``: the top-k most
        accessed versions go straight back into the cache so the first
        post-repack request for a hot version is already warm.
        """
        if self.cache.budget_bytes <= 0:
            return 0
        self._cached_vids()  # global mode: rotate the epoch before warming
        warmed = 0
        # reversed: LRU evicts oldest inserts first, so load coldest→hottest
        for vid in reversed(list(vids)):
            # validated lookup, not bare containment: a stale-tagged entry
            # (chain mode, e.g. an out-of-band metadata edit) must be
            # dropped and re-warmed, not treated as present — and dropping
            # it here also keeps the planner from calling it cached below
            if self.cache.get(vid, self._entry_fp(vid), count=False) is not None:
                continue
            plan = self.planner.plan([vid], cached=self.cache.vids())
            self._execute(plan)
            warmed += 1
        return warmed

    def stats(self) -> Dict[str, int]:
        with self._stats_lock:
            return {
                **self.cache.stats(),
                "full_decodes": self.full_decodes,
                "delta_applies": self.delta_applies,
                "fused_segments": self.fused_segments,
                "fused_launches": self.fused_stats.get("launches", 0),
            }

    # -- plan execution ------------------------------------------------------
    def _execute(self, plan: CheckoutPlan) -> Dict[int, FlatTree]:
        """Run a plan's decode steps; returns every tree it materialized.

        Within the plan, intermediate trees live in a local dict so prefix
        sharing works even with a zero cache budget; everything decoded is
        also offered to the cache (budget permitting) for future requests.
        """
        if self.fuse_chains:
            trees = self._execute_fused(plan)
        else:
            trees = self._execute_stepwise(plan)
        # hit/miss accounting per requested vid — under the cache lock, the
        # counters are shared with every reader-pool thread
        planned = {s.vid for s in plan.steps}
        n_miss = sum(1 for vid in plan.requested if vid in planned)
        with self.cache._lock:
            self.cache.misses += n_miss
            self.cache.hits += len(plan.requested) - n_miss
        return trees

    def _load_cached(self, plan: CheckoutPlan) -> Dict[int, FlatTree]:
        trees: Dict[int, FlatTree] = {}
        for vid in plan.from_cache:
            tree = self.cache.get(vid, self._entry_fp(vid), count=False)
            if tree is not None:
                trees[vid] = tree
        return trees

    def _execute_stepwise(self, plan: CheckoutPlan) -> Dict[int, FlatTree]:
        """Legacy one-hop-at-a-time execution (``fuse_chains=False``)."""
        objects = self._store.objects
        with _span("mat.execute_stepwise", steps=len(plan.steps)) as esp:
            trees = self._load_cached(plan)
            n_full = n_delta = 0
            for step in plan.steps:
                if step.base is None:
                    tree = decode_full(objects.get(step.object_key))
                    n_full += 1
                else:
                    base_tree = trees.get(step.base)
                    if base_tree is None:  # base evicted plan→execute
                        base_tree = self._materialize_chain(step.base, trees)
                    tree = apply_delta(base_tree, objects.get(step.object_key))
                    n_delta += 1
                trees[step.vid] = _freeze(tree)
                self.cache.put(step.vid, tree, self._entry_fp(step.vid))
            if esp:
                esp.set(full_decodes=n_full, delta_applies=n_delta)
        with self._stats_lock:
            self.full_decodes += n_full
            self.delta_applies += n_delta
        return trees

    def _execute_fused(self, plan: CheckoutPlan) -> Dict[int, FlatTree]:
        """Segment-fused execution through the device-resident delta pipeline.

        Delta steps are grouped into :class:`_Segment` runs ending at
        endpoints (requested vids, shared bases, every vid when caching);
        segments whose base tree is ready are batched into one
        :func:`apply_delta_chains` call per wave, so same-shaped leaves
        across independent chains share fused kernel launches.  Blocked
        device forms of segment terminals are memoized locally and fed back
        as ``base_blocked``, so a chain's intermediate trees never pay
        ``to_blocks`` twice.
        """
        objects = self._store.objects
        with _span("mat.execute_fused", steps=len(plan.steps)) as esp:
            trees = self._load_cached(plan)
            blocked: Dict[int, BlockedTree] = {}
            n_full = n_delta = n_segments = 0
            wave_stats: Dict[str, int] = {}

            requested = set(plan.requested)
            dependents = collections.Counter(
                s.base for s in plan.steps if s.base is not None
            )
            caching = self.cache.budget_bytes > 0

            def endpoint(vid: int) -> bool:
                return caching or vid in requested or dependents[vid] > 1

            segments: List[_Segment] = []
            open_at: Dict[int, _Segment] = {}
            for step in plan.steps:
                if step.base is None:
                    tree = decode_full(objects.get(step.object_key))
                    n_full += 1
                    trees[step.vid] = _freeze(tree)
                    self.cache.put(step.vid, tree, self._entry_fp(step.vid))
                    continue
                seg = open_at.pop(step.base, None)
                if seg is None:
                    seg = _Segment(base=step.base, steps=[])
                seg.steps.append(step)
                if endpoint(step.vid):
                    segments.append(seg)
                else:
                    open_at[step.vid] = seg
            # a chain tail is always requested (hence an endpoint), but close
            # any stragglers defensively so no planned step is dropped
            segments.extend(open_at.values())

            pending = segments
            while pending:
                ready = [s for s in pending if s.base in trees]
                if not ready:
                    # base evicted between plan and execute: stepwise
                    # fallback rebuilds it (and anything under it), then the
                    # wave retries
                    self._materialize_chain(pending[0].base, trees)
                    continue
                done = {id(s) for s in ready}
                pending = [s for s in pending if id(s) not in done]
                requests = [
                    (
                        trees[s.base],
                        [objects.get(st.object_key) for st in s.steps],
                        blocked.get(s.base),
                    )
                    for s in ready
                ]
                # wave_stats is plan-local: apply_delta_chains mutates the
                # dict it is given, and self.fused_stats is shared across
                # threads
                results = apply_delta_chains(requests, stats=wave_stats)
                for s, (tree, blk) in zip(ready, results):
                    trees[s.terminal] = _freeze(tree)
                    blocked[s.terminal] = blk
                    self.cache.put(
                        s.terminal, tree, self._entry_fp(s.terminal)
                    )
                    n_delta += len(s.steps)
                    n_segments += 1
            if esp:
                esp.set(
                    full_decodes=n_full,
                    delta_applies=n_delta,
                    fused_segments=n_segments,
                    fused_launches=wave_stats.get("launches", 0),
                )
        with self._stats_lock:
            self.full_decodes += n_full
            self.delta_applies += n_delta
            self.fused_segments += n_segments
            for k, v in wave_stats.items():
                self.fused_stats[k] = self.fused_stats.get(k, 0) + v
        return trees

    def _materialize_chain(
        self, vid: int, trees: Dict[int, FlatTree]
    ) -> FlatTree:
        """Fallback chain walk for a base missing from cache and plan."""
        plan = self.planner.plan([vid], cached=trees.keys())
        objects = self._store.objects
        n_full = n_delta = 0
        for step in plan.steps:
            if step.base is None:
                tree = decode_full(objects.get(step.object_key))
                n_full += 1
            else:
                tree = apply_delta(
                    trees[step.base], objects.get(step.object_key)
                )
                n_delta += 1
            trees[step.vid] = _freeze(tree)
            self.cache.put(step.vid, tree, self._entry_fp(step.vid))
        with self._stats_lock:
            self.full_decodes += n_full
            self.delta_applies += n_delta
        return trees[vid]
