"""Content-addressed object store (the physical layer under the version store).

Blobs are zstd-compressed and stored under their sha256; writes are atomic
(tmp + rename) so a preempted checkpoint save never corrupts the store —
the object either exists fully or not at all.  Dedup falls out of content
addressing: committing an identical shard twice stores one blob.
"""

from __future__ import annotations

import hashlib
import os
import tempfile
from pathlib import Path
from typing import Dict, Optional

import zstandard


class ObjectStore:
    def __init__(self, root: str | Path, *, zstd_level: int = 3) -> None:
        self.root = Path(root)
        (self.root / "objects").mkdir(parents=True, exist_ok=True)
        self._c = zstandard.ZstdCompressor(level=zstd_level)
        self._d = zstandard.ZstdDecompressor()

    def _path(self, key: str) -> Path:
        return self.root / "objects" / f"{key[:2]}" / f"{key[2:]}.zst"

    def put(self, payload: bytes) -> tuple[str, int]:
        """Store a blob; returns (key, stored_bytes)."""
        key = hashlib.sha256(payload).hexdigest()
        path = self._path(key)
        if path.exists():
            return key, path.stat().st_size
        path.parent.mkdir(parents=True, exist_ok=True)
        compressed = self._c.compress(payload)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(compressed)
            os.replace(tmp, path)  # atomic on POSIX
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return key, len(compressed)

    def get(self, key: str) -> bytes:
        return self._d.decompress(self._path(key).read_bytes())

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def stored_size(self, key: str) -> int:
        return self._path(key).stat().st_size

    def delete(self, key: str) -> None:
        p = self._path(key)
        if p.exists():
            p.unlink()

    def keys(self):
        for sub in (self.root / "objects").iterdir():
            if sub.is_dir():
                for f in sub.iterdir():
                    if f.suffix == ".zst":
                        yield sub.name + f.stem

    def total_bytes(self) -> int:
        return sum(self._path(k).stat().st_size for k in self.keys())
