"""Content-addressed object store (the physical layer under the version store).

Blobs are compressed through one :class:`Codec` and stored under their
sha256; writes are atomic (tmp + rename) so a preempted checkpoint save never
corrupts the store — the object either exists fully or not at all.  Dedup
falls out of content addressing: committing an identical shard twice stores
one blob.

``zstandard`` is an *optional* dependency: when it is absent the codec falls
back to stdlib ``zlib`` transparently.  Decompression dispatches on the frame
magic, so a store written with zstd stays readable as long as ``zstandard``
is installed, and zlib-written stores are readable everywhere.  (The on-disk
``.zst`` suffix is kept for layout stability regardless of backend — blob
contents are self-describing.)
"""

from __future__ import annotations

import hashlib
import os
import tempfile
import zlib
from pathlib import Path
from typing import Dict, Optional

try:  # optional dependency — stdlib zlib fallback below
    import zstandard
except ImportError:  # pragma: no cover - exercised via Codec(backend="zlib")
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


class Codec:
    """The one compression codec every store byte goes through.

    All compression in the store layer — object payloads *and* the Δ
    measurements of ``VersionStore.build_cost_graph`` — routes through this
    class, so measured storage costs always equal bytes actually at rest.
    """

    def __init__(self, level: int = 3, *, backend: Optional[str] = None) -> None:
        if backend is None:
            backend = "zstd" if zstandard is not None else "zlib"
        if backend == "zstd" and zstandard is None:
            raise RuntimeError("zstandard requested but not installed")
        if backend not in ("zstd", "zlib"):
            raise ValueError(f"unknown codec backend {backend!r}")
        self.backend = backend
        self.level = level
        if backend == "zstd":
            self._c = zstandard.ZstdCompressor(level=level)
            self._d = zstandard.ZstdDecompressor()

    def compress(self, payload: bytes) -> bytes:
        if self.backend == "zstd":
            return self._c.compress(payload)
        # zstd levels reach 22; zlib tops out at 9
        return zlib.compress(payload, min(self.level, 9))

    def decompress(self, blob: bytes) -> bytes:
        # dispatch on frame magic so mixed-backend stores keep working
        if blob[:4] == _ZSTD_MAGIC:
            if zstandard is None:
                raise RuntimeError(
                    "blob was written with zstd but zstandard is not installed"
                )
            if self.backend == "zstd":
                return self._d.decompress(blob)
            return zstandard.ZstdDecompressor().decompress(blob)
        return zlib.decompress(blob)

    def compressed_size(self, payload: bytes) -> int:
        """Bytes this payload would occupy at rest (the measured Δ)."""
        return len(self.compress(payload))


class ObjectStore:
    def __init__(
        self,
        root: str | Path,
        *,
        zstd_level: int = 3,
        codec: Optional[Codec] = None,
    ) -> None:
        self.root = Path(root)
        (self.root / "objects").mkdir(parents=True, exist_ok=True)
        self.codec = codec or Codec(level=zstd_level)

    def _path(self, key: str) -> Path:
        return self.root / "objects" / f"{key[:2]}" / f"{key[2:]}.zst"

    def put(self, payload: bytes) -> tuple[str, int]:
        """Store a blob; returns (key, stored_bytes)."""
        key = hashlib.sha256(payload).hexdigest()
        path = self._path(key)
        if path.exists():
            return key, path.stat().st_size
        path.parent.mkdir(parents=True, exist_ok=True)
        compressed = self.codec.compress(payload)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(compressed)
            os.replace(tmp, path)  # atomic on POSIX
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return key, len(compressed)

    def get(self, key: str) -> bytes:
        return self.codec.decompress(self._path(key).read_bytes())

    def exists(self, key: str) -> bool:
        return self._path(key).exists()

    def stored_size(self, key: str) -> int:
        return self._path(key).stat().st_size

    def delete(self, key: str) -> None:
        p = self._path(key)
        if p.exists():
            p.unlink()

    def keys(self):
        for sub in (self.root / "objects").iterdir():
            if sub.is_dir():
                for f in sub.iterdir():
                    if f.suffix == ".zst":
                        yield sub.name + f.stem

    def total_bytes(self) -> int:
        return sum(self._path(k).stat().st_size for k in self.keys())
