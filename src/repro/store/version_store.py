"""VersionStore — the paper's system, end to end.

Tracks a *version graph* (derivation DAG from commits/branches/merges) and a
*storage graph* (what is physically stored: full objects and deltas), keeps
the measured Δ/Φ matrices, and re-optimizes the storage graph on demand with
any of the paper's solvers (``repack``).

Commit path (online): a new version is stored as a delta against its first
parent's payload when that is smaller than storing it whole — a cheap local
rule; the *global* storage graph is what ``repack`` optimizes offline,
exactly mirroring Git's commit-then-`git repack` split that the paper
analyzes (§4.4, Appendix A).

Incremental Δ/Φ measurement: every measured matrix entry is persisted in the
msgpack metadata keyed by ``(src, dst)`` together with the content
fingerprints of both endpoint payloads.  ``build_cost_graph`` only re-measures
entries whose endpoints changed (version contents are immutable, so in
practice only pairs touching versions committed since the last measurement) —
``repack`` no longer re-checkouts and re-compresses every version on every
call.  All compression routes through the ObjectStore's :class:`Codec`, so
measured Δ equals bytes actually stored.

All metadata lives in one msgpack file (atomic rewrite); payloads live in the
content-addressed :class:`ObjectStore`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import msgpack

from ..core import (
    SOLVERS,
    StorageSolution,
    VersionGraph,
)
from .delta import (
    FlatTree,
    RecreationCostModel,
    apply_delta,
    decode_full,
    encode_delta,
    encode_full,
    flatten_payload,
)
from .objectstore import ObjectStore


@dataclasses.dataclass
class VersionMeta:
    vid: int
    parents: List[int]                  # derivation parents (version graph)
    message: str
    created_at: float
    raw_bytes: int                      # uncompressed payload size
    # physical storage: either a full object or a delta from `stored_base`
    stored_base: Optional[int] = None   # None => materialized
    object_key: str = ""
    stored_bytes: int = 0
    phi: float = 0.0                    # recreation cost of this edge
    access_count: int = 0
    content_fp: str = ""                # sha256 of the full (uncompressed) payload


class _PayloadProvider:
    """Lazy edge-payload encoder backing ``repack``.

    Maps ``(src, dst)`` to ``(encoded_payload, stats)`` — a full encoding for
    ``src == 0``, a delta otherwise — materializing checkouts and encodings
    on first use only.  ``repack`` therefore encodes just the n−1 edges the
    solver actually chose, not every measured candidate pair.
    """

    def __init__(self, store: "VersionStore") -> None:
        self._store = store
        self._flats: Dict[int, FlatTree] = {}
        self._fulls: Dict[int, bytes] = {}
        self._memo: Dict[Tuple[int, int], Tuple[bytes, Dict]] = {}

    def flat(self, vid: int) -> FlatTree:
        if vid not in self._flats:
            self._flats[vid] = self._store._checkout_flat(vid)
        return self._flats[vid]

    def full_payload(self, vid: int) -> bytes:
        if vid not in self._fulls:
            self._fulls[vid] = encode_full(self.flat(vid))
        return self._fulls[vid]

    def __getitem__(self, key: Tuple[int, int]) -> Tuple[bytes, Dict]:
        if key not in self._memo:
            src, dst = key
            if src == 0:
                self._memo[key] = (self.full_payload(dst), {})
            else:
                self._memo[key] = encode_delta(self.flat(src), self.flat(dst))
        return self._memo[key]


class VersionStore:
    def __init__(
        self,
        root: str | Path,
        *,
        cost_model: Optional[RecreationCostModel] = None,
        delta_hops: int = 3,
    ) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.objects = ObjectStore(self.root)
        self.cost_model = cost_model or RecreationCostModel()
        self.delta_hops = delta_hops
        self.versions: Dict[int, VersionMeta] = {}
        self._next_vid = 1
        # measured Δ entries: (src, dst) -> {sfp, dfp, delta, payload_len,
        # changed_blocks}; persisted in the msgpack metadata so repack only
        # re-measures pairs whose endpoints changed
        self._edge_cache: Dict[Tuple[int, int], Dict[str, Any]] = {}
        self.last_measured_edges = 0
        self._meta_path = self.root / "meta.msgpack"
        if self._meta_path.exists():
            self._load_meta()

    # ------------------------------------------------------------- commits
    def commit(
        self,
        payload: Any,
        *,
        parents: Sequence[int] = (),
        message: str = "",
    ) -> int:
        """Add a version; returns its id.  ``payload`` is any pytree."""
        flat = flatten_payload(payload)
        raw = sum(a.nbytes for a in flat.values())
        vid = self._next_vid
        self._next_vid += 1

        full_payload = encode_full(flat)
        stored_base = None
        best_obj = full_payload
        best_stats = None
        if parents:
            base_flat = self._checkout_flat(parents[0])
            delta_payload, stats = encode_delta(base_flat, flat)
            if len(delta_payload) < len(full_payload):
                stored_base = parents[0]
                best_obj = delta_payload
                best_stats = stats
        key, stored = self.objects.put(best_obj)
        if stored_base is None:
            phi = self.cost_model.phi_full(stored, raw)
        else:
            phi = self.cost_model.phi_delta(
                stored, len(best_obj), best_stats["changed_blocks"]
            )
        self.versions[vid] = VersionMeta(
            vid=vid,
            parents=list(parents),
            message=message,
            created_at=time.time(),
            raw_bytes=raw,
            stored_base=stored_base,
            object_key=key,
            stored_bytes=stored,
            phi=phi,
            content_fp=hashlib.sha256(full_payload).hexdigest(),
        )
        self._save_meta()
        return vid

    # ------------------------------------------------------------ checkout
    def checkout(self, vid: int) -> FlatTree:
        """Recreate a version by walking its storage chain."""
        self.versions[vid].access_count += 1
        return self._checkout_flat(vid)

    def _checkout_flat(self, vid: int) -> FlatTree:
        chain: List[VersionMeta] = []
        v: Optional[int] = vid
        while v is not None:
            meta = self.versions[v]
            chain.append(meta)
            v = meta.stored_base
            if len(chain) > len(self.versions) + 1:
                raise RuntimeError("storage graph cycle")
        chain.reverse()
        flat = decode_full(self.objects.get(chain[0].object_key))
        for meta in chain[1:]:
            flat = apply_delta(flat, self.objects.get(meta.object_key))
        return flat

    def recreation_cost(self, vid: int) -> float:
        """Modelled Φ along the current storage chain."""
        total = 0.0
        v: Optional[int] = vid
        while v is not None:
            meta = self.versions[v]
            total += meta.phi
            v = meta.stored_base
        return total

    def storage_bytes(self) -> int:
        return sum(m.stored_bytes for m in self.versions.values())

    # -------------------------------------------------------------- repack
    def build_cost_graph(
        self, *, extra_edges: bool = True
    ) -> Tuple[VersionGraph, _PayloadProvider]:
        """Measure the Δ/Φ matrices over version-graph-adjacent pairs (plus
        pairs within ``delta_hops``) and return (graph, payload provider).

        This is the paper's "revealing entries in the matrix" step: all-pairs
        is infeasible, so we measure around the derivation structure.
        Measured entries are cached in the metadata keyed by the endpoints'
        content fingerprints; unchanged entries are served from the cache
        without touching payloads.  ``last_measured_edges`` records how many
        entries were (re)measured by the call.
        """
        n = len(self.versions)
        g = VersionGraph(n, directed=True)
        provider = _PayloadProvider(self)
        codec = self.objects.codec
        measured = 0

        # legacy metas (pre-fingerprint) get their fingerprint backfilled once
        for vid, meta in self.versions.items():
            if not meta.content_fp:
                meta.content_fp = hashlib.sha256(
                    provider.full_payload(vid)
                ).hexdigest()

        # adjacency of the derivation DAG (undirected, for the hop ball)
        adj: Dict[int, set] = {v: set() for v in self.versions}
        for v, meta in self.versions.items():
            for p in meta.parents:
                adj[v].add(p)
                adj[p].add(v)

        done: set = set()
        for vid, meta in self.versions.items():
            fp = meta.content_fp
            ent = self._edge_cache.get((0, vid))
            if ent is None or ent["dfp"] != fp:
                full_payload = provider.full_payload(vid)
                ent = {
                    "sfp": "",
                    "dfp": fp,
                    "delta": codec.compressed_size(full_payload),
                    "payload_len": len(full_payload),
                    "changed_blocks": 0,
                }
                self._edge_cache[(0, vid)] = ent
                measured += 1
            g.set_materialization(
                vid, ent["delta"],
                self.cost_model.phi_full(ent["delta"], meta.raw_bytes),
            )
            # hop ball
            ball = {vid}
            frontier = {vid}
            hops = self.delta_hops if extra_edges else 1
            for _ in range(hops):
                frontier = {y for x in frontier for y in adj[x]} - ball
                ball |= frontier
            for other in sorted(ball - {vid}):
                if (other, vid) in done:
                    continue
                done.add((other, vid))
                sfp = self.versions[other].content_fp
                ent = self._edge_cache.get((other, vid))
                if ent is None or ent["sfp"] != sfp or ent["dfp"] != fp:
                    payload, stats = provider[(other, vid)]
                    ent = {
                        "sfp": sfp,
                        "dfp": fp,
                        "delta": codec.compressed_size(payload),
                        "payload_len": len(payload),
                        "changed_blocks": stats["changed_blocks"],
                    }
                    self._edge_cache[(other, vid)] = ent
                    measured += 1
                g.set_delta(
                    other, vid, ent["delta"],
                    self.cost_model.phi_delta(
                        ent["delta"], ent["payload_len"], ent["changed_blocks"]
                    ),
                )
        self.last_measured_edges = measured
        if measured:
            self._save_meta()  # persist new measurements for the next call
        return g, provider

    def repack(
        self,
        solver: str = "lmg",
        *,
        use_access_frequencies: bool = False,
        **solver_kwargs,
    ) -> Dict[str, float]:
        """Re-optimize the storage graph with one of the paper's solvers and
        rewrite physical storage to match.  Returns before/after stats."""
        if not self.versions:
            # nothing to repack: solvers need ≥1 version and the stats below
            # take max() over the version set
            zero = {"storage_bytes": 0, "sum_recreation_s": 0.0,
                    "max_recreation_s": 0.0}
            return {"before": dict(zero), "after": dict(zero)}
        before = {
            "storage_bytes": self.storage_bytes(),
            "sum_recreation_s": sum(self.recreation_cost(v) for v in self.versions),
            "max_recreation_s": max(self.recreation_cost(v) for v in self.versions),
        }
        g, cache = self.build_cost_graph()
        if use_access_frequencies and solver == "lmg":
            total = sum(m.access_count + 1 for m in self.versions.values())
            solver_kwargs["weights"] = {
                v: (m.access_count + 1) / total for v, m in self.versions.items()
            }
        sol: StorageSolution = SOLVERS[solver](g, **solver_kwargs)
        sol.validate()
        self._apply_solution(sol, cache)
        after = {
            "storage_bytes": self.storage_bytes(),
            "sum_recreation_s": sum(self.recreation_cost(v) for v in self.versions),
            "max_recreation_s": max(self.recreation_cost(v) for v in self.versions),
        }
        self.gc()
        self._save_meta()
        return {"before": before, "after": after}

    def _apply_solution(self, sol: StorageSolution, cache: _PayloadProvider) -> None:
        for vid, parent in sol.parent.items():
            meta = self.versions[vid]
            if parent == 0:
                payload, _ = cache[(0, vid)]
                key, stored = self.objects.put(payload)
                meta.stored_base = None
                meta.phi = self.cost_model.phi_full(stored, meta.raw_bytes)
            else:
                payload, stats = cache[(parent, vid)]
                key, stored = self.objects.put(payload)
                meta.stored_base = parent
                meta.phi = self.cost_model.phi_delta(
                    stored, len(payload), stats["changed_blocks"]
                )
            meta.object_key = key
            meta.stored_bytes = stored

    def gc(self) -> int:
        """Drop objects not referenced by any version; returns bytes freed."""
        live = {m.object_key for m in self.versions.values()}
        freed = 0
        for key in list(self.objects.keys()):
            if key not in live:
                freed += self.objects.stored_size(key)
                self.objects.delete(key)
        return freed

    # ------------------------------------------------------------ metadata
    def _save_meta(self) -> None:
        blob = msgpack.packb(
            {
                "next_vid": self._next_vid,
                "versions": {
                    str(v): dataclasses.asdict(m) for v, m in self.versions.items()
                },
                "edge_cache": {
                    f"{a},{b}": ent for (a, b), ent in self._edge_cache.items()
                },
            },
            use_bin_type=True,
        )
        fd, tmp = tempfile.mkstemp(dir=str(self.root))
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._meta_path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def _load_meta(self) -> None:
        obj = msgpack.unpackb(self._meta_path.read_bytes(), raw=False)
        self._next_vid = obj["next_vid"]
        self.versions = {
            int(v): VersionMeta(**m) for v, m in obj["versions"].items()
        }
        self._edge_cache = {}
        for key, ent in obj.get("edge_cache", {}).items():
            a, b = key.split(",")
            self._edge_cache[(int(a), int(b))] = ent

    # -------------------------------------------------------------- limits
    def log(self) -> List[VersionMeta]:
        return [self.versions[v] for v in sorted(self.versions)]
