"""VersionStore — the paper's system, end to end.

Tracks a *version graph* (derivation DAG from commits/branches/merges) and a
*storage graph* (what is physically stored: full objects and deltas), keeps
the measured Δ/Φ matrices, and re-optimizes the storage graph on demand
against a declarative :class:`~repro.core.spec.OptimizeSpec` (``repack``;
string solver names remain as a deprecated shim).  Named branches/tags and
the git-shaped verb set live in the
:class:`~repro.store.repository.Repository` facade; the ref table is
persisted here, in the same atomic metadata file as the version metas.

Commit path (online): a new version is stored as a delta against its first
parent's payload when that is smaller than storing it whole — a cheap local
rule; the *global* storage graph is what ``repack`` optimizes offline,
exactly mirroring Git's commit-then-`git repack` split that the paper
analyzes (§4.4, Appendix A).

Checkout path (the recreation layer): every checkout routes through the
:class:`~repro.store.materializer.Materializer` — a ``CheckoutPlanner`` that
compiles one or many requested vids into a topologically ordered decode plan
(shared storage-chain prefixes decoded exactly once), executed through a
byte-budgeted LRU ``MaterializationCache`` of FlatTrees validated per entry
against a fingerprint.  Under the default append-aware discipline
(``cache_invalidation="chain"``) each entry is tagged with its vid's
*decode-chain* fingerprint — a hash over just the ``(vid, stored_base,
object_key)`` triples along that vid's storage chain — so a commit (which
appends triples but rewrites none) keeps every warm entry alive and
interleaved save+serve traffic never goes cold; ``repack`` rewrites chains
and purges the cache wholesale.  ``cache_invalidation="global"`` keeps the
legacy whole-graph fingerprint that any commit rotates (purging everything);
either way a stale tree can never be served.  ``checkout`` serves hot
versions from memory; ``checkout_many`` batches k checkouts into one plan,
bit-identical to k sequential calls but strictly cheaper on chain-sharing
batches.  The cache budget is the ``cache_budget_bytes`` constructor knob
(default 256 MiB; 0 disables caching while keeping within-batch prefix
sharing), and ``repack(use_access_frequencies=True)`` prefetches the hottest
versions back into the cache after rewriting storage.

Concurrency: the store is single-writer / multi-reader.  Checkouts may run
from several threads at once — the materialization cache takes its own lock,
and access-count bumps, vid allocation and metadata writes are guarded by
the store lock.  Mutating operations (``commit``, ``repack``,
``gc``, ref writes) must stay confined to one writer at a time; ``commit``
may run concurrently with readers (it only appends), while ``repack``/``gc``
require exclusive access (the service tier enforces both with a
reader-writer lock).

Access counts are the workload signal for frequency-aware repacking; they
are flushed to the metadata file every ``access_flush_every`` checkouts and
on ``repack``/``close``, so counts survive a reload.

Incremental Δ/Φ measurement: every measured matrix entry is persisted in the
msgpack metadata keyed by ``(src, dst)`` together with the content
fingerprints of both endpoint payloads.  ``build_cost_graph`` only re-measures
entries whose endpoints changed (version contents are immutable, so in
practice only pairs touching versions committed since the last measurement) —
``repack`` no longer re-checkouts and re-compresses every version on every
call.  All compression routes through the ObjectStore's :class:`Codec`, so
measured Δ equals bytes actually stored.

All metadata lives in one msgpack file (atomic rewrite); payloads live in the
content-addressed :class:`ObjectStore`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import msgpack

from ..core import (
    OptimizeSpec,
    StorageSolution,
    VersionGraph,
    optimize,
    spec_from_solver,
)
from .delta import (
    FlatTree,
    RecreationCostModel,
    encode_delta,
    encode_full,
    flatten_payload,
)
from ..obs.tracer import span as _span
from .materializer import Materializer
from .materializer import storage_fingerprint as _storage_graph_fp
from .objectstore import ObjectStore


@dataclasses.dataclass
class VersionMeta:
    vid: int
    parents: List[int]                  # derivation parents (version graph)
    message: str
    created_at: float
    raw_bytes: int                      # uncompressed payload size
    # physical storage: either a full object or a delta from `stored_base`
    stored_base: Optional[int] = None   # None => materialized
    object_key: str = ""
    stored_bytes: int = 0
    phi: float = 0.0                    # recreation cost of this edge
    access_count: int = 0
    content_fp: str = ""                # sha256 of the full (uncompressed) payload


class _PayloadProvider:
    """Lazy edge-payload encoder backing ``repack``.

    Maps ``(src, dst)`` to ``(encoded_payload, stats)`` — a full encoding for
    ``src == 0``, a delta otherwise — materializing checkouts and encodings
    on first use only.  ``repack`` therefore encodes just the n−1 edges the
    solver actually chose, not every measured candidate pair.

    FlatTree materialization goes through the store's shared
    :class:`~repro.store.materializer.MaterializationCache` (no private
    per-provider tree dicts), so a repack's checkouts and the serving path
    reuse the same byte-budgeted cache.
    """

    def __init__(self, store: "VersionStore") -> None:
        self._store = store
        self._memo: Dict[Tuple[int, int], Tuple[bytes, Dict]] = {}

    def flat(self, vid: int) -> FlatTree:
        return self._store._checkout_flat(vid)

    def full_payload(self, vid: int) -> bytes:
        return self[(0, vid)][0]

    def __getitem__(self, key: Tuple[int, int]) -> Tuple[bytes, Dict]:
        if key not in self._memo:
            src, dst = key
            if src == 0:
                self._memo[key] = (encode_full(self.flat(dst)), {})
            else:
                # one batched plan: the pair's shared chain prefix is decoded
                # once even when the cache can't hold it (tiny/zero budgets)
                src_t, dst_t = self._store.materializer.checkout_many(
                    [src, dst]
                )
                self._memo[key] = encode_delta(src_t, dst_t)
        return self._memo[key]


class VersionStore:
    def __init__(
        self,
        root: str | Path,
        *,
        cost_model: Optional[RecreationCostModel] = None,
        delta_hops: int = 3,
        cache_budget_bytes: int = 256 << 20,
        access_flush_every: int = 64,
        prefetch_hot_k: int = 8,
        fuse_chains: bool = True,
        cache_invalidation: str = "chain",
    ) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.objects = ObjectStore(self.root)
        self.cost_model = cost_model or RecreationCostModel()
        self.delta_hops = delta_hops
        self.versions: Dict[int, VersionMeta] = {}
        self._next_vid = 1
        # guards hot state shared with service-tier reader threads: access
        # counts, vid allocation, and metadata writes
        self._lock = threading.RLock()
        # measured Δ entries: (src, dst) -> {sfp, dfp, delta, payload_len,
        # changed_blocks}; persisted in the msgpack metadata so repack only
        # re-measures pairs whose endpoints changed
        self._edge_cache: Dict[Tuple[int, int], Dict[str, Any]] = {}
        self.last_measured_edges = 0
        # named refs (branches are mutable pointers, tags immutable) plus the
        # current branch; owned by the Repository facade, persisted in the
        # msgpack metadata so they survive a close/reopen like version metas
        self.refs: Dict[str, Any] = {"branches": {}, "tags": {}, "head": "main"}
        # recreation layer: planner + byte-budgeted FlatTree LRU; fuse_chains
        # routes delta chains through the fused device-resident pipeline,
        # cache_invalidation picks the append-aware ("chain") or legacy
        # whole-graph ("global") fingerprint discipline
        self.materializer = Materializer(
            self,
            budget_bytes=cache_budget_bytes,
            fuse_chains=fuse_chains,
            invalidation=cache_invalidation,
        )
        self.access_flush_every = access_flush_every
        self.prefetch_hot_k = prefetch_hot_k
        self._unflushed_accesses = 0
        self._storage_fp: Optional[str] = None
        # record of the last repack's spec + outcome, persisted with the
        # metadata: fsck re-validates the recorded constraints against the
        # *current* storage graph (a post-repack mutation that silently
        # violates an agreed bound is a finding, not a crash)
        self.last_repack: Optional[Dict[str, Any]] = None
        # optional live (C, R) sampler (repro.obs.tradeoff.TradeoffMonitor):
        # when attached, commit/repack notify it.  Sampling is O(n) per
        # event, so the service tier attaches it — not store-building loops.
        self.tradeoff_monitor: Optional[Any] = None
        self._meta_path = self.root / "meta.msgpack"
        if self._meta_path.exists():
            self._load_meta()

    # ------------------------------------------------------------- commits
    def commit(
        self,
        payload: Any,
        *,
        parents: Sequence[int] = (),
        message: str = "",
        update_branch: Optional[str] = None,
    ) -> int:
        """Add a version; returns its id.  ``payload`` is any pytree.

        ``update_branch`` points the named branch ref at the new version in
        the same atomic metadata write as the commit itself (used by the
        Repository facade — one rewrite per commit, never two)."""
        with _span("store.commit") as csp:
            flat = flatten_payload(payload)
            raw = sum(a.nbytes for a in flat.values())
            with self._lock:
                vid = self._next_vid
                self._next_vid += 1

            full_payload = encode_full(flat)
            stored_base = None
            best_obj = full_payload
            best_stats = None
            if parents:
                base_flat = self._checkout_flat(parents[0])
                delta_payload, stats = encode_delta(base_flat, flat)
                if len(delta_payload) < len(full_payload):
                    stored_base = parents[0]
                    best_obj = delta_payload
                    best_stats = stats
            key, stored = self.objects.put(best_obj)
            if stored_base is None:
                phi = self.cost_model.phi_full(stored, raw)
            else:
                phi = self.cost_model.phi_delta(
                    stored, len(best_obj), best_stats["changed_blocks"]
                )
            with self._lock:
                self.versions[vid] = VersionMeta(
                    vid=vid,
                    parents=list(parents),
                    message=message,
                    created_at=time.time(),
                    raw_bytes=raw,
                    stored_base=stored_base,
                    object_key=key,
                    stored_bytes=stored,
                    phi=phi,
                    content_fp=hashlib.sha256(full_payload).hexdigest(),
                )
                # a commit only *appends* a (vid, stored_base, object_key)
                # triple: the whole-graph fingerprint rotates (global-mode
                # caches purge) but every existing decode chain is untouched,
                # so append-aware caches stay warm
                self._storage_fp = None
                if update_branch is not None:
                    self.refs["branches"][update_branch] = vid
                self._save_meta()
            if csp:
                csp.set(
                    vid=vid,
                    raw_bytes=raw,
                    stored_bytes=stored,
                    encoding="full" if stored_base is None else "delta",
                )
        mon = self.tradeoff_monitor
        if mon is not None:
            mon.on_commit(vid)
        return vid

    # ------------------------------------------------------------ checkout
    def storage_fingerprint(self) -> str:
        """Hash of every (vid, stored_base, object_key) triple — the
        whole-graph cache epoch used by ``cache_invalidation="global"`` and
        fsck.  Changes on commit and repack, never within a read-only
        workload.  (The default ``"chain"`` discipline uses
        :meth:`chain_fingerprint` instead, which commits do *not* rotate.)"""
        with self._lock:
            if self._storage_fp is None:
                self._storage_fp = _storage_graph_fp(self.versions)
            return self._storage_fp

    def chain_fingerprint(self, vid: int) -> str:
        """Fingerprint of ``vid``'s decode chain: a rolling hash over the
        ``(vid, stored_base, object_key)`` triples from ``vid`` down to its
        full object.  This is the append-aware cache tag — a commit adds new
        triples but never rewrites existing ones, so every existing chain
        fingerprint survives commits; a chain rewrite (repack, or a direct
        metadata edit) changes it and the tagged cache entry dies on its
        next lookup.  Recomputed per call — deliberately not memoized, so
        even out-of-band ``stored_base``/``object_key`` edits are caught —
        and the walk is bounded, so a corrupted cycle raises instead of
        looping.  Holds the store lock: reader-pool threads fingerprint
        chains while the writer thread mutates ``versions``, and the walk
        must observe either the pre- or post-mutation graph, never a mix."""
        with self._lock:
            h = hashlib.sha256()
            v: Optional[int] = vid
            hops = 0
            while v is not None:
                meta = self.versions[v]
                h.update(
                    f"{v}:{meta.stored_base}:{meta.object_key};".encode()
                )
                v = meta.stored_base
                hops += 1
                if hops > len(self.versions):
                    raise RuntimeError("storage graph cycle")
            return h.hexdigest()

    def checkout(self, vid: int) -> FlatTree:
        """Recreate a version through the materialization layer."""
        return self.checkout_many([vid])[0]

    def checkout_many(self, vids: Sequence[int]) -> List[FlatTree]:
        """Batch checkout: one plan, shared chain prefixes decoded once.

        Bit-identical to ``[checkout(v) for v in vids]``.  Returned arrays
        are shared with the cache and read-only; copy before mutating.
        """
        out = self.materializer.checkout_many(vids)
        # bump only after success: a KeyError/cycle abort must not inflate
        # the workload signal feeding frequency-aware repack
        with self._lock:
            for vid in vids:
                self.versions[vid].access_count += 1
            self._unflushed_accesses += len(vids)
            if self._unflushed_accesses >= self.access_flush_every:
                self.flush_access_counts()
        return out

    def _checkout_flat(self, vid: int) -> FlatTree:
        """Internal checkout: no access-count bump, same cache/planner path."""
        return self.materializer.checkout(vid)

    def flush_access_counts(self) -> None:
        """Persist access counts accumulated by checkouts since the last
        metadata write (they feed ``repack(use_access_frequencies=True)``
        after a reload)."""
        with self._lock:
            if self._unflushed_accesses:
                self._save_meta()

    def close(self) -> None:
        """Flush pending metadata (access counts).  Safe to call twice."""
        self.flush_access_counts()

    def recreation_cost(self, vid: int) -> float:
        """Modelled Φ along the current storage chain."""
        total = 0.0
        v: Optional[int] = vid
        hops = 0
        while v is not None:
            meta = self.versions[v]
            total += meta.phi
            v = meta.stored_base
            hops += 1
            if hops > len(self.versions):
                raise RuntimeError("storage graph cycle")
        return total

    def storage_bytes(self) -> int:
        return sum(m.stored_bytes for m in self.versions.values())

    # -------------------------------------------------------------- repack
    def build_cost_graph(
        self, *, extra_edges: bool = True
    ) -> Tuple[VersionGraph, _PayloadProvider]:
        """Measure the Δ/Φ matrices over version-graph-adjacent pairs (plus
        pairs within ``delta_hops``) and return (graph, payload provider).

        This is the paper's "revealing entries in the matrix" step: all-pairs
        is infeasible, so we measure around the derivation structure.
        Measured entries are cached in the metadata keyed by the endpoints'
        content fingerprints; unchanged entries are served from the cache
        without touching payloads.  ``last_measured_edges`` records how many
        entries were (re)measured by the call.
        """
        n = len(self.versions)
        g = VersionGraph(n, directed=True)
        provider = _PayloadProvider(self)
        codec = self.objects.codec
        measured = 0

        # legacy metas (pre-fingerprint) get their fingerprint backfilled once
        for vid, meta in self.versions.items():
            if not meta.content_fp:
                meta.content_fp = hashlib.sha256(
                    provider.full_payload(vid)
                ).hexdigest()

        # adjacency of the derivation DAG (undirected, for the hop ball)
        adj: Dict[int, set] = {v: set() for v in self.versions}
        for v, meta in self.versions.items():
            for p in meta.parents:
                adj[v].add(p)
                adj[p].add(v)

        done: set = set()
        for vid, meta in self.versions.items():
            fp = meta.content_fp
            ent = self._edge_cache.get((0, vid))
            if ent is None or ent["dfp"] != fp:
                full_payload = provider.full_payload(vid)
                ent = {
                    "sfp": "",
                    "dfp": fp,
                    "delta": codec.compressed_size(full_payload),
                    "payload_len": len(full_payload),
                    "changed_blocks": 0,
                }
                self._edge_cache[(0, vid)] = ent
                measured += 1
            g.set_materialization(
                vid, ent["delta"],
                self.cost_model.phi_full(ent["delta"], meta.raw_bytes),
            )
            # hop ball
            ball = {vid}
            frontier = {vid}
            hops = self.delta_hops if extra_edges else 1
            for _ in range(hops):
                frontier = {y for x in frontier for y in adj[x]} - ball
                ball |= frontier
            for other in sorted(ball - {vid}):
                if (other, vid) in done:
                    continue
                done.add((other, vid))
                sfp = self.versions[other].content_fp
                ent = self._edge_cache.get((other, vid))
                if ent is None or ent["sfp"] != sfp or ent["dfp"] != fp:
                    payload, stats = provider[(other, vid)]
                    ent = {
                        "sfp": sfp,
                        "dfp": fp,
                        "delta": codec.compressed_size(payload),
                        "payload_len": len(payload),
                        "changed_blocks": stats["changed_blocks"],
                    }
                    self._edge_cache[(other, vid)] = ent
                    measured += 1
                g.set_delta(
                    other, vid, ent["delta"],
                    self.cost_model.phi_delta(
                        ent["delta"], ent["payload_len"], ent["changed_blocks"]
                    ),
                )
        self.last_measured_edges = measured
        if measured:
            self._save_meta()  # persist new measurements for the next call
        return g, provider

    def access_weights(self) -> Dict[int, float]:
        """Normalized access-frequency weights (Laplace-smoothed so an
        unaccessed version still counts) — the workload signal for
        ``repack(use_access_frequencies=True)``."""
        total = sum(m.access_count + 1 for m in self.versions.values())
        return {
            v: (m.access_count + 1) / total for v, m in self.versions.items()
        }

    def repack(
        self,
        spec: Union[OptimizeSpec, str] = "lmg",
        *,
        use_access_frequencies: bool = False,
        **solver_kwargs,
    ) -> Dict[str, Any]:
        """Re-optimize the storage graph against an
        :class:`~repro.core.spec.OptimizeSpec` and rewrite physical storage
        to match.

        ``use_access_frequencies=True`` routes the recorded access counts
        into the spec's ``workload`` field; the spec must name a
        workload-aware grid point (Problems 3 or 5 — LMG), anything else
        raises instead of silently dropping the weights.

        Passing a string solver name plus kwargs is the deprecated legacy
        surface; it is mapped onto the equivalent spec via
        :func:`~repro.core.problems.spec_from_solver`.

        Returns before/after stats, ``gc_freed_bytes`` (orphaned object
        bytes reclaimed by the gc pass — repack never leaves dangling
        objects behind), and an ``optimize`` block recording the problem,
        solver, and backend actually used.
        """
        if isinstance(spec, str):
            warnings.warn(
                "repack(solver: str, **kwargs) is deprecated; pass an "
                "OptimizeSpec (repro.core.OptimizeSpec.problem(...))",
                DeprecationWarning, stacklevel=2,
            )
            spec = spec_from_solver(spec, solver_kwargs)
        elif solver_kwargs:
            raise ValueError(
                f"solver options go inside the OptimizeSpec; got stray "
                f"kwarg(s) {sorted(solver_kwargs)}"
            )
        if not self.versions:
            # nothing to repack: solvers need ≥1 version and the stats below
            # take max() over the version set
            zero = {"storage_bytes": 0, "sum_recreation_s": 0.0,
                    "max_recreation_s": 0.0}
            return {"before": dict(zero), "after": dict(zero),
                    "gc_freed_bytes": 0}
        if use_access_frequencies:
            if not spec.supports_workload():
                raise ValueError(
                    f"use_access_frequencies=True needs a workload-aware "
                    f"spec (Problem 3 or 5 — LMG); got {spec.describe()!r}. "
                    f"The chosen solver would silently ignore the recorded "
                    f"access counts."
                )
            spec = spec.with_workload(self.access_weights())
        with _span("store.repack", spec=spec.describe()) as rsp:
            before = {
                "storage_bytes": self.storage_bytes(),
                "sum_recreation_s": sum(
                    self.recreation_cost(v) for v in self.versions
                ),
                "max_recreation_s": max(
                    self.recreation_cost(v) for v in self.versions
                ),
            }
            with _span("store.measure") as msp:
                g, cache = self.build_cost_graph()
                if msp:
                    msp.set(
                        versions=len(self.versions),
                        measured_edges=self.last_measured_edges,
                    )
            result = optimize(g, spec)
            with _span("store.apply_solution"):
                self._apply_solution(result.solution, cache)
            after = {
                "storage_bytes": self.storage_bytes(),
                "sum_recreation_s": sum(
                    self.recreation_cost(v) for v in self.versions
                ),
                "max_recreation_s": max(
                    self.recreation_cost(v) for v in self.versions
                ),
            }
            with _span("store.gc") as gsp:
                freed = self.gc()
                if gsp:
                    gsp.set(freed_bytes=freed)
            if rsp:
                rsp.set(
                    solver=result.solver,
                    backend=result.backend_used,
                    storage_bytes_before=before["storage_bytes"],
                    storage_bytes_after=after["storage_bytes"],
                )
        self.last_repack = {
            "describe": spec.describe(),
            "problem": result.problem,
            "solver": result.solver,
            "backend": result.backend_used,
            "objective": spec.objective.metric,
            "objective_value": float(result.objective_value),
            "constraints": [
                {"metric": c.metric, "bound": float(c.bound)}
                for c in spec.constraints
            ],
            "timestamp": time.time(),
        }
        self._save_meta()
        if use_access_frequencies:
            # warm the cache with the hottest versions under the *new*
            # storage graph so the first post-repack hit is already served
            # from memory
            hot = sorted(
                self.versions,
                key=lambda v: self.versions[v].access_count,
                reverse=True,
            )[: self.prefetch_hot_k]
            self.materializer.prefetch(hot)
        mon = self.tradeoff_monitor
        if mon is not None:
            mon.on_repack()
        return {
            "before": before,
            "after": after,
            "gc_freed_bytes": freed,
            "optimize": {
                "problem": result.problem,
                "solver": result.solver,
                "backend": result.backend_used,
                "objective_value": result.objective_value,
                "wall_time_s": round(result.wall_time_s, 6),
            },
        }

    def _apply_solution(self, sol: StorageSolution, cache: _PayloadProvider) -> None:
        # phase 1: encode every chosen edge against the *old* storage graph
        # (the provider checkouts must not observe a half-rewritten graph)
        encoded: Dict[int, Tuple[int, bytes, Optional[Dict]]] = {}
        for vid, parent in sol.parent.items():
            payload, stats = cache[(parent, vid)]
            encoded[vid] = (parent, payload, stats)
        # phase 2: rewrite objects and metadata atomically w.r.t. checkouts
        with self._lock:
            for vid, (parent, payload, stats) in encoded.items():
                meta = self.versions[vid]
                key, stored = self.objects.put(payload)
                if parent == 0:
                    meta.stored_base = None
                    meta.phi = self.cost_model.phi_full(stored, meta.raw_bytes)
                else:
                    meta.stored_base = parent
                    meta.phi = self.cost_model.phi_delta(
                        stored, len(payload), stats["changed_blocks"]
                    )
                meta.object_key = key
                meta.stored_bytes = stored
            # storage graph rewritten: new epoch, every chain fingerprint is
            # dead — repack keeps the wholesale purge (append-aware
            # invalidation only spares *commits* the purge)
            self._storage_fp = None
        self.materializer.cache.purge()

    def gc(self) -> int:
        """Drop objects not referenced by any version; returns bytes freed."""
        live = {m.object_key for m in self.versions.values()}
        freed = 0
        for key in list(self.objects.keys()):
            if key not in live:
                freed += self.objects.stored_size(key)
                self.objects.delete(key)
        return freed

    # ---------------------------------------------------------------- fsck
    def fsck(self, **kwargs: Any):
        """Integrity-check the storage graph; returns an analysis
        :class:`~repro.analysis.findings.Report` (see
        :func:`repro.analysis.fsck.fsck_store` for the checks and kwargs)."""
        from ..analysis.fsck import fsck_store  # local: analysis -> store

        return fsck_store(self, **kwargs)

    # ------------------------------------------------------------ metadata
    def save_refs(self) -> None:
        """Persist the ``refs`` dict (branches/tags/head) with the metadata.

        Called by the :class:`~repro.store.repository.Repository` facade
        after every ref mutation — refs live in the same atomic msgpack file
        as version metas, so a close/reopen round-trips them."""
        self._save_meta()

    def _save_meta(self) -> None:
        with self._lock:
            self._save_meta_locked()

    def _save_meta_locked(self) -> None:
        blob = msgpack.packb(
            {
                "next_vid": self._next_vid,
                "versions": {
                    str(v): dataclasses.asdict(m) for v, m in self.versions.items()
                },
                "edge_cache": {
                    f"{a},{b}": ent for (a, b), ent in self._edge_cache.items()
                },
                "refs": {
                    "branches": {
                        name: vid for name, vid in self.refs["branches"].items()
                    },
                    "tags": {name: vid for name, vid in self.refs["tags"].items()},
                    "head": self.refs["head"],
                },
                "last_repack": self.last_repack,
            },
            use_bin_type=True,
        )
        fd, tmp = tempfile.mkstemp(dir=str(self.root))
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._meta_path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._unflushed_accesses = 0  # any metadata write persists counts

    def _load_meta(self) -> None:
        obj = msgpack.unpackb(self._meta_path.read_bytes(), raw=False)
        self._next_vid = obj["next_vid"]
        self.versions = {
            int(v): VersionMeta(**m) for v, m in obj["versions"].items()
        }
        self._edge_cache = {}
        for key, ent in obj.get("edge_cache", {}).items():
            a, b = key.split(",")
            self._edge_cache[(int(a), int(b))] = ent
        refs = obj.get("refs") or {}
        self.refs = {
            "branches": {
                str(k): int(v) for k, v in (refs.get("branches") or {}).items()
            },
            "tags": {str(k): int(v) for k, v in (refs.get("tags") or {}).items()},
            "head": str(refs.get("head", "main")),
        }
        self.last_repack = obj.get("last_repack") or None
        self._storage_fp = None  # metadata replaced: recompute lazily

    # -------------------------------------------------------------- limits
    def log(self) -> List[VersionMeta]:
        return [self.versions[v] for v in sorted(self.versions)]
