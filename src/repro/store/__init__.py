"""Versioned artifact store: content-addressed objects + delta chains,
storage-graph optimization via the paper's solvers."""

from .delta import (
    DeltaWire,
    RecreationCostModel,
    SparseLeafDelta,
    apply_delta,
    apply_delta_chain,
    apply_delta_chains,
    decode_delta_wire,
    decode_full,
    encode_delta,
    encode_full,
    flatten_payload,
)
from .materializer import (
    CheckoutPlan,
    CheckoutPlanner,
    MaterializationCache,
    Materializer,
)
from .objectstore import Codec, ObjectStore
from .repository import Ref, Repository, TreeDiff
from .version_store import VersionMeta, VersionStore

__all__ = [
    "Codec",
    "ObjectStore",
    "VersionStore",
    "VersionMeta",
    "Repository",
    "TreeDiff",
    "Ref",
    "Materializer",
    "MaterializationCache",
    "CheckoutPlanner",
    "CheckoutPlan",
    "RecreationCostModel",
    "flatten_payload",
    "encode_full",
    "decode_full",
    "encode_delta",
    "apply_delta",
    "apply_delta_chain",
    "apply_delta_chains",
    "decode_delta_wire",
    "DeltaWire",
    "SparseLeafDelta",
]
