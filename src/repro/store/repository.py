"""Repository — named refs over the VersionStore (the DataHub-style surface).

The :class:`~repro.store.version_store.VersionStore` speaks raw integer
version ids; this facade gives it the front-end that makes versioned storage
usable by humans and services (Bhardwaj et al.'s DATAHUB, Huang et al.'s
OrpheusDB): **branches** (mutable named pointers that advance on commit),
**tags** (immutable named pointers), and a git-shaped verb set —

* ``commit(tree, parent=ref)`` — add a version, advancing the target branch;
* ``branch(name, at=ref)`` / ``tag(name, at=ref)`` / ``switch(name)``;
* ``checkout(ref)`` / ``checkout_many(refs)`` — recreation through the
  materialization layer; a ref resolves to a vid, so ``checkout(ref)`` is
  byte-identical to ``checkout(vid)``;
* ``log(ref)`` — ancestry walk over the derivation DAG, newest first;
* ``diff(a, b)`` — leaf-level tree diff (added / removed / changed arrays);
* ``repack(spec)`` — storage-graph re-optimization against a declarative
  :class:`~repro.core.spec.OptimizeSpec`.

Refs persist in the store's ``meta.msgpack`` next to the version metadata
(same atomic rewrite), so branches and tags survive a close/reopen and are
visible to any later handle on the same root.  Every ref accepts either a
name or a raw vid — the raw-vid surface stays fully supported underneath.
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from ..core import OptimizeSpec
from .delta import FlatTree
from .version_store import VersionMeta, VersionStore

__all__ = ["Repository", "TreeDiff", "Ref"]

#: a ref: branch name, tag name, or raw version id
Ref = Union[str, int]


@dataclasses.dataclass(frozen=True)
class TreeDiff:
    """Leaf-level diff between two checked-out trees."""

    a: int                      # resolved vids
    b: int
    added: tuple                # leaf keys present only in b
    removed: tuple              # leaf keys present only in a
    changed: tuple              # leaf keys whose array content differs
    unchanged: int              # identical leaves
    bytes_added: int            # Σ nbytes over added leaves
    bytes_removed: int          # Σ nbytes over removed leaves
    bytes_changed: int          # Σ nbytes over changed leaves (b side)

    def summary(self) -> str:
        return (
            f"v{self.a}..v{self.b}: +{len(self.added)} -{len(self.removed)} "
            f"~{len(self.changed)} leaves ({self.bytes_changed/1e6:.2f} MB "
            f"changed), {self.unchanged} unchanged"
        )


class Repository:
    """Named-ref facade over a :class:`VersionStore`.

    The current branch (``head``) starts as ``"main"``; the first commit
    creates it.  All state changes (commit / branch / tag / switch) persist
    immediately.
    """

    def __init__(
        self,
        root: Union[str, Path, None] = None,
        *,
        store: Optional[VersionStore] = None,
        **store_kwargs: Any,
    ) -> None:
        if (root is None) == (store is None):
            raise ValueError("pass exactly one of root / store")
        self.store = store or VersionStore(root, **store_kwargs)

    # ------------------------------------------------------------------ refs
    @property
    def head(self) -> str:
        """The current branch name (commits without ``branch=`` advance it)."""
        return self.store.refs["head"]

    def branches(self) -> Dict[str, int]:
        return dict(self.store.refs["branches"])

    def tags(self) -> Dict[str, int]:
        return dict(self.store.refs["tags"])

    def resolve(self, ref: Optional[Ref] = None) -> int:
        """Ref -> vid.  ``None`` resolves the current branch tip; branch
        names shadow tag names; raw vids pass through (validated).

        Takes the store lock: the service tier resolves on its event loop
        while the writer thread advances branch refs, and the snapshot
        point must be the before- or after-commit tip, decided by the lock
        rather than GIL dict atomicity."""
        with self.store._lock:
            if ref is None:
                ref = self.head
            if isinstance(ref, (int, np.integer)):
                vid = int(ref)
                if vid not in self.store.versions:
                    raise ValueError(f"unknown version id {vid}")
                return vid
            branches = self.store.refs["branches"]
            tags = self.store.refs["tags"]
            if ref in branches:
                return branches[ref]
            if ref in tags:
                return tags[ref]
            raise ValueError(
                f"unknown ref {ref!r}: branches={sorted(branches)}, "
                f"tags={sorted(tags)}"
            )

    def branch(self, name: str, at: Optional[Ref] = None) -> str:
        """Create branch ``name`` at ``at`` (default: current head tip)."""
        self._check_ref_name(name)
        if name in self.store.refs["branches"]:
            raise ValueError(f"branch {name!r} already exists")
        self.store.refs["branches"][name] = self.resolve(at)
        self.store.save_refs()
        return name

    def tag(self, name: str, at: Optional[Ref] = None) -> str:
        """Create immutable tag ``name`` at ``at`` (default: head tip)."""
        self._check_ref_name(name)
        if name in self.store.refs["tags"]:
            raise ValueError(f"tag {name!r} already exists (tags are immutable)")
        self.store.refs["tags"][name] = self.resolve(at)
        self.store.save_refs()
        return name

    def switch(self, branch: str) -> int:
        """Make ``branch`` the current head; returns its tip vid."""
        if branch not in self.store.refs["branches"]:
            raise ValueError(
                f"unknown branch {branch!r}: {sorted(self.store.refs['branches'])}"
            )
        self.store.refs["head"] = branch
        self.store.save_refs()
        return self.store.refs["branches"][branch]

    def _check_ref_name(self, name: str) -> None:
        if not isinstance(name, str) or not name or name.isdigit():
            raise ValueError(
                f"ref names must be non-numeric non-empty strings, got {name!r}"
            )

    # --------------------------------------------------------------- commits
    def commit(
        self,
        tree: Any,
        *,
        message: str = "",
        parent: Union[Ref, Sequence[Ref], None] = None,
        branch: Optional[str] = None,
    ) -> int:
        """Commit a payload, advancing ``branch`` (default: current head).

        ``parent`` defaults to the branch tip; pass a ref for an explicit
        base or a sequence of refs for a merge commit.  Committing to a
        branch that does not exist yet is allowed only for the very first
        commit of an empty store (it creates the branch at the root) or
        with an explicit ``parent`` (creating the branch there, like
        ``git checkout -b``) — otherwise a typo'd branch name would
        silently create an orphan lineage.
        """
        branch = branch if branch is not None else self.head
        self._check_ref_name(branch)
        if parent is None:
            tip = self.store.refs["branches"].get(branch)
            if tip is not None:
                parents = [tip]
            elif not self.store.versions:
                parents = []  # initial commit of an empty store
            else:
                raise ValueError(
                    f"branch {branch!r} does not exist but the store has "
                    f"{len(self.store.versions)} versions; create the branch "
                    f"first (branch()) or pass parent= explicitly — an "
                    f"implicit parentless commit would orphan the new version"
                )
        elif isinstance(parent, (str, int, np.integer)):
            parents = [self.resolve(parent)]
        else:
            parents = [self.resolve(p) for p in parent]
        # the branch ref advances inside the commit's own metadata write
        return self.store.commit(
            tree, parents=parents, message=message, update_branch=branch
        )

    # -------------------------------------------------------------- checkout
    def checkout(self, ref: Optional[Ref] = None) -> FlatTree:
        """Recreate the tree at ``ref`` (default: head tip).  Identical to
        ``VersionStore.checkout(resolve(ref))`` — same plan, same cache."""
        return self.store.checkout(self.resolve(ref))

    def checkout_many(self, refs: Sequence[Ref]) -> List[FlatTree]:
        """Batch checkout: one decode plan over all resolved vids."""
        return self.store.checkout_many([self.resolve(r) for r in refs])

    # ------------------------------------------------------------- inspect
    def log(self, ref: Optional[Ref] = None) -> List[VersionMeta]:
        """Ancestry of ``ref`` over the derivation DAG, newest-vid first."""
        seen = set()
        frontier = [self.resolve(ref)]
        while frontier:
            v = frontier.pop()
            if v in seen:
                continue
            seen.add(v)
            frontier.extend(self.store.versions[v].parents)
        return [self.store.versions[v] for v in sorted(seen, reverse=True)]

    def diff(self, a: Ref, b: Ref) -> TreeDiff:
        """Leaf-level diff of the trees at two refs."""
        va, vb = self.resolve(a), self.resolve(b)
        ta, tb = self.store.checkout_many([va, vb])
        added = tuple(sorted(set(tb) - set(ta)))
        removed = tuple(sorted(set(ta) - set(tb)))
        changed, unchanged = [], 0
        for k in sorted(set(ta) & set(tb)):
            xa, xb = ta[k], tb[k]
            # byte-level comparison: NaN-safe and dtype-exact
            if (
                xa.shape != xb.shape
                or xa.dtype != xb.dtype
                or xa.tobytes() != xb.tobytes()
            ):
                changed.append(k)
            else:
                unchanged += 1
        return TreeDiff(
            a=va, b=vb,
            added=added, removed=removed, changed=tuple(changed),
            unchanged=unchanged,
            bytes_added=sum(tb[k].nbytes for k in added),
            bytes_removed=sum(ta[k].nbytes for k in removed),
            bytes_changed=sum(tb[k].nbytes for k in changed),
        )

    # --------------------------------------------------------------- storage
    def repack(
        self, spec: Union[OptimizeSpec, str] = "lmg", **kwargs: Any
    ) -> Dict[str, Any]:
        """Re-optimize physical storage against a declarative spec (see
        ``VersionStore.repack``; the string form is the deprecated shim)."""
        return self.store.repack(spec, **kwargs)

    def gc(self) -> int:
        return self.store.gc()

    def fsck(self, **kwargs: Any):
        """Integrity-check the underlying store's storage graph, refs and
        recorded constraints; returns an analysis
        :class:`~repro.analysis.findings.Report`."""
        return self.store.fsck(**kwargs)

    def serve(self, **kwargs: Any) -> "Any":
        """Build a :class:`~repro.service.DatasetService` over this repo.

        Keyword arguments pass through to ``DatasetService`` (reader-pool
        width, batching window, fsck cadence, ...); the caller starts it::

            async with repo.serve(readers=8) as svc:
                tree = await svc.checkout("main")
        """
        from ..service import DatasetService  # local: service imports us

        return DatasetService(self, **kwargs)

    def close(self) -> None:
        self.store.close()

    def __enter__(self) -> "Repository":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
