"""Pytree-level delta codec over the Pallas block kernels.

Encodes a *version payload* (any pytree of arrays — model params, optimizer
state, dataset shards) either fully or as a delta against a base payload:

* leaves present in both with identical shape/dtype → **block-sparse delta**
  (changed-block indices + packed 4 KiB blocks, from the Pallas mask/compact
  path) — the common case for checkpoint chains where few blocks move;
* new / reshaped leaves → stored whole;
* deleted leaves → tombstones.

Wire format is msgpack; zstd happens in the object store.  The codec also
returns the *measured* Δ (serialized bytes) and a Φ estimate from
:class:`RecreationCostModel` — these feed the paper's cost matrices, keeping
Δ and Φ genuinely distinct quantities (Scenario 3: Φ ≠ Δ).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from ..kernels import ops
from ..kernels.ref import BLOCK_BYTES

FlatTree = Dict[str, np.ndarray]


def flatten_payload(tree: Any) -> FlatTree:
    """Flatten a pytree to {path: np.ndarray} with '/'-joined keys."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# --------------------------------------------------------------------- wire
def _arr_to_wire(a: np.ndarray) -> Dict:
    return {
        "dtype": a.dtype.str if a.dtype != jnp.bfloat16 else "bfloat16",
        "shape": list(a.shape),
        "data": a.tobytes(),
    }


def _arr_from_wire(d: Dict) -> np.ndarray:
    dtype = jnp.bfloat16 if d["dtype"] == "bfloat16" else np.dtype(d["dtype"])
    return np.frombuffer(d["data"], dtype=dtype).reshape(d["shape"]).copy()


def encode_full(flat: FlatTree) -> bytes:
    # sorted-key serialization: the content fingerprint (sha256 of these
    # bytes) must not depend on dict insertion order, which differs between
    # the commit path (tree_flatten order) and checkout-re-encode
    # (apply_delta rebuild order) — an order-dependent fp would spuriously
    # invalidate the incremental Δ/Φ edge cache
    return msgpack.packb(
        {"kind": "full",
         "leaves": {k: _arr_to_wire(flat[k]) for k in sorted(flat)}},
        use_bin_type=True,
    )


def decode_full(payload: bytes) -> FlatTree:
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["kind"] == "full", obj["kind"]
    return {k: _arr_from_wire(v) for k, v in obj["leaves"].items()}


def encode_delta(base: FlatTree, new: FlatTree) -> Tuple[bytes, Dict]:
    """Delta payload turning `base` into `new`, plus stats for Φ modelling."""
    sparse, full, stats = {}, {}, {"changed_blocks": 0, "total_blocks": 0, "full_leaves": 0}
    tombstones = [k for k in base if k not in new]
    for key, arr in new.items():
        b = base.get(key)
        if b is None or b.shape != arr.shape or b.dtype != arr.dtype:
            full[key] = _arr_to_wire(arr)
            stats["full_leaves"] += 1
            continue
        bb, meta = ops.to_blocks(jnp.asarray(b))
        nb, _ = ops.to_blocks(jnp.asarray(arr))
        idx, blocks, n = ops.sparse_encode(bb, nb)
        stats["changed_blocks"] += n
        stats["total_blocks"] += int(bb.shape[0])
        if n == 0:
            sparse[key] = {"idx": b"", "blocks": b"", "n": 0}
            continue
        # trim padding before serialization (padding is a device-side artifact)
        sparse[key] = {
            "idx": np.asarray(idx[:n], np.int32).tobytes(),
            "blocks": np.asarray(blocks[:n], np.int32).tobytes(),
            "n": int(n),
        }
    payload = msgpack.packb(
        {"kind": "delta", "sparse": sparse, "full": full, "tombstones": tombstones},
        use_bin_type=True,
    )
    return payload, stats


def apply_delta(base: FlatTree, payload: bytes) -> FlatTree:
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["kind"] == "delta", obj["kind"]
    tombstones = set(obj["tombstones"])  # O(1) lookup per leaf, not O(T)
    out: FlatTree = {}
    for key, arr in base.items():
        if key in tombstones:
            continue
        d = obj["sparse"].get(key)
        if d is None:
            out[key] = arr
            continue
        if d["n"] == 0:
            out[key] = arr
            continue
        bb, meta = ops.to_blocks(jnp.asarray(arr))
        idx = jnp.asarray(np.frombuffer(d["idx"], np.int32))
        blocks = jnp.asarray(
            np.frombuffer(d["blocks"], np.int32).reshape(-1, 8, 128)
        )
        rec = ops.sparse_apply(bb, blocks, idx)
        out[key] = np.asarray(ops.from_blocks(rec, meta))
    for key, wire in obj["full"].items():
        out[key] = _arr_from_wire(wire)
    return out


# ----------------------------------------------------------------- Φ model
@dataclasses.dataclass(frozen=True)
class RecreationCostModel:
    """Maps a stored object to an estimated recreation cost in seconds.

    Distinct from Δ (bytes at rest): reading is charged at storage bandwidth,
    decompression and block application at their own rates — so compact,
    compute-heavy deltas genuinely trade Φ against Δ (paper Scenario 3).
    """

    read_gbps: float = 2.0          # storage/network read bandwidth
    decompress_gbps: float = 1.0    # zstd decode rate
    apply_gbps: float = 8.0         # on-device block scatter rate
    seek_s: float = 0.005           # per-object latency

    def phi(self, stored_bytes: int, raw_bytes: int, applied_bytes: int) -> float:
        return (
            self.seek_s
            + stored_bytes / (self.read_gbps * 1e9)
            + raw_bytes / (self.decompress_gbps * 1e9)
            + applied_bytes / (self.apply_gbps * 1e9)
        )

    def phi_full(self, stored_bytes: int, raw_bytes: int) -> float:
        return self.phi(stored_bytes, raw_bytes, 0)

    def phi_delta(self, stored_bytes: int, raw_bytes: int, changed_blocks: int) -> float:
        return self.phi(stored_bytes, raw_bytes, changed_blocks * BLOCK_BYTES)
