"""Pytree-level delta codec over the Pallas block kernels.

Encodes a *version payload* (any pytree of arrays — model params, optimizer
state, dataset shards) either fully or as a delta against a base payload:

* leaves present in both with identical shape/dtype → **block-sparse delta**
  (changed-block indices + packed 4 KiB blocks, from the Pallas mask/compact
  path) — the common case for checkpoint chains where few blocks move;
* new / reshaped leaves → stored whole;
* deleted leaves → tombstones.

Wire format is msgpack; zstd happens in the object store.  The codec also
returns the *measured* Δ (serialized bytes) and a Φ estimate from
:class:`RecreationCostModel` — these feed the paper's cost matrices, keeping
Δ and Φ genuinely distinct quantities (Scenario 3: Φ ≠ Δ).

Blocked-layout + chain-fusion contract
--------------------------------------
A leaf's bytes are viewed as ``(num_blocks, 8, 128)`` int32 — one 4 KiB
storage block per TPU VMEM tile (:func:`repro.kernels.ops.to_blocks`).  A
sparse wire entry stores the *new content* of each changed block plus its
row index, with ``_compact``'s device-side padding trimmed before
serialization; padding is reintroduced at decode time as ``idx = -1`` slots.

Because sparse entries carry content (not XOR), a K-step chain composes by
**last-writer-wins per block row**, which :func:`apply_delta_chain` exploits:
the chain's packed deltas are flattened *in chain order* into one padded
device stack and applied in a single fused Pallas dispatch
(:mod:`repro.kernels.chain_apply`), bit-identical to K sequential
:func:`apply_delta` calls.  Per-leaf slot counts are bucketed to powers of
two (min 8) and leaves with equal ``(num_blocks, slot_bucket)`` are batched
into one kernel launch, so jit caches are shared across chains of different
lengths and sparsity — the same shape-bucketing discipline as
``core/solvers/jax_backend.py``.  A leaf's chain segment restarts at any
mid-chain full rewrite (shape/dtype change) and ends at a tombstone; only
the segments between those events reach the kernel.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from ..kernels import ops
from ..kernels.ref import BLOCK_BYTES
from ..obs.tracer import start as _trace_start

FlatTree = Dict[str, np.ndarray]
# device-resident companion of a FlatTree: blocked form + layout meta per
# leaf (only leaves that went through the block pipeline appear)
BlockedLeaf = Tuple[jnp.ndarray, "ops.BlockMeta"]
BlockedTree = Dict[str, BlockedLeaf]

_SLOT_BUCKET_MIN = 8


def flatten_payload(tree: Any) -> FlatTree:
    """Flatten a pytree to {path: np.ndarray} with '/'-joined keys."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


# --------------------------------------------------------------------- wire
def _arr_to_wire(a: np.ndarray) -> Dict:
    return {
        "dtype": a.dtype.str if a.dtype != jnp.bfloat16 else "bfloat16",
        "shape": list(a.shape),
        "data": a.tobytes(),
    }


def _arr_from_wire(d: Dict) -> np.ndarray:
    dtype = jnp.bfloat16 if d["dtype"] == "bfloat16" else np.dtype(d["dtype"])
    return np.frombuffer(d["data"], dtype=dtype).reshape(d["shape"]).copy()


def encode_full(flat: FlatTree) -> bytes:
    # sorted-key serialization: the content fingerprint (sha256 of these
    # bytes) must not depend on dict insertion order, which differs between
    # the commit path (tree_flatten order) and checkout-re-encode
    # (apply_delta rebuild order) — an order-dependent fp would spuriously
    # invalidate the incremental Δ/Φ edge cache
    return msgpack.packb(
        {"kind": "full",
         "leaves": {k: _arr_to_wire(flat[k]) for k in sorted(flat)}},
        use_bin_type=True,
    )


def decode_full(payload: bytes) -> FlatTree:
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["kind"] == "full", obj["kind"]
    return {k: _arr_from_wire(v) for k, v in obj["leaves"].items()}


def encode_delta(base: FlatTree, new: FlatTree) -> Tuple[bytes, Dict]:
    """Delta payload turning `base` into `new`, plus stats for Φ modelling."""
    sparse, full, stats = {}, {}, {"changed_blocks": 0, "total_blocks": 0, "full_leaves": 0}
    tombstones = [k for k in base if k not in new]
    for key, arr in new.items():
        b = base.get(key)
        if b is None or b.shape != arr.shape or b.dtype != arr.dtype:
            full[key] = _arr_to_wire(arr)
            stats["full_leaves"] += 1
            continue
        bb, meta = ops.to_blocks(jnp.asarray(b))
        nb, _ = ops.to_blocks(jnp.asarray(arr))
        idx, blocks, n = ops.sparse_encode(bb, nb)
        stats["changed_blocks"] += n
        stats["total_blocks"] += int(bb.shape[0])
        if n == 0:
            sparse[key] = {"idx": b"", "blocks": b"", "n": 0}
            continue
        # trim padding before serialization (padding is a device-side artifact)
        sparse[key] = {
            "idx": np.asarray(idx[:n], np.int32).tobytes(),
            "blocks": np.asarray(blocks[:n], np.int32).tobytes(),
            "n": int(n),
        }
    payload = msgpack.packb(
        {"kind": "delta", "sparse": sparse, "full": full, "tombstones": tombstones},
        use_bin_type=True,
    )
    return payload, stats


# ------------------------------------------------------------- wire decode
@dataclasses.dataclass(frozen=True)
class SparseLeafDelta:
    """One leaf's packed sparse delta, trimmed (no padding slots)."""

    idx: np.ndarray      # (n,) int32 changed block rows
    blocks: np.ndarray   # (n, 8, 128) int32 packed new block content
    n: int


@dataclasses.dataclass(frozen=True)
class DeltaWire:
    """A decoded delta payload: msgpack unpacked once, arrays zero-copy views
    ready for device upload (the decode path of the fused chain pipeline)."""

    sparse: Dict[str, SparseLeafDelta]
    full: Dict[str, Dict]          # raw wire dicts, decoded lazily
    tombstones: FrozenSet[str]


def decode_delta_wire(payload: bytes) -> DeltaWire:
    """Unpack a delta payload into :class:`DeltaWire` (no block application)."""
    obj = msgpack.unpackb(payload, raw=False)
    assert obj["kind"] == "delta", obj["kind"]
    sparse: Dict[str, SparseLeafDelta] = {}
    for key, d in obj["sparse"].items():
        n = int(d["n"])
        if n == 0:
            sparse[key] = SparseLeafDelta(
                np.empty((0,), np.int32), np.empty((0, 8, 128), np.int32), 0
            )
            continue
        idx = np.frombuffer(d["idx"], np.int32)
        blocks = np.frombuffer(d["blocks"], np.int32).reshape(-1, 8, 128)
        sparse[key] = SparseLeafDelta(idx, blocks, n)
    return DeltaWire(sparse, obj["full"], frozenset(obj["tombstones"]))


def apply_delta(base: FlatTree, payload: Union[bytes, DeltaWire]) -> FlatTree:
    """Stepwise (one-hop) delta application — the reference recreation path.

    Unchanged leaves pass through by reference; each changed leaf pays one
    ``to_blocks``/``sparse_apply``/``from_blocks`` round trip.  Chains should
    use :func:`apply_delta_chain`, which is bit-identical and fused.
    """
    wire = decode_delta_wire(payload) if isinstance(payload, bytes) else payload
    out: FlatTree = {}
    # device results accumulate here and come back in ONE batched transfer
    # after the loop — a per-leaf np.asarray would serialize a device→host
    # sync per changed leaf (the analysis host-sync lint flags exactly that)
    pending: Dict[str, jnp.ndarray] = {}
    for key, arr in base.items():
        if key in wire.tombstones:
            continue
        d = wire.sparse.get(key)
        if d is None or d.n == 0:
            out[key] = arr
            continue
        bb, meta = ops.to_blocks(jnp.asarray(arr))
        rec = ops.sparse_apply(bb, jnp.asarray(d.blocks), jnp.asarray(d.idx))
        pending[key] = ops.from_blocks(rec, meta)
    if pending:
        out.update(jax.device_get(pending))
    for key, wire_dict in wire.full.items():
        out[key] = _arr_from_wire(wire_dict)
    return out


# ----------------------------------------------------- fused chain pipeline
def _slot_bucket(n: int) -> int:
    """Pad per-leaf chain slot counts to powers of two (min 8) so the fused
    kernel's jit cache is shared across chains of different depth/sparsity."""
    cap = _SLOT_BUCKET_MIN
    while cap < n:
        cap *= 2
    return cap


@dataclasses.dataclass
class _LeafProgram:
    """Per-leaf chain plan: an origin value plus the sparse segments applied
    after it (segments restart at mid-chain full rewrites)."""

    req: int                       # request index in the batch
    key: str
    origin_step: Optional[int]     # None → base tree; else wires[i].full
    segments: List[SparseLeafDelta]


def _resolve_leaf_programs(
    base: FlatTree, wires: Sequence[DeltaWire]
) -> Dict[str, Tuple[Optional[int], List[SparseLeafDelta]]]:
    """Fold a chain's per-step leaf events into one program per final leaf.

    Walks steps in order: tombstones kill a leaf, full rewrites restart its
    chain segment (later sparse deltas apply on the rewritten value), sparse
    deltas append to the current segment.  The result maps every leaf of the
    chain's *final* tree to ``(origin_step, segments)``.
    """
    state: Dict[str, Tuple[Optional[int], List[SparseLeafDelta]]] = {
        k: (None, []) for k in base
    }
    for i, w in enumerate(wires):
        for k in w.tombstones:
            state.pop(k, None)
        for k, d in w.sparse.items():
            if d.n == 0:
                continue
            st = state.get(k)
            if st is None:
                raise ValueError(
                    f"corrupt chain: step {i} carries a sparse delta for "
                    f"leaf {k!r} absent from the running tree"
                )
            st[1].append(d)
        for k in w.full:
            state[k] = (i, [])
    return state


def _num_blocks(arr: np.ndarray) -> int:
    return -(-arr.nbytes // BLOCK_BYTES)


def apply_delta_chains(
    requests: Sequence[
        Tuple[FlatTree, Sequence[Union[bytes, DeltaWire]], Optional[BlockedTree]]
    ],
    *,
    stats: Optional[Dict[str, int]] = None,
) -> List[Tuple[FlatTree, BlockedTree]]:
    """Apply one delta chain per request, fused and batched across requests.

    Each request is ``(base_tree, chain_payloads, base_blocked)`` —
    ``base_blocked`` optionally carries the base's device-resident blocked
    leaves so repeatedly-edited leaves skip re-``to_blocks``.  Leaves are
    grouped by ``(num_blocks, slot_bucket)`` across *all* requests and each
    group runs as one :func:`repro.kernels.ops.chain_apply_batched` launch.

    Returns ``[(tree, blocked)]`` per request, bit-identical to folding
    :func:`apply_delta` over each chain.  ``stats`` (optional) is bumped
    with ``launches`` / ``fused_slots`` for observability.
    """
    # explicit-lifetime span (no context entry): nothing below opens child
    # spans, and the single end() keeps the device-dispatch loop unindented
    _sp = _trace_start("delta.apply_chains", requests=len(requests))
    # the caller's stats dict accumulates across calls; snapshot so the span
    # attributes only this call's launches
    _launch0 = (stats or {}).get("launches", 0)
    _slots0 = (stats or {}).get("fused_slots", 0)
    wire_chains: List[List[DeltaWire]] = []
    outs: List[FlatTree] = []
    blocked_outs: List[BlockedTree] = []
    units: List[_LeafProgram] = []
    for ri, (base, payloads, _) in enumerate(requests):
        wires = [
            decode_delta_wire(p) if isinstance(p, bytes) else p
            for p in payloads
        ]
        wire_chains.append(wires)
        out: FlatTree = {}
        outs.append(out)
        blocked_outs.append({})
        for key, (origin_step, segs) in _resolve_leaf_programs(
            base, wires
        ).items():
            if not segs:
                # untouched leaf (reference passthrough) or plain full decode
                out[key] = (
                    base[key]
                    if origin_step is None
                    else _arr_from_wire(wires[origin_step].full[key])
                )
                continue
            units.append(_LeafProgram(ri, key, origin_step, segs))

    # shape-bucketed grouping: one fused launch per (num_blocks, slot_bucket)
    groups: Dict[Tuple[int, int], List[_LeafProgram]] = {}
    origins: Dict[Tuple[int, str], Tuple[Any, "ops.BlockMeta"]] = {}
    for u in units:
        base, _, base_blocked = requests[u.req]
        if u.origin_step is None:
            pre = (base_blocked or {}).get(u.key)
            if pre is not None:
                origin_blocks, meta = pre
            else:
                origin_blocks, meta = ops.to_blocks(jnp.asarray(base[u.key]))
        else:
            arr = _arr_from_wire(wire_chains[u.req][u.origin_step].full[u.key])
            origin_blocks, meta = ops.to_blocks(jnp.asarray(arr))
        origins[(u.req, u.key)] = (origin_blocks, meta)
        total = sum(s.n for s in u.segments)
        groups.setdefault((meta.num_blocks, _slot_bucket(total)), []).append(u)

    # dispatch every group first, keeping results on device; the host copies
    # happen once at the end as a single batched transfer (device_get issues
    # the async copies together), not one blocking sync per leaf
    host_fetch: List[Tuple[int, str, Any]] = []
    for (nb, cap), members in groups.items():
        idx_pad = np.full((len(members), cap), -1, np.int32)
        blk_pad = np.zeros((len(members), cap, 8, 128), np.int32)
        for li, u in enumerate(members):
            at = 0
            for seg in u.segments:  # chain order: later slots win in the fold
                idx_pad[li, at : at + seg.n] = seg.idx
                blk_pad[li, at : at + seg.n] = seg.blocks
                at += seg.n
        if len(members) == 1:
            u = members[0]
            ob, meta = origins[(u.req, u.key)]
            rec = ops.chain_apply(
                ob, jnp.asarray(blk_pad[0]), jnp.asarray(idx_pad[0])
            )
            recs = [rec]
        else:
            stack = jnp.stack([origins[(u.req, u.key)][0] for u in members])
            recs = ops.chain_apply_batched(
                stack, jnp.asarray(blk_pad), jnp.asarray(idx_pad)
            )
        if stats is not None:
            stats["launches"] = stats.get("launches", 0) + 1
            stats["fused_slots"] = (
                stats.get("fused_slots", 0) + len(members) * cap
            )
        for u, rec in zip(members, recs):
            meta = origins[(u.req, u.key)][1]
            blocked_outs[u.req][u.key] = (rec, meta)
            host_fetch.append((u.req, u.key, ops.from_blocks(rec, meta)))
    if host_fetch:
        fetched = jax.device_get([dev for _, _, dev in host_fetch])
        for (req, key, _), arr in zip(host_fetch, fetched):
            outs[req][key] = arr
    if _sp:
        if stats is not None:
            _sp.set(
                launches=stats.get("launches", 0) - _launch0,
                fused_slots=stats.get("fused_slots", 0) - _slots0,
            )
        else:
            _sp.set(launches=len(groups))
        _sp.set(leaves=len(units))
    _sp.end()
    return list(zip(outs, blocked_outs))


def apply_delta_chain(
    base: FlatTree,
    payloads: Sequence[Union[bytes, DeltaWire]],
    *,
    base_blocked: Optional[BlockedTree] = None,
) -> FlatTree:
    """Fused K-step chain application (single chain): bit-identical to
    ``functools.reduce(apply_delta, payloads, base)`` in one device dispatch
    per leaf-shape group."""
    return apply_delta_chains([(base, payloads, base_blocked)])[0][0]


# ----------------------------------------------------------------- Φ model
@dataclasses.dataclass(frozen=True)
class RecreationCostModel:
    """Maps a stored object to an estimated recreation cost in seconds.

    Distinct from Δ (bytes at rest): reading is charged at storage bandwidth,
    decompression and block application at their own rates — so compact,
    compute-heavy deltas genuinely trade Φ against Δ (paper Scenario 3).
    """

    read_gbps: float = 2.0          # storage/network read bandwidth
    decompress_gbps: float = 1.0    # zstd decode rate
    apply_gbps: float = 8.0         # on-device block scatter rate
    seek_s: float = 0.005           # per-object latency

    def phi(self, stored_bytes: int, raw_bytes: int, applied_bytes: int) -> float:
        return (
            self.seek_s
            + stored_bytes / (self.read_gbps * 1e9)
            + raw_bytes / (self.decompress_gbps * 1e9)
            + applied_bytes / (self.apply_gbps * 1e9)
        )

    def phi_full(self, stored_bytes: int, raw_bytes: int) -> float:
        return self.phi(stored_bytes, raw_bytes, 0)

    def phi_delta(self, stored_bytes: int, raw_bytes: int, changed_blocks: int) -> float:
        return self.phi(stored_bytes, raw_bytes, changed_blocks * BLOCK_BYTES)
