"""DatasetService — concurrent serving front-end over a :class:`Repository`.

The paper's recreation cost Φ only matters under retrieval traffic; this is
the tier that takes that traffic (the OrpheusDB/DataHub "bolt-on serving
front-end" shape): an asyncio event loop accepting checkout / commit / log /
diff / repack requests and dispatching the CPU/device-bound work onto thread
pools, with three load-bearing mechanisms:

* **Request coalescing** — a checkout resolves its ref to a vid at enqueue
  time (the snapshot point); if a materialization for that vid is already in
  flight, the request awaits the same future instead of decoding twice
  (``checkout.coalesced`` counts these).  Correct because a version's tree
  is immutable: whatever commit lands meanwhile, vid → tree never changes.
  Waiters await the shared future through ``asyncio.shield`` — a client
  timeout cancelling one coalesced request cannot cancel the future the
  other waiters share.

* **Batching window** — distinct vids arriving within ``batch_window_s``
  fold into one :meth:`VersionStore.checkout_many` plan (capped at
  ``max_batch``), so chain prefixes shared across concurrent requests decode
  exactly once.  The batch runs on a reader thread-pool worker; requesters
  await per-vid futures.

* **Single-writer / multi-reader coordination** — commits serialize on a
  one-thread writer pool and may overlap with readers (a commit only
  *appends* to the storage graph, and the append-aware cache keeps reader
  state warm across it — see ``cache_invalidation="chain"`` on
  ``VersionStore``).  ``repack`` and the background fsck sweep take the
  exclusive side of an async reader-writer lock, quiescing every in-flight
  request before the storage graph rewrites under them.  Each enqueued
  checkout additionally parks a read claim on its batch (released when the
  batch settles), so the quiesce covers pending/dispatching batches even
  when the requesters behind them were cancelled.

Reads are snapshot-consistent: ref resolution happens once per request on
the event loop, so a ``checkout("main")`` racing a commit observes either
the old or the new tip, never a torn mix — and the tree it returns is the
immutable content of whichever vid it resolved.

Per-request metrics (queue wait, decode time, warm-hit attribution,
p50/p99 end-to-end latency) record into :class:`ServiceMetrics`; a
configurable :class:`~repro.service.sweeper.FsckSweeper` surfaces integrity
findings and repack recommendations through the same registry.

Usage::

    repo = Repository(root)
    async with DatasetService(repo, readers=8) as svc:
        tree = await svc.checkout("main")
        vid = await svc.commit(new_tree, message="nightly refresh")
        print(svc.stats()["counters"])

All public coroutines must run on the loop that ``start()`` ran on.
"""

from __future__ import annotations

import asyncio
import contextlib
import dataclasses
import logging
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Set, Union

from ..obs.tracer import get_tracer
from ..obs.tradeoff import TradeoffMonitor
from ..store.delta import FlatTree
from ..store.repository import Ref, Repository, TreeDiff
from .metrics import ServiceMetrics

logger = logging.getLogger("repro.service")

__all__ = ["DatasetService"]


class _AsyncRWLock:
    """Async reader-writer lock: checkouts/commits/log/diff share the read
    side; repack and fsck take the write side, draining readers first.  A
    waiting writer blocks new readers, so sustained read traffic cannot
    starve a repack."""

    def __init__(self) -> None:
        self._readers = 0
        self._writer = False
        self._cond: Optional[asyncio.Condition] = None

    def _condition(self) -> asyncio.Condition:
        if self._cond is None:  # created lazily on the running loop
            self._cond = asyncio.Condition()
        return self._cond

    @contextlib.asynccontextmanager
    async def read(self):
        cond = self._condition()
        async with cond:
            await cond.wait_for(lambda: not self._writer)
            self._readers += 1
        try:
            yield
        finally:
            async with cond:
                self._readers -= 1
                cond.notify_all()

    @contextlib.asynccontextmanager
    async def write(self):
        cond = self._condition()
        async with cond:
            await cond.wait_for(lambda: not self._writer)
            self._writer = True
            try:
                await cond.wait_for(lambda: self._readers == 0)
            except BaseException:
                # cancelled mid-acquire: drop the claim or the flag leaks
                # and every later reader/writer blocks forever
                self._writer = False
                cond.notify_all()
                raise
        try:
            yield
        finally:
            async with cond:
                self._writer = False
                cond.notify_all()

    def claim_read_nowait(self) -> None:
        """Add one read claim synchronously.  Caller must already hold the
        read side (``_readers > 0`` and therefore no active writer), so the
        increment needs no waiting and — being await-free on the event
        loop — cannot be torn by a cancellation.  Pair with
        :meth:`release_read`."""
        if self._readers <= 0:
            raise RuntimeError("claim_read_nowait without a held read lock")
        self._readers += 1

    async def release_read(self, n: int = 1) -> None:
        """Release ``n`` claims taken via :meth:`claim_read_nowait`."""
        cond = self._condition()
        async with cond:
            self._readers -= n
            cond.notify_all()


@dataclasses.dataclass
class _PendingCheckout:
    """One enqueued checkout awaiting its batch: vid, future, enqueue time,
    and (when tracing) the request's root span — the batch dispatcher
    parents the retroactive queue-wait span under it."""

    vid: int
    future: "asyncio.Future[FlatTree]"
    enqueued_at: float
    span: Any = None


class DatasetService:
    """Asyncio serving tier over a :class:`Repository` (see module docs).

    Construct, then ``await start()`` (or use ``async with``).  Knobs:

    * ``readers`` — checkout/log/diff thread-pool width (checkouts are
      CPU/device-bound; the event loop itself never decodes).
    * ``batch_window_s`` — how long a checkout waits for co-batchable
      requests before dispatching; ``0`` dispatches on the next loop tick.
    * ``max_batch`` — dispatch immediately once this many distinct vids are
      pending, whatever the window says.
    * ``fsck_interval_s`` — run a background integrity sweep this often
      (``None`` disables; see :class:`FsckSweeper`).  ``fsck_sample``
      bounds the expensive per-version re-decode.
    * ``tradeoff`` — attach a :class:`~repro.obs.tradeoff.TradeoffMonitor`
      to the store for the service's lifetime (default on): live (C, R)
      samples on every commit/repack, surfaced through :meth:`stats`,
      the sweeper's drift gauges, and the Prometheus exporter.

    Tracing: the request path records spans into the process-global
    :mod:`repro.obs` tracer (disabled by default — the instrumentation then
    costs one attribute check per request stage).  Enable with
    ``repro.obs.tracing()`` around the service, or install a tracer via
    ``repro.obs.set_tracer``.  Span times share the event loop's
    ``time.monotonic`` clock, so per-stage span totals reconcile exactly
    with the ``ServiceMetrics`` latency tracks.
    """

    def __init__(
        self,
        repo: Repository,
        *,
        readers: int = 4,
        batch_window_s: float = 0.002,
        max_batch: int = 32,
        fsck_interval_s: Optional[float] = None,
        fsck_sample: Optional[int] = None,
        metrics_cap: int = 100_000,
        tradeoff: bool = True,
    ) -> None:
        if readers < 1:
            raise ValueError(f"need at least one reader thread, got {readers}")
        self.repo = repo
        self.readers = int(readers)
        self.batch_window_s = float(batch_window_s)
        self.max_batch = int(max_batch)
        self.fsck_interval_s = fsck_interval_s
        self.fsck_sample = fsck_sample
        self.metrics = ServiceMetrics(track_cap=metrics_cap)
        self.tradeoff = bool(tradeoff)
        self._owns_monitor = False
        self._monitor: Optional[TradeoffMonitor] = None
        self.last_fsck = None  # most recent sweep Report (sweeper writes it)
        self._rw = _AsyncRWLock()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._reader_pool: Optional[ThreadPoolExecutor] = None
        self._writer_pool: Optional[ThreadPoolExecutor] = None
        self._inflight: Dict[int, "asyncio.Future[FlatTree]"] = {}
        self._pending: List[_PendingCheckout] = []
        self._window_handle: Optional[asyncio.TimerHandle] = None
        self._dispatch_tasks: Set["asyncio.Task"] = set()
        self._sweep_task: Optional["asyncio.Task"] = None
        self._started = False

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> "DatasetService":
        """Bind to the running loop, spin up pools and the fsck sweeper."""
        if self._started:
            raise RuntimeError("service already started")
        self._loop = asyncio.get_running_loop()
        self._reader_pool = ThreadPoolExecutor(
            max_workers=self.readers, thread_name_prefix="repro-svc-read"
        )
        self._writer_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-svc-write"
        )
        if self.tradeoff and self.repo.store.tradeoff_monitor is None:
            self.repo.store.tradeoff_monitor = TradeoffMonitor(self.repo.store)
            self._owns_monitor = True
            self.repo.store.tradeoff_monitor.sample("start")
        self._monitor = self.repo.store.tradeoff_monitor
        self._started = True
        if self.fsck_interval_s is not None:
            from .sweeper import FsckSweeper  # local: sweeper imports us

            sweeper = FsckSweeper(
                self, interval_s=self.fsck_interval_s, sample=self.fsck_sample
            )
            self._sweep_task = self._loop.create_task(sweeper.run())
        return self

    async def stop(self) -> None:
        """Drain in-flight work, stop the sweeper, flush access counts."""
        if not self._started:
            return
        self._started = False  # new requests now refuse
        if self._sweep_task is not None:
            self._sweep_task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await self._sweep_task
            self._sweep_task = None
        self._dispatch_now()  # whatever the window was still holding
        while self._dispatch_tasks:
            await asyncio.gather(
                *list(self._dispatch_tasks), return_exceptions=True
            )
        # quiesce: taking the write side proves no reader remains in flight
        async with self._rw.write():
            pass
        self._reader_pool.shutdown(wait=True)
        self._writer_pool.shutdown(wait=True)
        if self._owns_monitor:
            # detach so store-layer bulk commits stop paying O(n) sampling;
            # self._monitor keeps the history readable through stats()
            self.repo.store.tradeoff_monitor = None
            self._owns_monitor = False
        self.repo.store.flush_access_counts()

    async def __aenter__(self) -> "DatasetService":
        return await self.start()

    async def __aexit__(self, *exc: Any) -> None:
        await self.stop()

    def _require_started(self) -> None:
        if not self._started:
            raise RuntimeError(
                "DatasetService not started (await start() or use "
                "'async with DatasetService(...)')"
            )

    # ------------------------------------------------------------- checkout
    async def checkout(self, ref: Optional[Ref] = None) -> FlatTree:
        """Materialize the tree at ``ref`` (default: head tip).

        Coalesces with any in-flight materialization of the same vid and
        folds into the current batching window otherwise.  Returns a fresh
        dict per request; the arrays are shared with the cache, read-only.
        """
        self._require_started()
        t0 = self._loop.time()
        self.metrics.inc("requests.checkout")
        tr = get_tracer()
        sp = tr.start("svc.checkout")  # request root span (NULL when disabled)
        try:
            async with self._rw.read():
                vid = self.repo.resolve(ref)  # snapshot point
                fut = self._inflight.get(vid)
                if fut is not None:
                    self.metrics.inc("checkout.coalesced")
                    if sp:
                        sp.set(vid=vid, coalesced=True)
                else:
                    fut = self._loop.create_future()
                    self._inflight[vid] = fut
                    # the pending entry itself holds a read claim (released
                    # by _dispatch after the batch settles), so a repack
                    # cannot slip between enqueue and dispatch even if
                    # every requester behind the batch is cancelled
                    self._rw.claim_read_nowait()
                    if sp:
                        sp.set(vid=vid, coalesced=False)
                    self._pending.append(
                        _PendingCheckout(vid, fut, t0, sp or None)
                    )
                    self._arm_window()
                # shield: the future is shared by every request coalesced
                # onto this vid — one waiter's cancellation must neither
                # cancel the others nor poison _inflight with a cancelled
                # future for later arrivals
                tree = await asyncio.shield(fut)
        except Exception:
            self.metrics.inc("errors.checkout")
            if sp:
                sp.set(error=True)
            raise
        finally:
            sp.end()
        self.metrics.observe("latency.checkout", self._loop.time() - t0)
        return dict(tree)

    async def checkout_many(self, refs: Sequence[Ref]) -> List[FlatTree]:
        """Concurrent checkouts of several refs — they coalesce and batch
        against each other exactly like independent requests."""
        return list(
            await asyncio.gather(*(self.checkout(r) for r in refs))
        )

    def _arm_window(self) -> None:
        if len(self._pending) >= self.max_batch:
            self._dispatch_now()
        elif self._window_handle is None:
            self._window_handle = self._loop.call_later(
                self.batch_window_s, self._dispatch_now
            )

    def _dispatch_now(self) -> None:
        if self._window_handle is not None:
            self._window_handle.cancel()
            self._window_handle = None
        batch, self._pending = self._pending, []
        if not batch:
            return
        task = self._loop.create_task(self._dispatch(batch))
        self._dispatch_tasks.add(task)
        task.add_done_callback(self._dispatch_tasks.discard)

    async def _dispatch(self, batch: List[_PendingCheckout]) -> None:
        """Run one folded batch on a reader thread; settle per-vid futures.

        The batch holds one read claim per entry (taken at enqueue), so the
        whole enqueue→settle span sits inside the read side of the RW lock:
        a repack/fsck writer cannot rewrite the storage graph under a
        pending or running batch, whatever happened to the requesters."""
        now = self._loop.time()
        store = self.repo.store
        tr = get_tracer()
        bsp = tr.start("svc.batch", size=len(batch))
        self.metrics.inc("checkout.batches")
        self.metrics.inc("checkout.batched_refs", len(batch))
        for p in batch:
            self.metrics.observe("queue_wait", now - p.enqueued_at)
            if tr.enabled:
                # retroactive: the enqueue→dispatch interval, parented under
                # the request's own root span (exactly the queue_wait track)
                tr.add_event(
                    "svc.queue_wait", p.enqueued_at, now,
                    parent=p.span, vid=p.vid,
                )
        vids = [p.vid for p in batch]  # distinct by construction (coalescing)

        def run_batch():
            # warm-hit attribution just before the decode mutates cache
            # state — on the reader thread, because probe() hashes the whole
            # decode chain per vid (too much work for the event loop)
            # (attach: pool threads don't inherit the submitting context, so
            # materializer/delta spans need the batch span bridged across)
            with tr.attach(bsp or None):
                warm = sum(1 for v in vids if store.materializer.probe(v))
                return warm, store.checkout_many(vids)

        try:
            try:
                t0 = self._loop.time()
                warm, trees = await self._loop.run_in_executor(
                    self._reader_pool, run_batch
                )
                t1 = self._loop.time()
                self.metrics.observe("decode", t1 - t0)
                if tr.enabled:
                    # same interval the "decode" track just recorded
                    tr.add_event("svc.decode", t0, t1, parent=bsp,
                                 vids=len(vids), warm=warm)
                self.metrics.inc("checkout.warm_hits", warm)
                self.metrics.inc("checkout.warm_misses", len(vids) - warm)
                if bsp:
                    bsp.set(warm=warm)
            except Exception as exc:
                for p in batch:
                    self._inflight.pop(p.vid, None)
                    if not p.future.done():
                        p.future.set_exception(exc)
                        # consume: every waiter may have been cancelled, and
                        # an unretrieved future exception logs noise at GC
                        p.future.exception()
                return
            for p, tree in zip(batch, trees):
                self._inflight.pop(p.vid, None)
                if not p.future.done():
                    p.future.set_result(tree)
        finally:
            bsp.end()
            # shielded: the claims MUST drop even if this task is cancelled
            # mid-release, or a waiting writer hangs forever
            await asyncio.shield(self._rw.release_read(len(batch)))

    # ---------------------------------------------------------------- write
    async def commit(
        self,
        tree: Any,
        *,
        message: str = "",
        parent: Union[Ref, Sequence[Ref], None] = None,
        branch: Optional[str] = None,
    ) -> int:
        """Commit a payload through the single-writer pool; returns its vid.

        Commits hold the read side of the RW lock — they may overlap with
        checkouts (append-only, and the append-aware cache keeps reader
        entries warm) but serialize among themselves on the one writer
        thread, and are excluded by an in-progress repack/fsck.
        """
        self._require_started()
        t0 = self._loop.time()
        self.metrics.inc("requests.commit")
        tr = get_tracer()
        sp = tr.start("svc.commit")

        def run_commit():
            with tr.attach(sp or None):  # bridge onto the writer thread
                return self.repo.commit(
                    tree, message=message, parent=parent, branch=branch
                )

        try:
            async with self._rw.read():
                vid = await self._loop.run_in_executor(
                    self._writer_pool, run_commit
                )
        except Exception:
            self.metrics.inc("errors.commit")
            if sp:
                sp.set(error=True)
            raise
        finally:
            sp.end()
        if sp:
            sp.set(vid=vid)
        self.metrics.observe("latency.commit", self._loop.time() - t0)
        return vid

    async def repack(
        self, spec: Any = "lmg", **kwargs: Any
    ) -> Dict[str, Any]:
        """Re-optimize physical storage under the exclusive write lock —
        every in-flight checkout/commit drains first, and the cache purge
        the rewrite triggers can never race a reader."""
        self._require_started()
        t0 = self._loop.time()
        self.metrics.inc("requests.repack")
        tr = get_tracer()
        sp = tr.start("svc.repack")

        def run_repack():
            with tr.attach(sp or None):  # store.repack nests under us
                return self.repo.repack(spec, **kwargs)

        try:
            async with self._rw.write():
                if tr.enabled:
                    # the drain window: write-lock wait while in-flight
                    # readers finish
                    tr.add_event(
                        "svc.quiesce", t0, self._loop.time(), parent=sp
                    )
                out = await self._loop.run_in_executor(
                    self._writer_pool, run_repack
                )
        finally:
            sp.end()
        self.metrics.observe("latency.repack", self._loop.time() - t0)
        return out

    # ---------------------------------------------------------------- reads
    async def log(self, ref: Optional[Ref] = None) -> List[Any]:
        """Ancestry of ``ref`` (resolved at dispatch), newest first."""
        self._require_started()
        self.metrics.inc("requests.log")
        with get_tracer().span("svc.log"):
            async with self._rw.read():
                return await self._loop.run_in_executor(
                    self._reader_pool, self.repo.log, ref
                )

    async def diff(self, a: Ref, b: Ref) -> TreeDiff:
        """Leaf-level diff of two refs, materialized on a reader thread."""
        self._require_started()
        self.metrics.inc("requests.diff")
        with get_tracer().span("svc.diff"):
            async with self._rw.read():
                return await self._loop.run_in_executor(
                    self._reader_pool, self.repo.diff, a, b
                )

    async def fsck(self):
        """One on-demand integrity sweep (same path, metrics and write-lock
        quiescing as the periodic background sweeper)."""
        self._require_started()
        from .sweeper import FsckSweeper

        return await FsckSweeper(
            self, interval_s=0.0, sample=self.fsck_sample
        ).sweep()

    # -------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        """Service metrics snapshot + the shared materializer/cache stats,
        plus the live tradeoff telemetry when a monitor is attached."""
        out = self.metrics.snapshot()
        out["store"] = self.repo.store.materializer.stats()
        mon = self._monitor or self.repo.store.tradeoff_monitor
        if mon is not None:
            out["tradeoff"] = mon.snapshot()
        if self.last_fsck is not None:
            out["fsck"] = {
                "findings": len(self.last_fsck.findings),
                "checked": sum(self.last_fsck.checked.values()),
            }
        return out
