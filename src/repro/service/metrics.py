"""Service-tier metrics: counters plus latency tracks with p50/p99 rollups.

Every request through the :class:`~repro.service.service.DatasetService`
records into one shared :class:`ServiceMetrics` — queue wait (enqueue →
batch dispatch), decode time (one sample per dispatched batch), end-to-end
latency per operation, and counters for the coalescing/batching machinery
(``checkout.coalesced``, ``checkout.batches``, ``checkout.warm_hits``, …)
and the background fsck sweep (``fsck.sweeps``, ``fsck.findings``,
``fsck.repack_recommended``).

Tracks keep a bounded window of recent samples (oldest dropped) for the
quantile rollups while ``count``/``mean`` cover everything ever observed, so
a long-lived service neither grows without bound nor loses its throughput
totals.  All methods are thread-safe: reader-pool threads and the event loop
record into the same registry.
"""

from __future__ import annotations

import collections
import math
import threading
from typing import Deque, Dict, Iterable, List, Union

__all__ = ["LatencyTrack", "ServiceMetrics", "percentile"]


def percentile(samples: Iterable[float], q: float) -> float:
    """Nearest-rank percentile of a non-empty sample set (``q`` in 0..100).

    Half-point ranks round *up* (explicit floor-half-up): builtin
    ``round()`` is banker's rounding, which sends every ``x.5`` rank to the
    nearer even index — on even-length windows the median rank (exactly
    ``.5``) then always resolves to the lower neighbor and the reported
    quantile biases low.
    """
    xs = sorted(samples)
    if not xs:
        raise ValueError("percentile of empty sample set")
    idx = int(math.floor(q / 100.0 * (len(xs) - 1) + 0.5))
    return xs[min(len(xs) - 1, max(0, idx))]


class LatencyTrack:
    """One latency series: bounded sample window + lifetime count/sum."""

    __slots__ = ("samples", "count", "total", "peak")

    def __init__(self, cap: int) -> None:
        self.samples: Deque[float] = collections.deque(maxlen=cap)
        self.count = 0
        self.total = 0.0
        self.peak = 0.0

    def record(self, seconds: float) -> None:
        self.samples.append(seconds)
        self.count += 1
        self.total += seconds
        if seconds > self.peak:
            self.peak = seconds

    def summary(self) -> Dict[str, float]:
        """Rollup in milliseconds (quantiles over the retained window)."""
        if not self.count:
            return {"count": 0}
        window: List[float] = list(self.samples)
        return {
            "count": self.count,
            "mean_ms": round(self.total / self.count * 1e3, 4),
            "p50_ms": round(percentile(window, 50) * 1e3, 4),
            "p99_ms": round(percentile(window, 99) * 1e3, 4),
            "max_ms": round(self.peak * 1e3, 4),
        }


class ServiceMetrics:
    """Thread-safe counter + latency-track registry for the service tier."""

    def __init__(self, *, track_cap: int = 100_000) -> None:
        self._lock = threading.Lock()
        self._track_cap = int(track_cap)
        self._counters: Dict[str, int] = {}
        self._tracks: Dict[str, LatencyTrack] = {}
        self._gauges: Dict[str, float] = {}

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def counter(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def set_gauge(self, name: str, value: float) -> None:
        """Last-write-wins point-in-time value (tradeoff drift ratios, store
        gauges — anything that is a level, not a count or a latency)."""
        with self._lock:
            self._gauges[name] = value

    def gauge(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    def observe(self, track: str, seconds: float) -> None:
        with self._lock:
            t = self._tracks.get(track)
            if t is None:
                t = self._tracks[track] = LatencyTrack(self._track_cap)
            t.record(seconds)

    def track(self, name: str) -> Dict[str, float]:
        with self._lock:
            t = self._tracks.get(name)
            return t.summary() if t is not None else {"count": 0}

    def snapshot(self) -> Dict[str, Union[Dict, int]]:
        """Point-in-time view: every counter, track rollup, and gauge."""
        with self._lock:
            return {
                "counters": dict(sorted(self._counters.items())),
                "tracks": {
                    name: t.summary()
                    for name, t in sorted(self._tracks.items())
                },
                "gauges": dict(sorted(self._gauges.items())),
            }
