"""Concurrent serving tier for versioned datasets.

The store layer answers "what does version v contain?"; this package
answers it *under traffic*: an asyncio front-end that coalesces identical
requests, folds concurrent distinct requests into one batched checkout
plan, coordinates the single writer with many readers, and keeps
per-request latency/hit-rate metrics — the serving half of the paper's
recreation-cost story.

Entry points: :class:`DatasetService` (or ``Repository.serve()``),
:class:`ServiceMetrics` for the shared registry, :class:`FsckSweeper` for
background integrity sweeps.
"""

from .metrics import LatencyTrack, ServiceMetrics, percentile
from .service import DatasetService
from .sweeper import FsckSweeper

__all__ = [
    "DatasetService",
    "FsckSweeper",
    "LatencyTrack",
    "ServiceMetrics",
    "percentile",
]
