"""Background integrity sweeps for a running :class:`DatasetService`.

A long-lived serving process accumulates risk the offline tools never see:
bit-rot in delta payloads, a half-applied repack after a crash, constraint
drift (a storage graph that no longer satisfies the recreation bound δ the
last optimization promised).  :class:`FsckSweeper` runs
:meth:`Repository.fsck` on a configurable cadence under the service's
exclusive write lock — the same quiescing a repack takes — so the whole
version table can be walked without racing in-flight commits.

Findings land in the shared :class:`ServiceMetrics` registry
(``fsck.sweeps``, ``fsck.findings``) and the full
:class:`~repro.analysis.findings.Report` is kept on
``service.last_fsck``.  A ``fsck.constraint`` finding means the stored
chains violate the recreation-cost bound the last repack was solved
against — the sweep counts it under ``fsck.repack_recommended`` and logs a
repack recommendation, since re-solving storage is the fix, not a serving
concern.

When the service carries a :class:`~repro.obs.tradeoff.TradeoffMonitor`
(the default), every sweep also publishes the live (C, R) drift as gauges
(``tradeoff.storage_ratio``, ``tradeoff.access_weighted_recreation_ratio``,
…) and the repack recommendation becomes *quantitative*: the warning says
how far the tradeoff has moved — "access-weighted R is 2.3x the post-repack
baseline" — not just that a constraint broke.
"""

from __future__ import annotations

import asyncio
import logging
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - import cycle (service starts us)
    from .service import DatasetService

logger = logging.getLogger("repro.service")

__all__ = ["FsckSweeper"]


class FsckSweeper:
    """Periodic fsck runner bound to one service (see module docs)."""

    def __init__(
        self,
        service: "DatasetService",
        *,
        interval_s: float,
        sample: Optional[int] = None,
    ) -> None:
        self.service = service
        self.interval_s = float(interval_s)
        self.sample = sample

    async def run(self) -> None:
        """Sweep every ``interval_s`` seconds until cancelled."""
        while True:
            await asyncio.sleep(self.interval_s)
            try:
                await self.sweep()
            except asyncio.CancelledError:
                raise
            except Exception:
                self.service.metrics.inc("errors.fsck")
                logger.exception("background fsck sweep failed")

    async def sweep(self):
        """One sweep: quiesce requests, fsck on a reader thread, record."""
        from ..obs.tracer import get_tracer

        svc = self.service
        tr = get_tracer()
        sp = tr.start("svc.fsck_sweep")
        t0 = svc._loop.time()
        try:
            async with svc._rw.write():
                if tr.enabled:
                    tr.add_event(
                        "svc.quiesce", t0, svc._loop.time(), parent=sp
                    )

                def run_fsck():
                    with tr.attach(sp or None):
                        return svc.repo.fsck(sample=self.sample)

                report = await svc._loop.run_in_executor(
                    svc._reader_pool, run_fsck
                )
        finally:
            sp.end()
        svc.last_fsck = report
        svc.metrics.inc("fsck.sweeps")
        svc.metrics.inc("fsck.findings", len(report.findings))
        if sp:
            sp.set(findings=len(report.findings))
        drift_note = self._publish_tradeoff_drift()
        drift = report.by_rule("fsck.constraint")
        if drift:
            svc.metrics.inc("fsck.repack_recommended")
            logger.warning(
                "fsck: %d constraint-drift finding(s) — stored chains no "
                "longer meet the last optimization's recreation bound; "
                "recommend scheduling a repack%s",
                len(drift),
                f" ({drift_note})" if drift_note else "",
            )
        return report

    def _publish_tradeoff_drift(self) -> Optional[str]:
        """Export the monitor's live drift ratios as ``tradeoff.*`` gauges
        and return the human one-liner for the repack recommendation (or
        ``None`` when no monitor/samples exist)."""
        svc = self.service
        mon = getattr(svc, "_monitor", None) or getattr(
            svc.repo.store, "tradeoff_monitor", None
        )
        if mon is None:
            return None
        # take a fresh sample so the ratios reflect *now*, not the last
        # commit (a sweep may run long after traffic went quiet)
        mon.sample("sweep")
        d = mon.drift()
        if d is None:
            return None
        for key in (
            "storage_ratio",
            "access_weighted_recreation_ratio",
            "recreation_p99_ratio",
        ):
            if d.get(key) is not None:
                svc.metrics.set_gauge(f"tradeoff.{key}", round(d[key], 6))
        svc.metrics.set_gauge("tradeoff.versions_added", d["versions_added"])
        svc.metrics.set_gauge("tradeoff.storage_bytes", d["storage_bytes"])
        return mon.describe_drift()
