"""Background integrity sweeps for a running :class:`DatasetService`.

A long-lived serving process accumulates risk the offline tools never see:
bit-rot in delta payloads, a half-applied repack after a crash, constraint
drift (a storage graph that no longer satisfies the recreation bound δ the
last optimization promised).  :class:`FsckSweeper` runs
:meth:`Repository.fsck` on a configurable cadence under the service's
exclusive write lock — the same quiescing a repack takes — so the whole
version table can be walked without racing in-flight commits.

Findings land in the shared :class:`ServiceMetrics` registry
(``fsck.sweeps``, ``fsck.findings``) and the full
:class:`~repro.analysis.findings.Report` is kept on
``service.last_fsck``.  A ``fsck.constraint`` finding means the stored
chains violate the recreation-cost bound the last repack was solved
against — the sweep counts it under ``fsck.repack_recommended`` and logs a
repack recommendation, since re-solving storage is the fix, not a serving
concern.
"""

from __future__ import annotations

import asyncio
import logging
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:  # pragma: no cover - import cycle (service starts us)
    from .service import DatasetService

logger = logging.getLogger("repro.service")

__all__ = ["FsckSweeper"]


class FsckSweeper:
    """Periodic fsck runner bound to one service (see module docs)."""

    def __init__(
        self,
        service: "DatasetService",
        *,
        interval_s: float,
        sample: Optional[int] = None,
    ) -> None:
        self.service = service
        self.interval_s = float(interval_s)
        self.sample = sample

    async def run(self) -> None:
        """Sweep every ``interval_s`` seconds until cancelled."""
        while True:
            await asyncio.sleep(self.interval_s)
            try:
                await self.sweep()
            except asyncio.CancelledError:
                raise
            except Exception:
                self.service.metrics.inc("errors.fsck")
                logger.exception("background fsck sweep failed")

    async def sweep(self):
        """One sweep: quiesce requests, fsck on a reader thread, record."""
        svc = self.service
        async with svc._rw.write():
            report = await svc._loop.run_in_executor(
                svc._reader_pool,
                lambda: svc.repo.fsck(sample=self.sample),
            )
        svc.last_fsck = report
        svc.metrics.inc("fsck.sweeps")
        svc.metrics.inc("fsck.findings", len(report.findings))
        drift = report.by_rule("fsck.constraint")
        if drift:
            svc.metrics.inc("fsck.repack_recommended")
            logger.warning(
                "fsck: %d constraint-drift finding(s) — stored chains no "
                "longer meet the last optimization's recreation bound; "
                "recommend scheduling a repack",
                len(drift),
            )
        return report
