"""repro.analysis — static analyses gating CI as a *regression* framework.

Two passes share one :class:`~repro.analysis.findings.Finding` / baseline
framework:

* :func:`~repro.analysis.kernel_audit.run_audit` — the **kernel/dispatch
  auditor**: traces every registered Pallas kernel and jitted solver entry
  point to a jaxpr (abstractly, via ``jax.make_jaxpr`` — no accelerator and
  no execution, so it runs identically with or without
  ``REPRO_PALLAS_INTERPRET``; that knob only affects runtime interpretation,
  while the ``pallas_call`` equations the auditor inspects appear in the
  trace either way) and lints jaxprs + module ASTs for TPU-readiness and
  dispatch-efficiency hazards.
* :func:`~repro.analysis.fsck.fsck_store` — the **storage-graph fsck**:
  walks a ``VersionStore`` like ``git fsck`` walks an object database
  (also surfaced as ``VersionStore.fsck()`` / ``Repository.fsck()``).

Rule catalog
============

Auditor (``python -m repro.analysis audit``):

``audit.trace`` (ERROR)
    Registered target failed to trace at all — it would silently drop out
    of every jaxpr rule.
``audit.dtype64`` (ERROR)
    Non-weak 64-bit values in a jaxpr traced under ``jax_enable_x64`` with
    the target's production input dtypes.  TPUs have no 64-bit lanes; in
    default x64-off mode the same code silently downcasts, so explicit
    64-bit intent (``astype(int64)``, default argmin index dtypes,
    promoting sums) is a latent porting bug.  Weak-typed Python scalars are
    exempt — they lower to the operand dtype.
``audit.dtype64-source`` (ERROR)
    ``int64``/``float64``/``uint64``/``complex128`` attribute tokens in
    kernel/hot-path module ASTs (catches paths the example trace misses;
    docstrings and comments don't count).
``audit.host-sync`` (ERROR)
    ``np.asarray`` / ``np.array`` / ``jax.device_get`` / ``.item()`` /
    ``.block_until_ready()`` inside a ``for``/``while`` loop of the
    materializer decode hot path (``store/delta.py`` appliers, the
    ``Materializer`` executors) — one blocking device→host sync per leaf.
    Batch: accumulate device results, one ``jax.device_get`` after the loop.
``audit.shape-bucket`` (ERROR)
    Two sub-checks: the bucket functions (``_slot_bucket``,
    ``_round_capacity``, ``_bucket_rows``, ``_bucket_width``) must cover,
    quantize (pow2 / multiple-of-8), and be idempotent + monotone; and
    same-bucket sizes must trace to identical Pallas kernel shapes —
    otherwise jit/kernel compile caches fragment per size.
``audit.io-alias`` (WARNING)
    A ``pallas_call`` output ≥ 1 MiB matching an input's shape+dtype must
    be aliased (``input_output_aliases``); otherwise the dispatch allocates
    a second full-size HBM buffer on the checkout hot path.

Fsck (``python -m repro.analysis fsck ROOT | --synthetic``): see
:mod:`repro.analysis.fsck` — dangling parents/bases, ``stored_base``
cycles, missing/orphaned objects, independent chain re-decode with content
fingerprint recomputation (cache-bypassing, so bit flips at rest are
caught), ref validity, and re-validation of the constraint bounds recorded
by the last ``repack`` against the current storage graph.

Baseline workflow
=================

Findings gate CI only when **new**.  ``Finding.key()`` (``rule::subject``,
deliberately line-number-free) identifies a finding across unrelated edits;
the committed ``analysis_baseline.json`` lists accepted keys.  CI runs::

    python -m repro.analysis audit                # exit 1 on new findings
    python -m repro.analysis fsck --synthetic     # exit 1 on any finding

To accept a finding deliberately::

    python -m repro.analysis audit --write-baseline
    git add analysis_baseline.json               # review the note, commit

NOTE-level findings (e.g. the allowlisted 64-bit solver math of
``core/solvers/jax_backend.py``, documented until the real-accelerator f32
flip) never gate and never need baselining; a baselined WARNING that later
escalates to ERROR re-gates.
"""

from .findings import (
    GATE_SEVERITY,
    Finding,
    Report,
    Severity,
    load_baseline,
    partition,
    write_baseline,
)

__all__ = [
    "Finding",
    "Severity",
    "Report",
    "GATE_SEVERITY",
    "load_baseline",
    "write_baseline",
    "partition",
    "run_audit",
    "fsck_store",
]


def run_audit():
    """Lazy re-export of :func:`repro.analysis.kernel_audit.run_audit`
    (importing jax only when the auditor actually runs)."""
    from .kernel_audit import run_audit as _run

    return _run()


def fsck_store(store, **kwargs):
    """Lazy re-export of :func:`repro.analysis.fsck.fsck_store`."""
    from .fsck import fsck_store as _fsck

    return _fsck(store, **kwargs)
