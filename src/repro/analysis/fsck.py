"""Storage-graph fsck — integrity checking for a VersionStore on disk.

Walks the physical storage graph the way ``git fsck`` walks an object
database, reporting :class:`~repro.analysis.findings.Finding`\\ s instead of
raising: a corrupted store should produce a complete damage report, not die
on the first bad object.

Checks (rule ids):

``fsck.dangling-parent`` / ``fsck.dangling-base`` (ERROR)
    Derivation parents / storage bases referencing unknown version ids.
``fsck.cycle`` (ERROR)
    ``stored_base`` chains must be acyclic — a cycle makes every version on
    it unrecreatable (checkout would never reach a full object).
``fsck.missing-object`` (ERROR)
    A version's ``object_key`` has no object file.
``fsck.orphan-object`` (WARNING)
    An object file no version references (leaked bytes; ``gc()`` reclaims).
``fsck.unreadable`` (ERROR)
    Decoding a version's storage chain raised (truncated/corrupt payload,
    codec failure, malformed wire format).
``fsck.fingerprint`` (ERROR)
    Recomputed content fingerprint differs from the recorded one —
    **bit-level payload corruption**.  The chain is re-decoded here
    independently of the materialization cache: the cache key is the
    storage-graph fingerprint (vid/base/key triples), which a bit flip
    inside an object file does *not* change, so a cached tree would mask
    exactly the corruption this check exists to find.  Sampled via
    ``sample=`` on big stores; full sweep by default.
``fsck.ref`` (ERROR)
    Branch/tag pointing at an unknown version; head naming a missing branch.
``fsck.constraint`` (ERROR)
    The store records the spec of its last ``repack`` (``last_repack`` in
    the metadata).  The recorded constraint bounds are re-validated against
    the *current* storage graph with the solvers' ``CONSTRAINT_TOL`` — a
    post-repack mutation that broke an agreed ``storage<=beta`` /
    ``max_recreation<=theta`` bound is drift worth failing CI over.
    Skipped when the graph has cycles or dangling bases (the metrics are
    undefined there; those errors are already reported).
"""

from __future__ import annotations

import hashlib
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

from ..core.solvers import CONSTRAINT_TOL
from ..store.delta import FlatTree, apply_delta, decode_full, encode_full
from .findings import Finding, Report, Severity

if TYPE_CHECKING:  # pragma: no cover
    from ..store.version_store import VersionStore


def _storage_cycles(versions) -> List[List[int]]:
    """Cycles in the ``stored_base`` functional graph (each as a vid list)."""
    color: Dict[int, int] = {}  # 0/absent=white, 1=on current path, 2=done
    cycles: List[List[int]] = []
    for start in sorted(versions):
        if color.get(start):
            continue
        path: List[int] = []
        v: Optional[int] = start
        while v is not None and v in versions and not color.get(v):
            color[v] = 1
            path.append(v)
            v = versions[v].stored_base
        if v is not None and color.get(v) == 1:
            cycles.append(path[path.index(v):])
        for u in path:
            color[u] = 2
    return cycles


def _sample_vids(vids: Sequence[int], sample: Optional[int]) -> List[int]:
    """Deterministic evenly-spaced subset (first and last always included)."""
    vids = sorted(vids)
    if sample is None or sample >= len(vids) or sample <= 0:
        return list(vids)
    if sample == 1:
        return [vids[-1]]
    step = (len(vids) - 1) / (sample - 1)
    return sorted({vids[round(i * step)] for i in range(sample)})


def _decode_chain(store: "VersionStore", vid: int,
                  memo: Dict[int, FlatTree]) -> FlatTree:
    """Recreate ``vid`` straight from object payloads (no materializer cache;
    see the ``fsck.fingerprint`` rationale in the module docstring)."""
    chain: List[int] = []
    v: Optional[int] = vid
    while v is not None and v not in memo:
        chain.append(v)
        v = store.versions[v].stored_base
    tree = memo[v] if v is not None else None
    for u in reversed(chain):
        payload = store.objects.get(store.versions[u].object_key)
        tree = decode_full(payload) if tree is None else \
            apply_delta(tree, payload)
        memo[u] = tree
    return memo[vid]


def fsck_store(store: "VersionStore", *,
               sample: Optional[int] = None) -> Report:
    """Run every fsck check over ``store``; returns a :class:`Report`.

    ``sample`` bounds how many versions get the (expensive) independent
    chain re-decode + fingerprint recomputation; graph/object/ref checks
    are always exhaustive.
    """
    report = Report(tool="fsck")
    versions = store.versions

    # ---- graph shape: dangling references, cycles -------------------------
    decodable = set(versions)
    for vid in sorted(versions):
        report.bump("fsck.dangling-parent")
        report.bump("fsck.dangling-base")
        meta = versions[vid]
        for p in meta.parents:
            if p not in versions:
                report.add(Finding(
                    "fsck.dangling-parent", Severity.ERROR, f"v{vid}",
                    f"derivation parent v{p} does not exist",
                    "restore the missing version's metadata or rewrite the "
                    "parents list",
                ))
        b = meta.stored_base
        if b is not None and b not in versions:
            decodable.discard(vid)
            report.add(Finding(
                "fsck.dangling-base", Severity.ERROR, f"v{vid}",
                f"stored_base v{b} does not exist — the version is "
                f"unrecreatable",
                "re-encode the version as a full object or against a "
                "surviving base (repack does both)",
            ))
    report.bump("fsck.cycle", len(versions))
    cycles = _storage_cycles(versions)
    on_cycle = {v for cyc in cycles for v in cyc}
    for cyc in cycles:
        loop = " -> ".join(f"v{v}" for v in cyc + cyc[:1])
        report.add(Finding(
            "fsck.cycle", Severity.ERROR, f"v{min(cyc)}",
            f"stored_base cycle: {loop} — no member can ever reach a full "
            f"object",
            "break the cycle by re-encoding one member as a full object",
        ))

    # ---- object inventory -------------------------------------------------
    live = {m.object_key for m in versions.values()}
    for vid in sorted(versions):
        report.bump("fsck.missing-object")
        meta = versions[vid]
        if not store.objects.exists(meta.object_key):
            decodable.discard(vid)
            report.add(Finding(
                "fsck.missing-object", Severity.ERROR, f"v{vid}",
                f"object {meta.object_key[:12]}… is gone from the object "
                f"store",
                "restore the object file from a replica; the version (and "
                "every delta stored against it) is unrecreatable until then",
            ))
    for key in sorted(store.objects.keys()):
        report.bump("fsck.orphan-object")
        if key not in live:
            report.add(Finding(
                "fsck.orphan-object", Severity.WARNING, f"object:{key[:12]}",
                f"object {key[:12]}… ({store.objects.stored_size(key)} B) is "
                f"referenced by no version",
                "run gc() to reclaim the bytes (repack does this "
                "automatically)",
            ))

    # ---- refs -------------------------------------------------------------
    refs = store.refs
    for kind, singular in (("branches", "branch"), ("tags", "tag")):
        for name, vid in sorted(refs.get(kind, {}).items()):
            report.bump("fsck.ref")
            if vid not in versions:
                report.add(Finding(
                    "fsck.ref", Severity.ERROR, f"{singular}:{name}",
                    f"{singular} {name!r} points at unknown version v{vid}",
                    "delete the ref or repoint it at a surviving version",
                ))
    if refs.get("branches"):
        report.bump("fsck.ref")
        head = refs.get("head")
        if head not in refs["branches"]:
            report.add(Finding(
                "fsck.ref", Severity.ERROR, f"head:{head}",
                f"head names branch {head!r}, which does not exist",
                "switch() to an existing branch",
            ))

    # ---- payload integrity: independent re-decode + fingerprint -----------
    # only chains that are structurally sound can be decoded; every vid
    # excluded here is already covered by an ERROR above
    blocked = set(on_cycle)
    changed = True
    while changed:  # propagate undecodability down base chains
        changed = False
        for vid in list(decodable):
            b = versions[vid].stored_base
            if b is not None and (b in blocked or b not in decodable):
                decodable.discard(vid)
                blocked.add(vid)
                changed = True
    decodable -= blocked
    memo: Dict[int, FlatTree] = {}
    for vid in _sample_vids(sorted(decodable), sample):
        report.bump("fsck.fingerprint")
        meta = versions[vid]
        try:
            flat = _decode_chain(store, vid, memo)
        except Exception as e:
            report.add(Finding(
                "fsck.unreadable", Severity.ERROR, f"v{vid}",
                f"decoding the storage chain raised "
                f"{type(e).__name__}: {e}",
                "the stored payload (or one of its bases) is corrupt; "
                "restore from a replica",
            ))
            memo[vid] = None  # poison: dependents fail fast, not misleadingly
            continue
        fp = hashlib.sha256(encode_full(flat)).hexdigest()
        if meta.content_fp and fp != meta.content_fp:
            report.add(Finding(
                "fsck.fingerprint", Severity.ERROR, f"v{vid}",
                f"recomputed content fingerprint {fp[:12]}… != recorded "
                f"{meta.content_fp[:12]}… — payload bytes changed at rest",
                "bit-level corruption in this version's chain; restore the "
                "affected object(s) from a replica",
            ))

    # ---- recorded optimization constraints --------------------------------
    lr = store.last_repack
    if lr and lr.get("constraints") and versions:
        structurally_sound = not cycles and all(
            (m.stored_base is None or m.stored_base in versions)
            for m in versions.values()
        )
        for cons in lr["constraints"]:
            report.bump("fsck.constraint")
            metric, bound = cons["metric"], float(cons["bound"])
            if not structurally_sound:
                continue  # metrics undefined; graph errors already reported
            if metric == "storage":
                achieved = float(store.storage_bytes())
            else:
                costs = [store.recreation_cost(v) for v in versions]
                achieved = (max(costs) if metric == "max_recreation"
                            else sum(costs))
            if achieved > bound + CONSTRAINT_TOL:
                report.add(Finding(
                    "fsck.constraint", Severity.ERROR,
                    f"constraint:{metric}",
                    f"last repack ({lr.get('describe', '?')}) bounded "
                    f"{metric} <= {bound:g}, but the current storage graph "
                    f"achieves {achieved:g}",
                    "the storage graph drifted past its agreed bound since "
                    "the last repack; repack again with the same spec",
                ))
    return report
