"""Command-line front-end: ``python -m repro.analysis {audit,fsck}``.

Exit status is the CI contract: 0 = no non-baselined gating findings,
1 = new findings (build should fail), 2 = usage error.  ``--write-baseline``
records the current gating findings as accepted and exits 0 — commit the
file to move the gate.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
from typing import Optional

import numpy as np

from .findings import Report, load_baseline, partition, write_baseline

DEFAULT_BASELINE = "analysis_baseline.json"


def build_synthetic_store(root) -> "object":
    """A small but fully-featured store for self-checks: a main line of
    commits, a side branch, a tag, and a constrained repack (so every fsck
    rule, including ``fsck.constraint``, has something to verify)."""
    from ..core import OptimizeSpec
    from ..store.repository import Repository

    rng = np.random.RandomState(0)
    repo = Repository(root)
    tree = {
        "w": rng.randn(64, 64).astype(np.float32),
        "b": rng.randn(256).astype(np.float32),
    }
    repo.commit(tree, message="init")
    for i in range(3):
        tree = dict(tree)
        w = tree["w"].copy()
        w[i, : 8] += 1.0
        tree["w"] = w
        repo.commit(tree, message=f"step {i}")
    repo.branch("side", at=2)
    side = dict(tree)
    side["extra"] = rng.randn(128).astype(np.float32)
    repo.commit(side, message="side work", branch="side")
    repo.tag("v1", at=3)
    # generous θ: the point is recording a constraint, not stressing it
    repo.repack(OptimizeSpec.problem(6, theta=10.0))
    return repo


def _finish(report: Report, args: argparse.Namespace) -> int:
    if args.write_baseline:
        n = write_baseline(report.findings, args.baseline,
                           note="accepted via --write-baseline")
        print(f"wrote {n} accepted finding(s) to {args.baseline}")
        return 0
    baseline = load_baseline(args.baseline)
    new, old = partition(report.findings, baseline)
    if args.json:
        old_keys = {f.key() for f in old}
        print(json.dumps({
            "tool": report.tool,
            "checked": report.checked,
            "findings": [
                dict(f.to_dict(), baselined=f.key() in old_keys)
                for f in report.findings
            ],
            "new": len(new),
        }, indent=2))
    else:
        print(report.render(baseline=baseline))
    if new:
        print(f"\n{len(new)} new finding(s) — failing. Fix them, or accept "
              f"deliberately with --write-baseline and commit "
              f"{args.baseline}.", file=sys.stderr)
        return 1
    return 0


def _cmd_audit(args: argparse.Namespace) -> int:
    from .kernel_audit import run_audit

    return _finish(run_audit(), args)


def _cmd_fsck(args: argparse.Namespace) -> int:
    from .fsck import fsck_store

    if args.synthetic == (args.root is not None):
        print("fsck: pass exactly one of ROOT or --synthetic",
              file=sys.stderr)
        return 2
    if args.synthetic:
        with tempfile.TemporaryDirectory() as td:
            repo = build_synthetic_store(td)
            return _finish(fsck_store(repo.store, sample=args.sample), args)
    from ..store.version_store import VersionStore

    store = VersionStore(args.root)
    return _finish(fsck_store(store, sample=args.sample), args)


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analyses for the repro storage system: 'audit' "
                    "lints every kernel/solver jaxpr for TPU-readiness; "
                    "'fsck' integrity-checks a VersionStore on disk.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)
    for name, fn in (("audit", _cmd_audit), ("fsck", _cmd_fsck)):
        s = sub.add_parser(name)
        s.set_defaults(fn=fn)
        s.add_argument("--baseline", default=DEFAULT_BASELINE,
                       help=f"baseline file (default {DEFAULT_BASELINE}; "
                            f"missing file = empty baseline)")
        s.add_argument("--write-baseline", action="store_true",
                       help="accept current gating findings and exit 0")
        s.add_argument("--json", action="store_true",
                       help="machine-readable report on stdout")
        if name == "fsck":
            s.add_argument("root", nargs="?",
                           help="store root directory to check")
            s.add_argument("--synthetic", action="store_true",
                           help="build a throwaway synthetic store and fsck "
                                "it (CI self-check)")
            s.add_argument("--sample", type=int, default=None,
                           help="cap fingerprint re-decodes to N versions "
                                "(default: all)")
    args = p.parse_args(argv)
    return args.fn(args)
