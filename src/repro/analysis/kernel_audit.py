"""Jaxpr-level kernel/dispatch auditor — the TPU-readiness lint.

Every registered kernel and jitted solver entry point is traced abstractly
with :func:`jax.make_jaxpr` (no accelerator, no execution — tracing is
independent of ``REPRO_PALLAS_INTERPRET``; the Pallas calls appear as
``pallas_call`` equations whether or not they would interpret at runtime)
and the resulting jaxprs are linted against the rule catalog in
:mod:`repro.analysis`.  Traces run under ``jax_enable_x64`` with each
target's *production input dtypes*: explicit 64-bit intent (``astype(int64)``,
default ``argmin`` index dtypes, promoting ``sum``\\ s) then surfaces as real
64-bit avals, while in the default x64-off mode the very same code silently
downcasts — which is exactly the hazard class the rule exists to catch.

Source-level rules (the 64-bit token scan and the host-sync lint) parse the
module ASTs instead: some hazards — a ``np.asarray`` device→host sync inside
a per-leaf loop — are invisible to a jaxpr but obvious in the source.

Allowlist: the solver entry points of ``core/solvers/jax_backend.py`` run
under ``enable_x64`` *by contract* (float64 cost matrices, bit-identity with
the NumPy oracles); their 64-bit findings are downgraded to NOTE with the
reason attached.  Flipping them to f32 is the ROADMAP real-accelerator item,
at which point the allowlist entries should be deleted and the auditor keeps
them honest.
"""

from __future__ import annotations

import ast
import dataclasses
import functools
import inspect
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from .findings import Finding, Report, Severity

S = jax.ShapeDtypeStruct

#: outputs at or above this many bytes must be aliased/donated when an input
#: of identical shape+dtype exists (rule audit.io-alias)
ALIAS_BYTES_THRESHOLD = 1 << 20

_BAD64 = ("int64", "float64", "uint64", "complex128")

#: {rule: {subject prefix: reason}} — matches are downgraded to NOTE
ALLOWLIST: Dict[str, Dict[str, str]] = {
    "audit.dtype64-source": {
        "repro.kernels.block_diff": (
            "hash_coefficients builds its table with host-side NumPy int64 "
            "RNG draws and bit-casts to int32 before any device upload; no "
            "64-bit value reaches a jaxpr (the jaxpr rule confirms)"
        ),
    },
}


# ------------------------------------------------------------------ targets
@dataclasses.dataclass(frozen=True)
class AuditTarget:
    """One traceable entry point: ``build()`` returns ``(fn, args)`` such
    that ``jax.make_jaxpr(fn)(*args)`` reproduces the production dispatch."""

    name: str                      # stable subject, e.g. "kernels.ops._compact"
    build: Callable[[], Tuple[Callable, Tuple[Any, ...]]]
    description: str = ""


def _kernel_targets() -> List[AuditTarget]:
    from ..kernels import block_diff, chain_apply, ops, segment_ops, \
        sparse_apply, xor_delta

    nb = 1024  # 4 MiB of 4 KiB blocks: big enough to trip the alias rule
    blocks = S((nb, 8, 128), jnp.int32)

    def t(name, build, description=""):
        return AuditTarget(name, build, description)

    return [
        t("kernels.segment_ops.segment_min_rows",
          lambda: (lambda x: segment_ops.segment_min_rows(x),
                   (S((256, 128), jnp.float32),)),
          "per-row min reduction (SSSP relaxation)"),
        t("kernels.segment_ops.segment_argmin_rows",
          lambda: (lambda x: segment_ops.segment_argmin_rows(x),
                   (S((256, 128), jnp.float32),)),
          "per-row first-argmin (parent selection)"),
        t("kernels.segment_ops.min_argmin_1d",
          lambda: (lambda x: segment_ops.min_argmin_1d(x),
                   (S((1000,), jnp.float32),)),
          "global (min, argmin) vertex pick"),
        t("kernels.segment_ops.min_argmin_1d[xla]",
          lambda: (lambda x: segment_ops.min_argmin_1d(x, use_pallas=False),
                   (S((1000,), jnp.float32),)),
          "XLA lowering of the same reduction (CPU fast path)"),
        t("kernels.xor_delta.xor_delta",
          lambda: (lambda a, b: xor_delta.xor_delta(a, b), (blocks, blocks)),
          "XOR delta encode/apply"),
        t("kernels.block_diff.changed_block_mask",
          lambda: (lambda a, b: block_diff.changed_block_mask(a, b),
                   (blocks, blocks)),
          "changed-block detection (delta encoder)"),
        t("kernels.block_diff.block_hash",
          lambda: (lambda x: block_diff.block_hash(
              x, jnp.asarray(block_diff.hash_coefficients())), (blocks,)),
          "per-block content hash (dedup hints)"),
        t("kernels.sparse_apply.sparse_delta_apply",
          lambda: (lambda b, p, i: sparse_apply.sparse_delta_apply(b, p, i),
                   (blocks, S((64, 8, 128), jnp.int32), S((64,), jnp.int32))),
          "block-sparse delta apply (one-hop recreation)"),
        t("kernels.chain_apply.chain_delta_apply",
          lambda: (lambda b, p, i: chain_apply.chain_delta_apply(b, p, i),
                   (blocks, S((4, 16, 8, 128), jnp.int32),
                    S((4, 16), jnp.int32))),
          "fused K-step chain apply (whole-chain checkout)"),
        t("kernels.chain_apply.chain_delta_apply_batched",
          lambda: (lambda b, p, i: chain_apply.chain_delta_apply_batched(
              b, p, i),
                   (S((4, 256, 8, 128), jnp.int32),
                    S((4, 16, 8, 128), jnp.int32), S((4, 16), jnp.int32))),
          "batched fused chain apply (many leaves, one launch)"),
        t("kernels.ops._compact",
          lambda: (functools.partial(ops._compact, capacity=16),
                   (S((256, 1), jnp.int32), S((256, 8, 128), jnp.int32))),
          "changed-block compaction (sparse encode)"),
        t("kernels.ops.to_blocks",
          lambda: (lambda x: ops.to_blocks(x)[0], (S((4096,), jnp.float32),)),
          "byte-preserving layout conversion (encode side)"),
    ]


def _solver_targets() -> List[AuditTarget]:
    from ..core.solvers import jax_backend as jb

    nvp, d = 16, 8
    ids = S((nvp, d), jnp.int32)
    w = S((nvp, d), jnp.float32)
    vec_i = S((nvp,), jnp.int32)
    vec_f = S((nvp,), jnp.float32)

    return [
        AuditTarget(
            "core.solvers.jax_backend._sssp_jit",
            lambda: (lambda ps, pw: jb._sssp_jit(ps, pw, True), (ids, w)),
            "jitted Bellman-Ford SSSP (Problem 2 / SPT)"),
        AuditTarget(
            "core.solvers.jax_backend._prim_jit",
            lambda: (lambda pd, pw, rd, rw, n: jb._prim_jit(
                pd, pw, rd, rw, n, True),
                (ids, w, vec_i, vec_f, S((), jnp.int32))),
            "jitted Prim (Problem 1, undirected)"),
        AuditTarget(
            "core.solvers.jax_backend._mp_jit",
            lambda: (lambda pd, pdl, pph, rd, rdl, rph, n, th: jb._mp_jit(
                pd, pdl, pph, rd, rdl, rph, n, th, True),
                (ids, w, w, vec_i, vec_f, vec_f, S((), jnp.int32),
                 S((), jnp.float32))),
            "jitted Modified Prim (Problems 4/6)"),
        AuditTarget(
            "core.solvers.jax_backend._lmg_score_jit",
            lambda: (lambda cu, cv, cd, cp, act, cur, dd, mm, ti, sz, wt, bu:
                     jb._lmg_score_jit(cu, cv, cd, cp, act, cur, dd, mm, ti,
                                       sz, wt, bu, True),
                (vec_i, vec_i, vec_f, vec_f, S((nvp,), jnp.bool_), vec_f,
                 vec_f, vec_f, vec_i, vec_i, S((), jnp.float32),
                 S((), jnp.float32))),
            "jitted LMG candidate scoring round (Problems 3/5)"),
    ]


def audit_targets() -> List[AuditTarget]:
    """Every registered kernel + jitted solver entry point, in audit order."""
    return _kernel_targets() + _solver_targets()


# ------------------------------------------------------------ jaxpr helpers
def trace_target(target: AuditTarget):
    """Abstractly trace a target under x64 (see module docstring)."""
    fn, args = target.build()
    with enable_x64():
        return jax.make_jaxpr(fn)(*args)


def iter_eqns(jaxpr) -> Iterable[Any]:
    """All equations of a jaxpr, descending into sub-jaxprs (pjit bodies,
    while/cond/scan branches, pallas kernel bodies)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_eqns(inner)
                elif hasattr(sub, "eqns"):
                    yield from iter_eqns(sub)


def _aval_of(var):
    aval = getattr(var, "aval", None)
    return aval if aval is not None and hasattr(aval, "dtype") else None


def _find_pallas_calls(jaxpr) -> List[Any]:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def _allowlisted(rule: str, subject: str) -> Optional[str]:
    for prefix, reason in ALLOWLIST.get(rule, {}).items():
        if subject.startswith(prefix):
            return reason
    return None


def _emit(report: Report, rule: str, severity: Severity, subject: str,
          message: str, fix_hint: str = "") -> None:
    """Add a finding, downgrading allowlisted subjects to NOTE."""
    reason = _allowlisted(rule, subject)
    if reason is not None:
        severity = Severity.NOTE
        message = f"{message} [allowlisted: {reason}]"
    report.add(Finding(rule, severity, subject, message, fix_hint))


# ------------------------------------------------------- rule: audit.dtype64
def check_dtype64(report: Report, target: AuditTarget, jaxpr) -> None:
    """No non-weak 64-bit avals in the traced jaxpr (TPU has no f64/i64).

    Weak-typed scalars (Python int/float literals) are exempt: they trace as
    64-bit under x64 but lower to the operand dtype — only *committed* 64-bit
    values (explicit ``astype``, default argmin index dtypes, promoting
    reductions) produce non-weak 64-bit outputs.
    """
    report.bump("audit.dtype64")
    hits: Dict[str, int] = {}
    tops = list(jaxpr.jaxpr.invars) + list(jaxpr.jaxpr.outvars)
    for var in tops:
        aval = _aval_of(var)
        if aval is None or getattr(aval, "weak_type", False):
            continue
        if str(aval.dtype) in _BAD64:
            hits[f"boundary:{aval.dtype}"] = (
                hits.get(f"boundary:{aval.dtype}", 0) + 1
            )
    for eqn in iter_eqns(jaxpr.jaxpr):
        for var in eqn.outvars:
            aval = _aval_of(var)
            if aval is None or getattr(aval, "weak_type", False):
                continue
            if str(aval.dtype) in _BAD64:
                k = f"{eqn.primitive.name}:{aval.dtype}"
                hits[k] = hits.get(k, 0) + 1
    if hits:
        detail = ", ".join(f"{k} x{n}" for k, n in sorted(hits.items()))
        _emit(
            report, "audit.dtype64", Severity.ERROR, target.name,
            f"64-bit values in traced jaxpr: {detail}",
            "use explicit 32-bit dtypes (lax.argmin(..., jnp.int32), "
            "jnp.sum(..., dtype=jnp.int32), .astype(jnp.int32)); TPUs have "
            "no 64-bit lanes and x64-off mode silently downcasts",
        )


# ------------------------------------------------ rule: audit.dtype64-source
#: modules scanned for 64-bit dtype tokens (AST attributes, so docstrings
#: and comments do not count)
DTYPE_SOURCE_MODULES = (
    "repro.kernels.segment_ops",
    "repro.kernels.ops",
    "repro.kernels.chain_apply",
    "repro.kernels.sparse_apply",
    "repro.kernels.block_diff",
    "repro.kernels.xor_delta",
    "repro.store.delta",
    "repro.store.materializer",
    "repro.core.solvers.jax_backend",
)


def check_dtype64_source(report: Report, module_name: str) -> None:
    """No ``jnp.int64`` / ``np.float64`` / … attribute tokens in the module.

    Complements the jaxpr rule: an ``astype(jnp.int64)`` in untraced host
    code (or a path the example args miss) still shows up here.
    """
    import importlib

    report.bump("audit.dtype64-source")
    mod = importlib.import_module(module_name)
    tree = ast.parse(inspect.getsource(mod))
    lines: List[int] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in _BAD64:
            lines.append(node.lineno)
    if lines:
        _emit(
            report, "audit.dtype64-source", Severity.ERROR, module_name,
            f"{len(lines)} 64-bit dtype reference(s) at line(s) "
            f"{sorted(set(lines))}",
            "replace with 32-bit dtypes or guard capacities host-side "
            "(see kernels/segment_ops.py MAX_INT32_ELEMS)",
        )


# ---------------------------------------------------- rule: audit.host-sync
#: {module: function qualnames} forming the materializer decode hot path;
#: a device→host call *inside a loop* there serializes one sync per leaf
HOT_PATH_FUNCTIONS: Dict[str, Tuple[str, ...]] = {
    "repro.store.delta": ("apply_delta", "apply_delta_chains"),
    "repro.store.materializer": (
        "Materializer._execute_fused",
        "Materializer._execute_stepwise",
        "Materializer._materialize_chain",
    ),
}

_SYNC_ATTRS = ("item", "block_until_ready", "device_get")
_NP_SYNC_FUNCS = ("asarray", "array")


def _qualnames(tree: ast.Module):
    """Yield (qualname, FunctionDef) for every function, class-aware."""
    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
    yield from walk(tree, "")


def _sync_calls_in_loops(fn: ast.AST) -> List[Tuple[int, str]]:
    """(line, callname) for device→host sync calls inside for/while loops."""
    hits: List[Tuple[int, str]] = []

    def scan(node, in_loop):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # nested function: separate scope, lint separately
            entering = in_loop or isinstance(child, (ast.For, ast.While))
            if isinstance(child, ast.Call) and in_loop:
                f = child.func
                if isinstance(f, ast.Attribute):
                    base = f.value
                    if (f.attr in _NP_SYNC_FUNCS
                            and isinstance(base, ast.Name)
                            and base.id in ("np", "numpy")):
                        hits.append((child.lineno, f"np.{f.attr}"))
                    elif f.attr in _SYNC_ATTRS:
                        hits.append((child.lineno, f".{f.attr}"))
            scan(child, entering)

    scan(fn, False)
    return hits


def check_host_sync(report: Report, module_name: str,
                    functions: Sequence[str]) -> None:
    """No per-leaf device→host syncs in the decode hot path.

    Syntactic by design: ``np.asarray`` on a host array is free, but the
    listed functions handle device-resident blocked leaves, where it blocks
    on the device stream once per call.  Batch the transfers instead —
    collect device results and fetch them with one ``jax.device_get`` after
    the loop (one transfer per request group).
    """
    import importlib

    mod = importlib.import_module(module_name)
    tree = ast.parse(inspect.getsource(mod))
    found = dict(_qualnames(tree))
    for qual in functions:
        report.bump("audit.host-sync")
        fn = found.get(qual)
        subject = f"{module_name}.{qual}"
        if fn is None:
            _emit(report, "audit.host-sync", Severity.WARNING, subject,
                  "hot-path function missing from module (rule config is "
                  "stale)",
                  "update HOT_PATH_FUNCTIONS in repro/analysis/kernel_audit.py")
            continue
        hits = _sync_calls_in_loops(fn)
        if hits:
            detail = ", ".join(f"{name}@L{line}" for line, name in hits)
            _emit(
                report, "audit.host-sync", Severity.ERROR, subject,
                f"device→host sync inside per-leaf loop: {detail}",
                "accumulate device results and fetch once with "
                "jax.device_get after the loop (one batched transfer per "
                "request group)",
            )


# -------------------------------------------------- rule: audit.shape-bucket
@dataclasses.dataclass(frozen=True)
class BucketContract:
    """A size-bucketing function and the contract its callers rely on."""

    name: str
    fn: Callable[[int], int]
    kind: str                  # "pow2" | "mult8"
    max_check: int = 4096


def bucket_contracts() -> List[BucketContract]:
    from ..core.solvers import jax_backend as jb
    from ..kernels import ops
    from ..store import delta

    return [
        BucketContract("store.delta._slot_bucket", delta._slot_bucket, "pow2"),
        BucketContract("kernels.ops._round_capacity", ops._round_capacity,
                       "pow2"),
        BucketContract("core.solvers.jax_backend._bucket_rows",
                       jb._bucket_rows, "pow2"),
        BucketContract("core.solvers.jax_backend._bucket_width",
                       jb._bucket_width, "mult8"),
    ]


def check_bucket_contract(report: Report, c: BucketContract) -> None:
    """Bucket functions must cover (f(k) >= k), quantize (pow2 / mult-of-8),
    be idempotent (f(f(k)) == f(k)) and monotone — the conditions under which
    jit caches are shared and recompiles stay O(log max_size)."""
    report.bump("audit.shape-bucket")
    problems: List[str] = []
    prev = 0
    for k in range(1, c.max_check + 1):
        b = c.fn(k)
        if b < k:
            problems.append(f"f({k})={b} < k (dropped data)")
        if c.kind == "pow2" and b & (b - 1):
            problems.append(f"f({k})={b} not a power of two")
        if c.kind == "mult8" and b % 8:
            problems.append(f"f({k})={b} not a multiple of 8")
        if c.fn(b) != b:
            problems.append(f"f(f({k}))={c.fn(b)} != f({k})={b} (not "
                            f"idempotent)")
        if b < prev:
            problems.append(f"f({k})={b} < f({k-1})={prev} (not monotone)")
        prev = b
        if len(problems) >= 4:
            break
    if problems:
        _emit(
            report, "audit.shape-bucket", Severity.ERROR, c.name,
            f"bucket contract violated: {'; '.join(problems[:4])}",
            "jit signatures derived from this bucket will fragment the "
            "compile cache (or drop data); restore pow2/mult8 rounding",
        )


@dataclasses.dataclass(frozen=True)
class BucketProbe:
    """Sizes in the same bucket must trace to identical jit signatures."""

    name: str
    trace: Callable[[int], str]    # size -> canonical signature string
    sizes: Tuple[int, ...]         # all mapping to one bucket


def _signature(jaxpr) -> str:
    """Canonical kernel-shape signature: the in/out avals of every pallas
    call in the trace.  Top-level invars are deliberately excluded — the
    *outer* jit is always keyed on the raw input shape; the bucket contract
    is that the padded shapes reaching the kernels (and hence the Pallas
    compile cache) coincide for same-bucket sizes."""
    pcs = ";".join(
        ",".join(str(_aval_of(v)) for v in e.invars)
        + "->" + ",".join(str(_aval_of(v)) for v in e.outvars)
        for e in _find_pallas_calls(jaxpr.jaxpr)
    )
    return f"pallas[{pcs}]"


def bucket_probes() -> List[BucketProbe]:
    from ..kernels import ops, segment_ops
    from ..store import delta

    def probe_min_argmin(n: int) -> str:
        return _signature(jax.make_jaxpr(
            lambda x: segment_ops.min_argmin_1d(x)
        )(S((n,), jnp.float32)))

    def probe_compact(n_changed: int) -> str:
        cap = ops._round_capacity(n_changed)
        return _signature(jax.make_jaxpr(
            functools.partial(ops._compact, capacity=cap)
        )(S((64, 1), jnp.int32), S((64, 8, 128), jnp.int32)))

    def probe_chain(total_slots: int) -> str:
        cap = delta._slot_bucket(total_slots)
        return _signature(jax.make_jaxpr(
            lambda b, p, i: ops.chain_apply(b, p, i)
        )(S((8, 8, 128), jnp.int32), S((cap, 8, 128), jnp.int32),
          S((cap,), jnp.int32)))

    return [
        BucketProbe("kernels.segment_ops.min_argmin_1d/pad_to_rows",
                    probe_min_argmin, (27, 100, 128)),
        BucketProbe("kernels.ops.sparse_encode/_round_capacity",
                    probe_compact, (5, 6, 8)),
        BucketProbe("store.delta.apply_delta_chains/_slot_bucket",
                    probe_chain, (3, 5, 8)),
    ]


def check_bucket_probe(report: Report, p: BucketProbe) -> None:
    report.bump("audit.shape-bucket")
    sigs = {}
    with enable_x64():
        for n in p.sizes:
            sigs.setdefault(p.trace(n), []).append(n)
    if len(sigs) > 1:
        detail = "; ".join(f"sizes {v} -> {k[:80]}" for k, v in sigs.items())
        _emit(
            report, "audit.shape-bucket", Severity.ERROR, p.name,
            f"same-bucket sizes trace to different jit signatures: {detail}",
            "route the size through the bucket function before shaping "
            "device arrays so the jit cache is shared",
        )


# ------------------------------------------------------ rule: audit.io-alias
def check_io_alias(report: Report, target: AuditTarget, jaxpr) -> None:
    """Pallas calls writing a large output that matches an input's
    shape+dtype must alias it (``input_output_aliases``): without donation
    the dispatch allocates a second full-size HBM buffer and pays an extra
    copy — on the checkout hot path that is pure waste."""
    report.bump("audit.io-alias")
    for ei, eqn in enumerate(_find_pallas_calls(jaxpr.jaxpr)):
        aliases = tuple(eqn.params.get("input_output_aliases") or ())
        aliased_outs = {pair[1] for pair in aliases}
        in_avals = [_aval_of(v) for v in eqn.invars]
        for oi, var in enumerate(eqn.outvars):
            aval = _aval_of(var)
            if aval is None:
                continue
            nbytes = int(np.prod(aval.shape)) * aval.dtype.itemsize
            if nbytes < ALIAS_BYTES_THRESHOLD:
                continue
            match = any(
                ia is not None
                and ia.shape == aval.shape and ia.dtype == aval.dtype
                for ia in in_avals
            )
            if match and oi not in aliased_outs:
                _emit(
                    report, "audit.io-alias", Severity.WARNING, target.name,
                    f"pallas_call #{ei} output {oi} "
                    f"({aval.dtype}{list(aval.shape)}, {nbytes >> 20} MiB) "
                    f"matches an input but is not aliased",
                    "pass input_output_aliases={in_idx: out_idx} to "
                    "pl.pallas_call so the buffer is updated in place",
                )


# ------------------------------------------------------------------- driver
def run_audit() -> Report:
    """Run the full rule catalog; returns a :class:`Report`.

    Trace failures are findings too (``audit.trace``): a kernel that stops
    tracing abstractly would silently drop out of every jaxpr rule.
    """
    report = Report(tool="audit")
    for target in audit_targets():
        report.bump("audit.trace")
        try:
            jaxpr = trace_target(target)
        except Exception as e:  # pragma: no cover - defensive
            report.add(Finding(
                "audit.trace", Severity.ERROR, target.name,
                f"abstract trace failed: {type(e).__name__}: {e}",
                "fix the target or its registry entry in "
                "repro/analysis/kernel_audit.py",
            ))
            continue
        check_dtype64(report, target, jaxpr)
        check_io_alias(report, target, jaxpr)
    for module in DTYPE_SOURCE_MODULES:
        check_dtype64_source(report, module)
    for module, functions in HOT_PATH_FUNCTIONS.items():
        check_host_sync(report, module, functions)
    for contract in bucket_contracts():
        check_bucket_contract(report, contract)
    for probe in bucket_probes():
        check_bucket_probe(report, probe)
    return report
