"""Finding / baseline framework shared by the kernel auditor and fsck.

A :class:`Finding` is one typed violation: a rule id, a severity, a *stable*
subject (the thing that is wrong — an audit target, a module, a version id, a
ref name; never a line number, which churns), a human message, and a fix
hint.  ``Finding.key()`` — ``"rule::subject"`` — is the identity the baseline
file stores, so a finding stays recognized across unrelated edits to the
same file.

Baselines make the analyses usable as a CI *regression* gate: a committed
``analysis_baseline.json`` records the findings a repo has accepted (with a
note per entry), and :func:`partition` splits a fresh run into ``new`` (fail
the build) vs ``baselined`` (known, tolerated).  Only findings at WARNING or
above gate; NOTE-level findings (e.g. allowlisted 64-bit solver math) are
informational and never need baselining.

Workflow::

    report = run_audit()                      # or fsck_store(store)
    new, old = partition(report.findings, load_baseline(path))
    if new: fail CI, printing each finding + fix hint
    # to accept a finding deliberately:
    write_baseline(report.findings, path)     # then commit the file
"""

from __future__ import annotations

import dataclasses
import enum
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union


class Severity(enum.IntEnum):
    """How bad a finding is; only WARNING and above gate CI."""

    NOTE = 10      # informational / allowlisted — never gates
    WARNING = 20   # should be fixed; gates unless baselined
    ERROR = 30     # correctness hazard; gates unless baselined

    def __str__(self) -> str:  # "error" in reports and JSON
        return self.name.lower()


#: findings at or above this severity gate CI (NOTE never does)
GATE_SEVERITY = Severity.WARNING


@dataclasses.dataclass(frozen=True)
class Finding:
    """One typed analysis violation."""

    rule: str        # e.g. "audit.dtype64" / "fsck.cycle"
    severity: Severity
    subject: str     # stable id: target/module/vid/ref — never a line number
    message: str
    fix_hint: str = ""

    def key(self) -> str:
        """Baseline identity: stable across unrelated edits."""
        return f"{self.rule}::{self.subject}"

    def gates(self) -> bool:
        return self.severity >= GATE_SEVERITY

    def to_dict(self) -> Dict[str, str]:
        return {
            "rule": self.rule,
            "severity": str(self.severity),
            "subject": self.subject,
            "message": self.message,
            "fix_hint": self.fix_hint,
        }

    def render(self) -> str:
        hint = f"\n      fix: {self.fix_hint}" if self.fix_hint else ""
        return (
            f"[{str(self.severity).upper():7s}] {self.rule}  {self.subject}\n"
            f"      {self.message}{hint}"
        )


@dataclasses.dataclass
class Report:
    """The result of one analysis pass: findings + what was covered.

    ``checked`` counts subjects examined per rule (so "0 findings" is
    distinguishable from "0 targets") — an auditor that silently traced
    nothing would otherwise read as a clean bill of health.
    """

    tool: str                       # "audit" | "fsck"
    findings: List[Finding] = dataclasses.field(default_factory=list)
    checked: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def bump(self, rule: str, n: int = 1) -> None:
        self.checked[rule] = self.checked.get(rule, 0) + n

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def gating(self) -> List[Finding]:
        return [f for f in self.findings if f.gates()]

    def render(self, *, baseline: Optional[Dict[str, dict]] = None) -> str:
        new, old = partition(self.findings, baseline or {})
        lines = [
            f"repro.analysis {self.tool}: "
            f"{sum(self.checked.values())} checks over "
            f"{len(self.checked)} rules — "
            f"{len(new)} new finding(s), {len(old)} baselined, "
            f"{len(self.findings) - len(new) - len(old)} note(s)"
        ]
        for f in self.findings:
            tag = ""
            if f in old:
                tag = "  (baselined)"
            lines.append(f.render() + tag)
        return "\n".join(lines)


# ----------------------------------------------------------------- baseline
BASELINE_VERSION = 1


def load_baseline(path: Union[str, Path, None]) -> Dict[str, dict]:
    """Load a baseline file -> {finding key: entry}; missing file = empty."""
    if path is None:
        return {}
    p = Path(path)
    if not p.exists():
        return {}
    obj = json.loads(p.read_text())
    if obj.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"unsupported baseline version {obj.get('version')!r} in {p} "
            f"(expected {BASELINE_VERSION})"
        )
    return dict(obj.get("findings", {}))


def write_baseline(
    findings: Sequence[Finding], path: Union[str, Path], *, note: str = ""
) -> int:
    """Write the gating findings as the new accepted baseline; returns count.

    Only gating findings are recorded — NOTE-level entries never need
    accepting.  Entries keep the message at time of acceptance so a later
    reader knows what was tolerated and why.
    """
    entries = {
        f.key(): {
            "severity": str(f.severity),
            "message": f.message,
            "note": note,
        }
        for f in findings
        if f.gates()
    }
    blob = json.dumps(
        {"version": BASELINE_VERSION, "findings": entries},
        indent=2,
        sort_keys=True,
    )
    Path(path).write_text(blob + "\n")
    return len(entries)


def partition(
    findings: Iterable[Finding], baseline: Dict[str, dict]
) -> Tuple[List[Finding], List[Finding]]:
    """Split gating findings into (new, baselined).

    NOTE-level findings appear in neither list — they are informational.
    A baselined entry whose severity *increased* since acceptance counts as
    new again (an accepted WARNING that became an ERROR must re-gate).
    """
    new: List[Finding] = []
    old: List[Finding] = []
    order = {"note": Severity.NOTE, "warning": Severity.WARNING,
             "error": Severity.ERROR}
    for f in findings:
        if not f.gates():
            continue
        ent = baseline.get(f.key())
        if ent is not None and order.get(ent.get("severity", "note"),
                                        Severity.NOTE) >= f.severity:
            old.append(f)
        else:
            new.append(f)
    return new, old
