"""Command-line front-end: ``python -m repro.obs {summary,trace,convert,prom,overhead}``.

* ``summary`` — per-span-name rollup table (count / total / mean / max) plus
  the live tradeoff snapshot; ``--synthetic`` builds a throwaway store and
  drives real service traffic through an enabled tracer first, so the
  command is a self-contained end-to-end exercise of the whole
  instrumentation path (the CI smoke step).  ``--trace-out`` additionally
  writes a Perfetto-loadable Chrome trace (validated before exit — a
  structurally broken export fails the command), ``--prom`` writes the
  Prometheus text exposition.
* ``trace OUT`` — synthetic exercise, write only the Chrome trace.
* ``convert IN OUT`` — spans JSONL (``dump_spans_jsonl`` format) → Chrome
  trace JSON, validated.
* ``prom`` — synthetic exercise, print the Prometheus exposition.
* ``overhead`` — the disabled-tracer overhead gate: measures warm-checkout
  latency, counts instrumentation points hit per warm checkout, measures
  the per-call cost of a disabled ``span()``, and fails (exit 1) if the
  projected overhead exceeds ``--budget-pct`` (default 2%).  Projection,
  not A/B timing: ``points × per_call_cost / warm_latency`` is robust to
  noisy CI runners, where two back-to-back timings of the same code easily
  differ by more than the budget.

Exit status: 0 = ok, 1 = gate/validation failure, 2 = usage error.
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

from .export import (
    chrome_trace,
    dump_spans_jsonl,
    load_spans_jsonl,
    prometheus_text,
    validate_chrome_trace,
)
from .tracer import Tracer, get_tracer, set_tracer, span as _span


# -- synthetic self-exercise -------------------------------------------------
def _synthetic_traffic(root: str) -> Tuple[Tracer, Dict[str, Any]]:
    """Build a small store and drive real service traffic under an enabled
    tracer: commits, cold + warm + coalesced checkouts, a constrained
    repack, and an fsck sweep — every instrumented layer fires at least
    once.  Returns (tracer, service stats snapshot)."""
    import numpy as np

    from ..analysis.cli import build_synthetic_store
    from ..core import OptimizeSpec
    from ..service.service import DatasetService

    repo = build_synthetic_store(root)

    async def drive() -> Dict[str, Any]:
        rng = np.random.RandomState(1)
        async with DatasetService(
            repo, readers=2, batch_window_s=0.001
        ) as svc:
            vids = sorted(repo.store.versions)
            await svc.checkout_many(vids)          # cold batch
            await svc.checkout_many(vids[:3] * 2)  # warm + coalesced
            tree = dict(await svc.checkout())
            tree["w"] = tree["w"] + rng.randn(*tree["w"].shape).astype(
                tree["w"].dtype
            )
            await svc.commit(tree, message="synthetic update")
            await svc.checkout()                   # post-commit warm path
            await svc.repack(OptimizeSpec.problem(6, theta=10.0))
            await svc.checkout_many(vids[:2])
            await svc.fsck()
            return svc.stats()

    tracer = Tracer(enabled=True)
    old = set_tracer(tracer)
    try:
        stats = asyncio.run(drive())
    finally:
        set_tracer(old)
    return tracer, stats


def _exercise(args: argparse.Namespace) -> Tuple[Tracer, Dict[str, Any]]:
    with tempfile.TemporaryDirectory() as td:
        return _synthetic_traffic(td)


# -- rendering ---------------------------------------------------------------
def _summary_table(tracer: Tracer) -> str:
    rows = sorted(
        tracer.summary().items(), key=lambda kv: -kv[1]["total_s"]
    )
    name_w = max([len(n) for n, _ in rows] + [len("span")])
    lines = [
        f"{'span':<{name_w}}  {'count':>6}  {'total_ms':>10}  "
        f"{'mean_ms':>9}  {'max_ms':>9}"
    ]
    for name, d in rows:
        lines.append(
            f"{name:<{name_w}}  {int(d['count']):>6}  "
            f"{d['total_s'] * 1e3:>10.3f}  {d['mean_s'] * 1e3:>9.3f}  "
            f"{d['max_s'] * 1e3:>9.3f}"
        )
    return "\n".join(lines)


def _tradeoff_block(stats: Dict[str, Any]) -> str:
    trade = stats.get("tradeoff") or {}
    latest = trade.get("latest")
    if not latest:
        return "tradeoff: no samples"
    lines = [
        "tradeoff (latest sample, event=%s):" % latest["event"],
        f"  versions={latest['versions']}  "
        f"storage={latest['storage_bytes_total']}B "
        f"(full={latest['storage_bytes_full']}B x{latest['full_objects']}, "
        f"delta={latest['storage_bytes_delta']}B x{latest['delta_objects']})",
        f"  recreation_s p50={latest['recreation_p50_s']:.4g} "
        f"p99={latest['recreation_p99_s']:.4g} "
        f"max={latest['recreation_max_s']:.4g}  "
        f"access_weighted_sum={latest['access_weighted_recreation_s']:.4g}  "
        f"max_chain_depth={latest['max_chain_depth']}",
    ]
    drift = trade.get("drift")
    if drift and drift.get("access_weighted_recreation_ratio") is not None:
        lines.append(
            f"  drift vs {drift['baseline_event']} baseline: "
            f"storage {drift['storage_ratio']:.3f}x, "
            f"access-weighted R "
            f"{drift['access_weighted_recreation_ratio']:.3f}x "
            f"(+{drift['versions_added']} versions)"
        )
    return "\n".join(lines)


def _write_trace(tracer: Tracer, path: str) -> int:
    chrome_trace(tracer, path)
    problems = validate_chrome_trace(path)
    if problems:
        print(f"trace validation FAILED for {path}:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"wrote {path} ({len(tracer)} spans, Perfetto-loadable)")
    return 0


# -- commands ----------------------------------------------------------------
def _cmd_summary(args: argparse.Namespace) -> int:
    if not args.synthetic:
        print(
            "summary: only --synthetic mode is available from the CLI (a "
            "live tracer exists only inside the traced process; export one "
            "with dump_spans_jsonl and use 'convert')",
            file=sys.stderr,
        )
        return 2
    tracer, stats = _exercise(args)
    if args.json:
        print(json.dumps({
            "spans": tracer.summary(),
            "tradeoff": stats.get("tradeoff"),
            "counters": stats.get("counters"),
        }, indent=2, sort_keys=True))
    else:
        print(_summary_table(tracer))
        print()
        print(_tradeoff_block(stats))
    rc = 0
    if args.trace_out:
        rc = max(rc, _write_trace(tracer, args.trace_out))
    if args.prom:
        with open(args.prom, "w") as f:
            f.write(prometheus_text(stats))
        print(f"wrote {args.prom}")
    return rc


def _cmd_trace(args: argparse.Namespace) -> int:
    tracer, _ = _exercise(args)
    if args.spans_out:
        n = dump_spans_jsonl(tracer, args.spans_out)
        print(f"wrote {args.spans_out} ({n} spans, JSONL)")
    return _write_trace(tracer, args.out)


def _cmd_convert(args: argparse.Namespace) -> int:
    rows = load_spans_jsonl(args.inp)
    chrome_trace(rows, args.out)
    problems = validate_chrome_trace(args.out)
    if problems:
        print(f"convert: output failed validation:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"converted {len(rows)} spans -> {args.out}")
    return 0


def _cmd_prom(args: argparse.Namespace) -> int:
    _, stats = _exercise(args)
    sys.stdout.write(prometheus_text(stats))
    return 0


def _cmd_overhead(args: argparse.Namespace) -> int:
    """Disabled-tracer overhead gate (see module docstring)."""
    import numpy as np

    from ..store.version_store import VersionStore

    assert not get_tracer().enabled, "overhead gate needs tracing disabled"
    with tempfile.TemporaryDirectory() as td:
        store = VersionStore(td, access_flush_every=10**9)
        rng = np.random.RandomState(0)
        tree = {"w": rng.randn(128, 128).astype(np.float32)}
        vid = store.commit(tree, message="base")
        for i in range(args.chain):
            t = dict(tree)
            w = t["w"].copy()
            w[i % 128, :8] += 1.0
            t["w"] = w
            tree = t
            vid = store.commit(tree, parents=[vid], message=f"step {i}")

        store.checkout(vid)  # warm the cache
        # 1) warm-checkout latency (the protected quantity)
        reps = args.reps
        t0 = time.perf_counter()
        for _ in range(reps):
            store.checkout(vid)
        warm_s = (time.perf_counter() - t0) / reps

        # 2) instrumentation points hit per warm checkout
        probe = Tracer(enabled=True)
        old = set_tracer(probe)
        try:
            store.checkout(vid)
        finally:
            set_tracer(old)
        points = len(probe)

        # 3) per-call cost of a disabled span()
        calls = 200_000
        t0 = time.perf_counter()
        for _ in range(calls):
            _span("overhead.probe")
        per_call_s = (time.perf_counter() - t0) / calls

    overhead_pct = 100.0 * points * per_call_s / warm_s if warm_s > 0 else 0.0
    out = {
        "warm_checkout_us": round(warm_s * 1e6, 3),
        "instrumentation_points": points,
        "disabled_span_ns": round(per_call_s * 1e9, 3),
        "overhead_pct": round(overhead_pct, 5),
        "budget_pct": args.budget_pct,
    }
    print(json.dumps(out, indent=2))
    if overhead_pct > args.budget_pct:
        print(
            f"overhead gate FAILED: {overhead_pct:.4f}% > "
            f"{args.budget_pct}% budget",
            file=sys.stderr,
        )
        return 1
    print(
        f"overhead gate ok: {points} points x {per_call_s * 1e9:.0f}ns "
        f"= {overhead_pct:.4f}% of a {warm_s * 1e6:.0f}us warm checkout "
        f"(budget {args.budget_pct}%)"
    )
    return 0


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Observability tooling: span-trace summaries, Chrome "
                    "trace / Prometheus exports, and the disabled-tracer "
                    "overhead gate.",
    )
    sub = p.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary", help="per-span rollup + tradeoff snapshot")
    s.set_defaults(fn=_cmd_summary)
    s.add_argument("--synthetic", action="store_true",
                   help="build a throwaway store and drive traced service "
                        "traffic (the CI self-exercise)")
    s.add_argument("--trace-out", default=None, metavar="PATH",
                   help="also write a validated Chrome trace JSON")
    s.add_argument("--prom", default=None, metavar="PATH",
                   help="also write the Prometheus text exposition")
    s.add_argument("--json", action="store_true",
                   help="machine-readable summary on stdout")

    s = sub.add_parser("trace", help="synthetic exercise -> Chrome trace")
    s.set_defaults(fn=_cmd_trace, synthetic=True)
    s.add_argument("out", help="Chrome trace JSON output path")
    s.add_argument("--spans-out", default=None, metavar="PATH",
                   help="also dump raw spans as JSONL ('convert' input)")

    s = sub.add_parser("convert", help="spans JSONL -> Chrome trace JSON")
    s.set_defaults(fn=_cmd_convert)
    s.add_argument("inp", help="spans JSONL (dump_spans_jsonl format)")
    s.add_argument("out", help="Chrome trace JSON output path")

    s = sub.add_parser("prom", help="synthetic exercise -> Prometheus text")
    s.set_defaults(fn=_cmd_prom, synthetic=True)

    s = sub.add_parser("overhead",
                       help="fail if disabled-tracer overhead exceeds budget")
    s.set_defaults(fn=_cmd_overhead)
    s.add_argument("--budget-pct", type=float, default=2.0,
                   help="max projected overhead, %% of warm-checkout latency "
                        "(default 2.0)")
    s.add_argument("--chain", type=int, default=16,
                   help="delta-chain length of the microbench store")
    s.add_argument("--reps", type=int, default=200,
                   help="warm-checkout timing repetitions")

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":  # pragma: no cover - module entry
    sys.exit(main())
