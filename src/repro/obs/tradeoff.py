"""Live storage/recreation-tradeoff telemetry over a ``VersionStore``.

The paper's central quantity is the (C, R) tradeoff: total storage cost C
against recreation cost R per version.  Offline, the benchmarks measure it;
this module makes it *live state*: a :class:`TradeoffMonitor` attached to a
store samples both sides of the tradeoff on every commit and repack —

* **storage side** — bytes at rest split by encoding (full objects vs
  deltas), object counts;
* **recreation side** — per-version modelled recreation cost Φ along the
  current storage chains: p50/p99/max percentiles, the plain sum (Problem 2
  objective), and the **access-weighted recreation sum** Σ w_v·R(v) with the
  store's Laplace-smoothed access weights — the Problem 5/6 objective the
  workload-aware repacks optimize.

Samples keep to a bounded history (deque), and the sample taken right after
a ``repack`` becomes the **baseline**: :meth:`drift` compares the latest
sample against it, so the service tier's :class:`FsckSweeper` can report
*quantitative* drift — "access-weighted R is 2.3× the post-repack
baseline" — instead of only flagging that a constraint broke.  Before any
repack, the baseline is the sample taken at attach time (labelled
``start``).

Sampling is O(n) in the version count (one memoized pass computes every
chain cost), runs under the store lock for a consistent snapshot, and
happens on the committing/repacking thread — attach a monitor where commits
are service-scale events (the ``DatasetService`` does this by default), not
inside tight store-building loops.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Deque, Dict, List, Optional

__all__ = ["TradeoffMonitor", "TradeoffSample"]


@dataclasses.dataclass(frozen=True)
class TradeoffSample:
    """One point-in-time measurement of both sides of the (C, R) tradeoff."""

    timestamp: float
    event: str                          # "start" | "commit" | "repack" | "sample"
    versions: int
    full_objects: int
    delta_objects: int
    storage_bytes_full: int
    storage_bytes_delta: int
    recreation_p50_s: float
    recreation_p99_s: float
    recreation_max_s: float
    recreation_sum_s: float
    access_weighted_recreation_s: float
    max_chain_depth: int

    @property
    def storage_bytes_total(self) -> int:
        return self.storage_bytes_full + self.storage_bytes_delta

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["storage_bytes_total"] = self.storage_bytes_total
        return d


class TradeoffMonitor:
    """Bounded-history (C, R) sampler bound to one store (see module docs).

    The store calls :meth:`on_commit` / :meth:`on_repack` when a monitor is
    attached (``store.tradeoff_monitor``); anything else may call
    :meth:`sample` ad hoc.  Thread-safe: samples are taken under the store
    lock, history mutation under the monitor's own lock.
    """

    def __init__(self, store: Any, *, capacity: int = 512) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.store = store
        self._lock = threading.Lock()
        self.history: "Deque[TradeoffSample]" = collections.deque(
            maxlen=int(capacity)
        )
        self.baseline: Optional[TradeoffSample] = None

    # -- measurement -------------------------------------------------------
    def _chain_costs(self) -> Dict[int, float]:
        """Recreation cost Φ per version, one memoized O(n) pass (caller
        holds the store lock)."""
        versions = self.store.versions
        costs: Dict[int, float] = {}
        for vid in versions:
            chain: List[int] = []
            v: Optional[int] = vid
            while v is not None and v not in costs:
                chain.append(v)
                v = versions[v].stored_base
                if len(chain) > len(versions):
                    raise RuntimeError("storage graph cycle")
            acc = 0.0 if v is None else costs[v]
            for u in reversed(chain):
                acc += versions[u].phi
                costs[u] = acc
        return costs

    def _chain_depths(self) -> int:
        versions = self.store.versions
        depth: Dict[int, int] = {}
        for vid in versions:
            chain: List[int] = []
            v: Optional[int] = vid
            while v is not None and v not in depth:
                chain.append(v)
                v = versions[v].stored_base
            for u in reversed(chain):
                b = versions[u].stored_base
                depth[u] = 0 if b is None else depth[b] + 1
        return max(depth.values(), default=0)

    def sample(self, event: str = "sample") -> TradeoffSample:
        """Measure now, append to history, and return the sample."""
        from ..service.metrics import percentile  # local: leaf-only import

        store = self.store
        with store._lock:
            versions = store.versions
            full_b = delta_b = full_n = delta_n = 0
            for m in versions.values():
                if m.stored_base is None:
                    full_b += m.stored_bytes
                    full_n += 1
                else:
                    delta_b += m.stored_bytes
                    delta_n += 1
            if versions:
                costs = self._chain_costs()
                xs = list(costs.values())
                weights = store.access_weights()
                awr = sum(weights[v] * costs[v] for v in costs)
                p50 = percentile(xs, 50)
                p99 = percentile(xs, 99)
                mx = max(xs)
                total = sum(xs)
                depth = self._chain_depths()
            else:
                awr = p50 = p99 = mx = total = 0.0
                depth = 0
        s = TradeoffSample(
            timestamp=time.time(),
            event=event,
            versions=len(versions),
            full_objects=full_n,
            delta_objects=delta_n,
            storage_bytes_full=full_b,
            storage_bytes_delta=delta_b,
            recreation_p50_s=p50,
            recreation_p99_s=p99,
            recreation_max_s=mx,
            recreation_sum_s=total,
            access_weighted_recreation_s=awr,
            max_chain_depth=depth,
        )
        with self._lock:
            self.history.append(s)
            if self.baseline is None:
                self.baseline = dataclasses.replace(s, event="start")
        return s

    # -- store hooks -------------------------------------------------------
    def on_commit(self, vid: int) -> TradeoffSample:
        return self.sample("commit")

    def on_repack(self, stats: Optional[Dict[str, Any]] = None) -> TradeoffSample:
        """Post-repack sample; becomes the drift baseline."""
        s = self.sample("repack")
        with self._lock:
            self.baseline = s
        return s

    # -- introspection -----------------------------------------------------
    @property
    def latest(self) -> Optional[TradeoffSample]:
        with self._lock:
            return self.history[-1] if self.history else None

    def drift(self) -> Optional[Dict[str, Any]]:
        """Latest sample vs the baseline (post-repack if one happened, else
        the attach-time sample): absolute values plus ratios.  ``None``
        until at least one sample exists."""
        with self._lock:
            if not self.history or self.baseline is None:
                return None
            latest, base = self.history[-1], self.baseline

        def ratio(now: float, then: float) -> Optional[float]:
            return (now / then) if then > 0 else None

        return {
            "baseline_event": base.event,
            "baseline_age_s": latest.timestamp - base.timestamp,
            "versions_added": latest.versions - base.versions,
            "storage_bytes": latest.storage_bytes_total,
            "storage_bytes_baseline": base.storage_bytes_total,
            "storage_ratio": ratio(
                latest.storage_bytes_total, base.storage_bytes_total
            ),
            "access_weighted_recreation_s":
                latest.access_weighted_recreation_s,
            "access_weighted_recreation_baseline_s":
                base.access_weighted_recreation_s,
            "access_weighted_recreation_ratio": ratio(
                latest.access_weighted_recreation_s,
                base.access_weighted_recreation_s,
            ),
            "recreation_p99_ratio": ratio(
                latest.recreation_p99_s, base.recreation_p99_s
            ),
        }

    def describe_drift(self) -> Optional[str]:
        """Human one-liner for logs/repack recommendations, e.g.
        ``access-weighted R is 2.31x the post-repack baseline; storage is
        1.08x (+12 versions since)``."""
        d = self.drift()
        if d is None:
            return None
        kind = ("post-repack" if d["baseline_event"] == "repack"
                else "attach-time")
        awr = d["access_weighted_recreation_ratio"]
        sto = d["storage_ratio"]
        awr_s = f"{awr:.2f}x" if awr is not None else "n/a"
        sto_s = f"{sto:.2f}x" if sto is not None else "n/a"
        return (
            f"access-weighted R is {awr_s} the {kind} baseline; storage is "
            f"{sto_s} (+{d['versions_added']} versions since)"
        )

    def snapshot(self) -> Dict[str, Any]:
        """JSON-friendly view for ``DatasetService.stats()`` / exporters."""
        latest = self.latest
        with self._lock:
            base = self.baseline
            n = len(self.history)
        return {
            "samples": n,
            "latest": latest.to_dict() if latest else None,
            "baseline": base.to_dict() if base else None,
            "drift": self.drift(),
        }
