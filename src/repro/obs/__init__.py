"""Observability: end-to-end span tracing + live tradeoff telemetry.

Three pieces, one contract (details in each module's docstring):

* :mod:`.tracer` — the span tracer the whole request path records into:
  ``DatasetService`` request lifecycle (enqueue → queue wait → coalesce /
  batch fold → dispatch), ``Materializer`` plan/decode with cache-hit and
  fused-launch attribution, ``apply_delta_chains`` device launches,
  ``optimize()`` solver runs, and repack/fsck quiesce windows.  Spans
  propagate through ``contextvars`` (nesting survives ``await`` and, via
  :meth:`Tracer.attach`, the hop onto reader/writer pool threads), land in
  a bounded ring buffer, and cost one attribute check when tracing is
  disabled — the default.
* :mod:`.export` — Chrome trace-event JSON (Perfetto-loadable, one track
  per thread/task) and Prometheus-style text exposition merging
  ``ServiceMetrics`` with the store/tradeoff gauges.
* :mod:`.tradeoff` — :class:`TradeoffMonitor`: live (C, R) samples on every
  commit/repack (storage bytes by full/delta object, per-version
  recreation-cost percentiles, the access-weighted recreation sum of
  Problems 5/6) with a post-repack baseline, so drift is a *number* the
  ``FsckSweeper`` can put in its repack recommendation.

Quick start::

    from repro import obs

    with obs.tracing() as tracer:            # enabled tracer, auto-restored
        ...  # run service traffic
    obs.chrome_trace(tracer, "trace.json")   # load in ui.perfetto.dev

CLI: ``python -m repro.obs {summary,trace,convert,prom,overhead}``
(``--synthetic`` self-exercises a throwaway store end to end).
"""

from __future__ import annotations

import contextlib
from typing import Iterator

from .export import (
    chrome_trace,
    dump_spans_jsonl,
    load_spans_jsonl,
    prometheus_text,
    validate_chrome_trace,
)
from .tracer import (
    NULL_SPAN,
    Span,
    Tracer,
    add_event,
    enabled,
    get_tracer,
    set_tracer,
    span,
    start,
)
from .tradeoff import TradeoffMonitor, TradeoffSample

__all__ = [
    "Span",
    "Tracer",
    "NULL_SPAN",
    "get_tracer",
    "set_tracer",
    "span",
    "start",
    "add_event",
    "enabled",
    "tracing",
    "chrome_trace",
    "validate_chrome_trace",
    "prometheus_text",
    "dump_spans_jsonl",
    "load_spans_jsonl",
    "TradeoffMonitor",
    "TradeoffSample",
]


@contextlib.contextmanager
def tracing(*, capacity: int = 65536) -> Iterator[Tracer]:
    """Install a fresh enabled tracer as the process global for the block;
    restores the previous tracer on exit and yields the new one (its spans
    stay readable after the block ends)."""
    tracer = Tracer(enabled=True, capacity=capacity)
    old = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(old)
