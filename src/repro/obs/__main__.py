"""``python -m repro.obs`` — see :mod:`repro.obs.cli`."""

import sys

from .cli import main

sys.exit(main())
