"""Trace/metric exporters: Chrome trace-event JSON and Prometheus text.

Two output formats over the tracer/metrics state:

* :func:`chrome_trace` — the Chrome trace-event format (the ``trace_event``
  JSON Perfetto and ``chrome://tracing`` load).  Every finished span becomes
  one complete (``"ph": "X"``) event; tracks (one per thread/task, assigned
  at span start) become ``tid``\\ s with ``thread_name`` metadata events, so
  concurrent requests render as separate rows instead of interleaving.
  Timestamps are microseconds relative to the earliest span in the export
  (the tracer clock is ``time.monotonic``, so absolute values are
  meaningless across processes anyway).  ``pid`` groups spans from
  different runs/processes in one file (the serving benchmark exports its
  chain/global runs side by side this way).
* :func:`prometheus_text` — Prometheus/OpenMetrics-style text exposition
  over a ``DatasetService.stats()`` snapshot: counters → ``_total``
  counters, latency tracks → summaries (quantile samples + ``_count`` /
  ``_sum``), gauges / materializer stats / tradeoff samples → gauges.
  Metric names are sanitized (``[^a-zA-Z0-9_]`` → ``_``) and prefixed
  ``repro_``.

:func:`validate_chrome_trace` is the CI contract: it checks the structural
invariants Perfetto needs (event list, required keys, non-negative
``ts``/``dur``, integer pids/tids, metadata naming every tid) and returns a
list of problems — empty means loadable.
"""

from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from .tracer import Span, Tracer

__all__ = [
    "chrome_trace",
    "validate_chrome_trace",
    "prometheus_text",
    "dump_spans_jsonl",
    "load_spans_jsonl",
]

_SpanLike = Union[Span, Dict[str, Any]]


def _as_dict(sp: _SpanLike) -> Dict[str, Any]:
    return sp.to_dict() if isinstance(sp, Span) else sp


def chrome_trace(
    spans: Union[Tracer, Iterable[_SpanLike]],
    path: Union[str, Path, None] = None,
    *,
    pid: int = 1,
    process_name: str = "repro",
    base: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Render spans as a Chrome trace-event JSON object (optionally written
    to ``path``).

    ``base`` merges these events into an existing export (pass the previous
    call's return value with a different ``pid`` to put several runs in one
    file — each shows up as its own process group in Perfetto).
    """
    if isinstance(spans, Tracer):
        spans = spans.spans()
    rows = [_as_dict(s) for s in spans]
    rows = [r for r in rows if r.get("t1") is not None]
    rows.sort(key=lambda r: r["t0"])

    out = base if base is not None else {"traceEvents": [], "displayTimeUnit": "ms"}
    events: List[Dict[str, Any]] = out["traceEvents"]

    # one time origin per file: fixed by the first export into it, so a
    # later run merged with base= lands to the right of the first (the
    # tracer clock is monotonic within the process)
    t_min = out.get("_origin_s")
    if t_min is None:
        t_min = out["_origin_s"] = min((r["t0"] for r in rows), default=0.0)

    events.append({
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "ts": 0, "args": {"name": process_name},
    })
    tids: Dict[str, int] = {}
    for r in rows:
        track = r.get("track") or "thread:?"
        tid = tids.get(track)
        if tid is None:
            tid = tids[track] = len(tids) + 1
            events.append({
                "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                "ts": 0, "args": {"name": track},
            })
        args = {
            k: v for k, v in (r.get("attrs") or {}).items()
        }
        args["span_id"] = r["span_id"]
        if r.get("parent_id") is not None:
            args["parent_id"] = r["parent_id"]
        events.append({
            "ph": "X",
            "name": r["name"],
            "cat": r["name"].split(".", 1)[0],
            "pid": pid,
            "tid": tid,
            "ts": round(max(0.0, r["t0"] - t_min) * 1e6, 3),
            "dur": round((r["t1"] - r["t0"]) * 1e6, 3),
            "args": args,
        })

    if path is not None:
        Path(path).write_text(
            json.dumps(_strip_private(out), indent=None, separators=(",", ":"))
            + "\n"
        )
    return out


def _strip_private(trace: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in trace.items() if not k.startswith("_")}


def validate_chrome_trace(
    trace: Union[str, Path, Dict[str, Any]]
) -> List[str]:
    """Structural validation of a Chrome trace export (see module docs).
    Accepts a path, a JSON string, or the parsed object; returns a list of
    problems — empty means Perfetto-loadable."""
    if isinstance(trace, Path) or (
        isinstance(trace, str) and "\n" not in trace and not trace.lstrip().startswith("{")
    ):
        try:
            trace = json.loads(Path(trace).read_text())
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable trace file: {e}"]
    elif isinstance(trace, str):
        try:
            trace = json.loads(trace)
        except json.JSONDecodeError as e:
            return [f"invalid JSON: {e}"]

    problems: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["top level must be an object with a 'traceEvents' array"]
    events = trace["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]
    named_tids: Dict[int, set] = {}
    used_tids: Dict[int, set] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event[{i}]: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "B", "E", "i", "C"):
            problems.append(f"event[{i}]: unknown phase {ph!r}")
            continue
        for key in ("name", "pid", "tid"):
            if key not in ev:
                problems.append(f"event[{i}]: missing {key!r}")
        if not isinstance(ev.get("pid"), int) or not isinstance(ev.get("tid"), int):
            problems.append(f"event[{i}]: pid/tid must be integers")
            continue
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_tids.setdefault(ev["pid"], set()).add(ev["tid"])
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event[{i}]: ts must be a number >= 0, got {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(
                    f"event[{i}]: X event needs dur >= 0, got {dur!r}"
                )
            used_tids.setdefault(ev["pid"], set()).add(ev["tid"])
    for pid, tids in used_tids.items():
        missing = tids - named_tids.get(pid, set())
        if missing:
            problems.append(
                f"pid {pid}: tids {sorted(missing)} have no thread_name "
                f"metadata"
            )
    return problems


# -- Prometheus text ---------------------------------------------------------
_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(*parts: str) -> str:
    return _NAME_RE.sub("_", "_".join(("repro",) + parts))


def prometheus_text(snapshot: Dict[str, Any]) -> str:
    """Render a ``DatasetService.stats()``-shaped snapshot (``counters`` /
    ``tracks`` / ``gauges`` / ``store`` / ``tradeoff`` sub-dicts, each
    optional) as Prometheus text exposition."""
    lines: List[str] = []

    def emit(name: str, value: Any, mtype: str, labels: str = "",
             suffix: str = "") -> None:
        if not any(l.startswith(f"# TYPE {name} ") for l in lines):
            lines.append(f"# TYPE {name} {mtype}")
        lines.append(f"{name}{suffix}{labels} {value}")

    for key, val in sorted((snapshot.get("counters") or {}).items()):
        emit(_metric_name(key, "total"), val, "counter")

    for key, tr in sorted((snapshot.get("tracks") or {}).items()):
        name = _metric_name(key, "seconds")
        count = tr.get("count", 0)
        lines.append(f"# TYPE {name} summary")
        for q, field in (("0.5", "p50_ms"), ("0.99", "p99_ms")):
            if field in tr:
                lines.append(
                    f'{name}{{quantile="{q}"}} {tr[field] / 1e3:.9g}'
                )
        lines.append(f"{name}_count {count}")
        if "mean_ms" in tr:
            lines.append(
                f"{name}_sum {tr['mean_ms'] / 1e3 * count:.9g}"
            )
        else:
            lines.append(f"{name}_sum 0")

    for key, val in sorted((snapshot.get("gauges") or {}).items()):
        emit(_metric_name(key), val, "gauge")

    store = snapshot.get("store") or {}
    for key, val in sorted(store.items()):
        emit(_metric_name("store", key), val, "gauge")

    trade = snapshot.get("tradeoff") or {}
    latest = trade.get("latest") or {}
    if latest:
        name = _metric_name("tradeoff", "storage_bytes")
        lines.append(f"# TYPE {name} gauge")
        lines.append(
            f'{name}{{kind="full"}} {latest.get("storage_bytes_full", 0)}'
        )
        lines.append(
            f'{name}{{kind="delta"}} {latest.get("storage_bytes_delta", 0)}'
        )
        name = _metric_name("tradeoff", "objects")
        lines.append(f"# TYPE {name} gauge")
        lines.append(f'{name}{{kind="full"}} {latest.get("full_objects", 0)}')
        lines.append(f'{name}{{kind="delta"}} {latest.get("delta_objects", 0)}')
        name = _metric_name("tradeoff", "recreation_seconds")
        lines.append(f"# TYPE {name} gauge")
        for q, field in (("0.5", "recreation_p50_s"),
                         ("0.99", "recreation_p99_s"),
                         ("max", "recreation_max_s")):
            lines.append(
                f'{name}{{quantile="{q}"}} {latest.get(field, 0.0):.9g}'
            )
        emit(
            _metric_name("tradeoff", "sum_recreation_seconds"),
            f"{latest.get('recreation_sum_s', 0.0):.9g}", "gauge",
        )
        emit(
            _metric_name("tradeoff", "access_weighted_recreation_seconds"),
            f"{latest.get('access_weighted_recreation_s', 0.0):.9g}", "gauge",
        )
    drift = trade.get("drift") or {}
    for key in ("storage_ratio", "access_weighted_recreation_ratio"):
        if key in drift and drift[key] is not None:
            emit(
                _metric_name("tradeoff_drift", key), f"{drift[key]:.9g}",
                "gauge",
            )
    return "\n".join(lines) + "\n"


# -- raw span dump / convert -------------------------------------------------
def dump_spans_jsonl(
    spans: Union[Tracer, Sequence[_SpanLike]], path: Union[str, Path]
) -> int:
    """Write spans as one-JSON-object-per-line (the ``convert`` input
    format); returns the number written."""
    if isinstance(spans, Tracer):
        spans = spans.spans()
    rows = [_as_dict(s) for s in spans]
    with open(path, "w") as f:
        for r in rows:
            f.write(json.dumps(r, separators=(",", ":")) + "\n")
    return len(rows)


def load_spans_jsonl(path: Union[str, Path]) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows
