"""Span tracer: nested wall-clock intervals across the event loop and pools.

One :class:`Tracer` owns a bounded ring buffer of finished :class:`Span`\\ s.
The **span contract** every instrumented layer follows:

* A span is an interval ``[t0, t1)`` on the tracer clock
  (``time.monotonic`` — the same clock the asyncio event loop and the
  service metrics use, so span totals reconcile exactly with the
  ``ServiceMetrics`` latency tracks) plus a ``name``, a free-form ``attrs``
  dict, and parent/trace ids for nesting.
* Parenthood propagates through a ``contextvars.ContextVar``: entering a
  span (``with tracer.span("x"):``) makes it the current parent for
  anything opened in the same task/thread context — including across
  ``await`` boundaries, because asyncio snapshots the context per task.
  Thread pools do **not** inherit context; a caller dispatching work onto a
  worker thread wraps the callable's body in :meth:`Tracer.attach` to carry
  its span across explicitly (the service tier does this for every batch).
* A span may be *started* in one context and *ended* in another
  (``sp = tracer.start("x")`` … ``sp.end()`` from a pool thread): ``end``
  is thread-safe and idempotent, and only ``__enter__``/``__exit__`` touch
  the context var.
* Track assignment for the exporters happens at start: spans opened inside
  an asyncio task get a per-task track (concurrent requests don't
  interleave on one Perfetto row); spans opened elsewhere get their
  thread's track.

Disabled tracers (the default global) are near-free: ``span()``/``start``
return a shared no-op singleton whose methods do nothing, and
``add_event``/``record`` return immediately — the hot serving path pays one
attribute check per instrumentation point.  ``Span.__bool__`` is ``True``
for real spans and ``False`` for the singleton, so call sites can guard
expensive attribute computation with ``if sp: sp.set(...)``.

The ring buffer keeps the most recent ``capacity`` finished spans
(``dropped`` counts overwrites), so a long-lived service traces
continuously with bounded memory.  All mutation is lock-guarded; reader
pool threads, the writer thread and the event loop share one tracer.
"""

from __future__ import annotations

import asyncio
import contextlib
import contextvars
import functools
import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
    "span",
    "start",
    "add_event",
    "enabled",
]

_current_span: "contextvars.ContextVar[Optional[Span]]" = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)


def _track_name() -> str:
    """Exporter track for a span started here: the running asyncio task if
    any (so concurrent requests get separate Perfetto rows), else the
    thread."""
    try:
        task = asyncio.current_task()
    except RuntimeError:
        task = None
    if task is not None:
        return f"task:{task.get_name()}"
    return f"thread:{threading.current_thread().name}"


class Span:
    """One traced interval.  Created by a :class:`Tracer`; see module docs
    for the start/end and context-propagation contract."""

    __slots__ = (
        "name", "span_id", "parent_id", "trace_id", "t0", "t1",
        "attrs", "track", "_tracer", "_token",
    )

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        span_id: int,
        parent_id: Optional[int],
        trace_id: int,
        t0: float,
        track: str,
        attrs: Optional[Dict[str, Any]] = None,
    ) -> None:
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.trace_id = trace_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, Any] = attrs or {}
        self.track = track
        self._tracer = tracer
        self._token: Optional[contextvars.Token] = None

    def set(self, **attrs: Any) -> "Span":
        """Attach attributes (merged; later calls win on key collision)."""
        self.attrs.update(attrs)
        return self

    def end(self, t1: Optional[float] = None) -> None:
        """Close the span and record it.  Thread-safe, idempotent; callable
        from a different thread than the one that started the span."""
        if self.t1 is None:
            self.t1 = self._tracer.now() if t1 is None else t1
            self._tracer._record(self)

    @property
    def duration(self) -> float:
        if self.t1 is None:
            return 0.0
        return self.t1 - self.t0

    def __enter__(self) -> "Span":
        self._token = _current_span.set(self)
        return self

    def __exit__(self, *exc: Any) -> None:
        if self._token is not None:
            _current_span.reset(self._token)
            self._token = None
        self.end()

    def __bool__(self) -> bool:
        return True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = f"{self.duration * 1e3:.3f}ms" if self.t1 else "open"
        return f"<Span {self.name!r} #{self.span_id} {state}>"

    def to_dict(self) -> Dict[str, Any]:
        """JSON-serializable form (the JSONL dump / ``convert`` format)."""
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "trace_id": self.trace_id,
            "t0": self.t0,
            "t1": self.t1,
            "track": self.track,
            "attrs": self.attrs,
        }


class _NullSpan:
    """Shared do-nothing span returned by disabled tracers.  Falsy, so call
    sites can skip attribute computation with ``if sp:``."""

    __slots__ = ()

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    def end(self, t1: Optional[float] = None) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "<NullSpan>"


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + bounded ring buffer (see module docstring).

    ``enabled=False`` (the default for the global tracer) makes every
    entry point a near-zero no-op; flipping :meth:`enable`/:meth:`disable`
    at runtime is safe — spans already open finish normally.
    """

    #: the tracer clock — one source for spans AND the service metrics
    now = staticmethod(time.monotonic)

    def __init__(self, *, enabled: bool = False, capacity: int = 65536) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=self.capacity)
        self.dropped = 0
        self._ids = itertools.count(1)

    # -- lifecycle ---------------------------------------------------------
    def enable(self) -> "Tracer":
        self.enabled = True
        return self

    def disable(self) -> "Tracer":
        self.enabled = False
        return self

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self.dropped = 0

    # -- span creation -----------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Context-manager span: sets the context parent while entered."""
        if not self.enabled:
            return NULL_SPAN
        return self._make(name, _current_span.get(), attrs)

    def start(self, name: str, *, parent: Optional[Span] = None, **attrs: Any):
        """Explicit-lifetime span: does NOT touch the context var; close it
        with ``.end()`` from any thread.  ``parent`` overrides the current
        context parent (pass a span captured on the event loop to parent
        work running in a pool thread)."""
        if not self.enabled:
            return NULL_SPAN
        if parent is None:
            parent = _current_span.get()
        return self._make(name, parent, attrs)

    def _make(
        self, name: str, parent: Optional[Span], attrs: Dict[str, Any]
    ) -> Span:
        if isinstance(parent, _NullSpan):
            parent = None
        sid = next(self._ids)
        if parent is not None:
            pid: Optional[int] = parent.span_id
            tid = parent.trace_id
        else:
            pid, tid = None, sid
        return Span(self, name, sid, pid, tid, self.now(), _track_name(), attrs)

    def add_event(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        parent: Optional[Span] = None,
        **attrs: Any,
    ) -> None:
        """Record a finished span retroactively from timestamps already in
        hand (tracer-clock seconds) — e.g. the queue-wait interval between a
        request's enqueue stamp and its batch dispatch."""
        if not self.enabled:
            return
        if parent is None:
            parent = _current_span.get()
        sp = self._make(name, parent, attrs)
        sp.t0 = t0
        sp.t1 = t1
        self._record(sp)

    @contextlib.contextmanager
    def attach(self, parent: Optional[Span]) -> Iterator[None]:
        """Make ``parent`` the context parent for this block — the bridge
        for work dispatched onto pool threads, which don't inherit the
        submitting context.  A ``None``/null parent leaves context alone."""
        if not self.enabled or parent is None or isinstance(parent, _NullSpan):
            yield
            return
        token = _current_span.set(parent)
        try:
            yield
        finally:
            _current_span.reset(token)

    def wrap(self, name: Optional[str] = None, **attrs: Any) -> Callable:
        """Decorator form: traces every call of the wrapped (a)sync function
        as one span named ``name`` (default: the function's qualname)."""

        def deco(fn: Callable) -> Callable:
            label = name or fn.__qualname__
            if asyncio.iscoroutinefunction(fn):

                @functools.wraps(fn)
                async def awrapper(*args: Any, **kwargs: Any):
                    with self.span(label, **attrs):
                        return await fn(*args, **kwargs)

                return awrapper

            @functools.wraps(fn)
            def wrapper(*args: Any, **kwargs: Any):
                with self.span(label, **attrs):
                    return fn(*args, **kwargs)

            return wrapper

        return deco

    # -- ring buffer -------------------------------------------------------
    def _record(self, sp: Span) -> None:
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(sp)

    def spans(self) -> List[Span]:
        """Snapshot of the retained finished spans, oldest first."""
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    def current(self) -> Optional[Span]:
        """The context's current parent span (``None`` outside any span)."""
        sp = _current_span.get()
        return None if isinstance(sp, _NullSpan) else sp

    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-name rollup over retained spans: count / total / mean / max
        (seconds) — what the CLI summary table prints."""
        agg: Dict[str, Dict[str, float]] = {}
        for sp in self.spans():
            d = agg.setdefault(
                sp.name, {"count": 0, "total_s": 0.0, "max_s": 0.0}
            )
            d["count"] += 1
            d["total_s"] += sp.duration
            if sp.duration > d["max_s"]:
                d["max_s"] = sp.duration
        for d in agg.values():
            d["mean_s"] = d["total_s"] / d["count"]
        return agg


# -- global tracer -----------------------------------------------------------
# Disabled by default: the instrumented layers call through these module
# functions, which cost one attribute check when tracing is off.  The service
# tier and CLI install an enabled tracer via set_tracer() (or pass their own
# Tracer straight to DatasetService).
_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-global default; returns the old
    one (restore it in tests)."""
    global _GLOBAL
    old, _GLOBAL = _GLOBAL, tracer
    return old


def enabled() -> bool:
    return _GLOBAL.enabled


def span(name: str, **attrs: Any):
    """``with span("layer.op") as sp:`` against the global tracer."""
    t = _GLOBAL
    if not t.enabled:
        return NULL_SPAN
    return t.span(name, **attrs)


def start(name: str, *, parent: Optional[Span] = None, **attrs: Any):
    t = _GLOBAL
    if not t.enabled:
        return NULL_SPAN
    return t.start(name, parent=parent, **attrs)


def add_event(
    name: str, t0: float, t1: float, *, parent: Optional[Span] = None,
    **attrs: Any,
) -> None:
    t = _GLOBAL
    if t.enabled:
        t.add_event(name, t0, t1, parent=parent, **attrs)
