"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention 1:2, window 2048.
[arXiv:2402.19427; unverified]

Sub-quadratic: supports the long_500k cell (decode state = RG-LRU state +
a 2048-token local-attention window cache).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=("rglru", "rglru", "attn"),
    lru_width=4096,
    attn_window=2048,
    mlp_kind="gelu",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
