"""Architecture config schema + the four assigned input shapes.

One :class:`ModelConfig` drives every family (dense / MoE / MLA / hybrid /
SSM / enc-dec / VLM backbone); ``layer_pattern`` describes the per-layer
block sequence (e.g. RecurrentGemma's (rglru, rglru, attn) triples).  Every
field mirrors the published configuration cited in the arch file.

``reduced()`` returns the same family at smoke-test scale: the per-arch CPU
tests instantiate *that*, while the full configs are exercised exclusively
through the dry-run (ShapeDtypeStruct only — no allocation).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# the assigned LM shape set (seq_len × global_batch)
SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    # block sequence, tiled to n_layers. entries: attn|mla|rglru|rwkv
    layer_pattern: Tuple[str, ...] = ("attn",)
    mlp_kind: str = "swiglu"          # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_dense_layers: int = 0           # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    moe_impl: str = "dispatch"        # dispatch (pjit scatter) | ep (shard_map all_to_all)
    # --- MLA (deepseek) -------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0
    mla_compressed_cache: bool = False   # perf variant: cache the c_kv latent
    mtp: bool = False                    # multi-token-prediction aux head
    # --- recurrent (RG-LRU / RWKV6) -------------------------------------------
    lru_width: int = 0
    conv_width: int = 4
    attn_window: int = 0              # sliding window for local attention
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_shift_lora: int = 32
    rwkv_impl: str = "scan"           # scan (baseline) | chunked (perf variant)
    # --- enc-dec ---------------------------------------------------------------
    enc_layers: int = 0               # >0 => encoder-decoder
    frontend: str = "none"            # none | audio_stub | vq_stub
    # --- which assigned shapes apply (long_500k only for sub-quadratic) --------
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 512 so embedding/head shard
        evenly on a 16-way model axis (standard framework practice; padded
        rows are zero-init and never targeted by the loss)."""
        return -(-self.vocab // 512) * 512

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def blocks(self) -> List[str]:
        """Per-layer block kinds, length n_layers."""
        out: List[str] = []
        i = 0
        while len(out) < self.n_layers:
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            if self.n_experts and kind == "attn_moe_pair":
                pass
            out.append(kind)
            i += 1
        return out[: self.n_layers]

    def layer_uses_moe(self, layer_idx: int) -> bool:
        return bool(self.n_experts) and layer_idx >= self.n_dense_layers

    def param_count(self) -> int:
        """Approximate total parameters (used for roofline MODEL_FLOPS)."""
        from ..models.registry import count_params  # local import, no cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from ..models.registry import count_params

        return count_params(self, active_only=True)

    def reduced(self) -> "ModelConfig":
        """Same family at smoke scale."""
        shrink = {
            "n_layers": min(self.n_layers, 4 if len(self.layer_pattern) < 3 else 6),
            "d_model": 128,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            "head_dim": 32,
            "d_ff": 256,
            "vocab": 512,
            "enc_layers": min(self.enc_layers, 2) if self.enc_layers else 0,
        }
        if self.n_experts:
            shrink.update(
                n_experts=4, top_k=min(self.top_k, 2), expert_d_ff=64,
                n_shared_experts=min(self.n_shared_experts, 1),
                n_dense_layers=min(self.n_dense_layers, 1),
                # drop-free capacity (cf = E/K) so prefill/decode and teacher
                # forcing agree exactly; production keeps the paper's 1.25
                capacity_factor=4 / min(self.top_k, 2),
            )
        if self.q_lora_rank:
            shrink.update(
                q_lora_rank=64, kv_lora_rank=32, qk_rope_head_dim=16,
                qk_nope_head_dim=32, v_head_dim=32,
            )
        if self.lru_width:
            shrink.update(lru_width=128, attn_window=32)
        if self.family == "ssm":
            shrink.update(rwkv_head_dim=32, rwkv_decay_lora=16, rwkv_shift_lora=8)
        if self.attn_window and not self.lru_width:
            shrink.update(attn_window=32)
        return dataclasses.replace(self, name=self.name + "-smoke", **shrink)
