"""DeepSeek-V3 (671B total) — MLA, 1 shared + 256 routed experts top-8,
3 leading dense layers, MTP. [arXiv:2412.19437; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,             # dense-layer ff width
    vocab=129280,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    expert_d_ff=2048,
    n_dense_layers=3,
    layer_pattern=("mla",),
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    mtp=True,
    mlp_kind="swiglu",
)
