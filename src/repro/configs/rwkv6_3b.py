"""RWKV-6 (Finch) 3B — attention-free, token-shift + data-dependent decay.
[arXiv:2404.05892; hf]

Sub-quadratic (O(1) decode state): supports the long_500k cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,              # d_model / rwkv_head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    layer_pattern=("rwkv",),
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    rwkv_shift_lora=32,
    mlp_kind="rwkv_ffn",
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
