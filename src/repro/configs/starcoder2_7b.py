"""StarCoder2-7B — dense, GQA(kv=4), RoPE, GELU MLP. [arXiv:2402.19173; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab=49152,
    mlp_kind="gelu",
    rope_theta=1e5,
)
