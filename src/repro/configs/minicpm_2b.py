"""MiniCPM-2B — llama-like, trained with the WSD schedule. [arXiv:2404.06395; hf]

The WSD (warmup-stable-decay) schedule itself lives in
``repro.training.optimizer``; arch-wise this is a dense GQA transformer
(kv=36 == MHA), tied embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab=122753,
    mlp_kind="swiglu",
    tie_embeddings=True,
)
