"""Chameleon-34B — early-fusion VLM backbone, unified text+VQ vocab, qk-norm.
VQ image tokenizer is a stub frontend per spec (tokens arrive pre-quantized).
[arXiv:2405.09818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    mlp_kind="swiglu",
    frontend="vq_stub",
)
