"""Assigned architecture registry: --arch <id> resolves here."""

from .base import SHAPES, ModelConfig, ShapeSpec
from .chameleon_34b import CONFIG as chameleon_34b
from .deepseek_v3 import CONFIG as deepseek_v3_671b
from .minicpm_2b import CONFIG as minicpm_2b
from .minitron_4b import CONFIG as minitron_4b
from .phi35_moe import CONFIG as phi35_moe
from .qwen3_32b import CONFIG as qwen3_32b
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .rwkv6_3b import CONFIG as rwkv6_3b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .starcoder2_7b import CONFIG as starcoder2_7b

ARCHS = {
    c.name: c
    for c in [
        qwen3_32b,
        starcoder2_7b,
        minitron_4b,
        minicpm_2b,
        phi35_moe,
        deepseek_v3_671b,
        seamless_m4t_medium,
        recurrentgemma_9b,
        chameleon_34b,
        rwkv6_3b,
    ]
}

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeSpec"]
