"""SeamlessM4T-medium — enc-dec backbone (12L + 12L), multimodal frontends
stubbed per spec (input_specs provides precomputed frame embeddings).
[arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,            # decoder layers
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    mlp_kind="gelu",
    frontend="audio_stub",
)
