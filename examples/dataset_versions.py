"""Versioned datasets feeding the training pipeline (paper scenario #2).

A tokenized corpus evolves through cleaning -> dedup -> mixture updates;
each stage is a version in the store (deltas, not copies).  Training jobs
pin a dataset *version id* — switching versions is a checkout, reproducible
forever, and the storage stays near the size of one copy + edits.

Run:  PYTHONPATH=src python examples/dataset_versions.py
"""

import shutil
import tempfile

import numpy as np

from repro.data.pipeline import VersionedDatasetPipeline
from repro.store import VersionStore


def main() -> None:
    d = tempfile.mkdtemp(prefix="repro_data_")
    store = VersionStore(d)
    rng = np.random.RandomState(0)

    # v1: raw corpus — 4 shards of token ids
    shards = {f"shard{i:02d}": rng.randint(0, 50000, 200_000).astype(np.int32)
              for i in range(4)}
    v1 = store.commit(shards, message="raw corpus")

    # v2: cleaning pass rewrites 3% of tokens in two shards
    cleaned = {k: v.copy() for k, v in shards.items()}
    for k in ("shard00", "shard02"):
        idx = rng.choice(cleaned[k].size, size=cleaned[k].size * 3 // 100, replace=False)
        cleaned[k][idx] = 0
    v2 = store.commit(cleaned, parents=[v1], message="cleaning pass")

    # v3: dedup drops one shard, adds a fresh one
    dedup = {k: v for k, v in cleaned.items() if k != "shard03"}
    dedup["shard04"] = rng.randint(0, 50000, 150_000).astype(np.int32)
    v3 = store.commit(dedup, parents=[v2], message="dedup + new crawl")

    raw = sum(m.raw_bytes for m in store.log())
    print(f"3 corpus versions: raw {raw/1e6:.1f} MB -> stored "
          f"{store.storage_bytes()/1e6:.1f} MB")

    pipe = VersionedDatasetPipeline(store, v3, seq_len=128, global_batch=8)
    b0 = pipe.next_batch()
    snap = pipe.snapshot()
    b1 = pipe.next_batch()

    # resume determinism: a new pipeline from the snapshot yields batch 1 again
    pipe2 = VersionedDatasetPipeline(store, v3, seq_len=128, global_batch=8)
    pipe2.restore(snap)
    b1_again = pipe2.next_batch()
    assert np.array_equal(b1["tokens"], b1_again["tokens"])
    print(f"pinned dataset v{v3}; batch shape {b0['tokens'].shape}; "
          f"resume-from-snapshot determinism ✓")
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
