"""Versioned datasets feeding the training pipeline (paper scenario #2).

A tokenized corpus evolves through cleaning -> dedup -> mixture updates;
each stage is a commit on the repository's ``main`` branch, *tagged* with a
stable name (deltas, not copies).  Training jobs pin a dataset tag —
switching versions is a checkout, reproducible forever, and the storage
stays near the size of one copy + edits.

Run:  PYTHONPATH=src python examples/dataset_versions.py
"""

import shutil
import tempfile

import numpy as np

from repro.data.pipeline import VersionedDatasetPipeline
from repro.store import Repository


def main() -> None:
    d = tempfile.mkdtemp(prefix="repro_data_")
    repo = Repository(d)
    store = repo.store
    rng = np.random.RandomState(0)

    # raw corpus — 4 shards of token ids
    shards = {f"shard{i:02d}": rng.randint(0, 50000, 200_000).astype(np.int32)
              for i in range(4)}
    repo.commit(shards, message="raw corpus")
    repo.tag("corpus-raw")

    # cleaning pass rewrites 3% of tokens in two shards
    cleaned = {k: v.copy() for k, v in shards.items()}
    for k in ("shard00", "shard02"):
        idx = rng.choice(cleaned[k].size, size=cleaned[k].size * 3 // 100, replace=False)
        cleaned[k][idx] = 0
    repo.commit(cleaned, message="cleaning pass")
    repo.tag("corpus-cleaned")

    # dedup drops one shard, adds a fresh one
    dedup = {k: v for k, v in cleaned.items() if k != "shard03"}
    dedup["shard04"] = rng.randint(0, 50000, 150_000).astype(np.int32)
    v3 = repo.commit(dedup, message="dedup + new crawl")
    repo.tag("corpus-dedup-v1")

    raw = sum(m.raw_bytes for m in store.log())
    print(f"3 corpus versions: raw {raw/1e6:.1f} MB -> stored "
          f"{store.storage_bytes()/1e6:.1f} MB; tags={sorted(repo.tags())}")
    print(f"cleaning touched: {repo.diff('corpus-raw', 'corpus-cleaned').summary()}")

    # the training job pins the *tag*; the pipeline still speaks raw vids
    assert repo.resolve("corpus-dedup-v1") == v3
    pipe = VersionedDatasetPipeline(store, repo.resolve("corpus-dedup-v1"),
                                    seq_len=128, global_batch=8)
    b0 = pipe.next_batch()
    snap = pipe.snapshot()
    b1 = pipe.next_batch()

    # resume determinism: a new pipeline from the snapshot yields batch 1 again
    pipe2 = VersionedDatasetPipeline(store, v3, seq_len=128, global_batch=8)
    pipe2.restore(snap)
    b1_again = pipe2.next_batch()
    assert np.array_equal(b1["tokens"], b1_again["tokens"])
    print(f"pinned dataset 'corpus-dedup-v1' (v{v3}); batch shape "
          f"{b0['tokens'].shape}; resume-from-snapshot determinism ✓")
    repo.close()
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
