"""Quickstart: the paper end to end in two minutes (CPU).

Canonical API tour:

1. Reproduces the paper's Figure 1 worked example with the declarative
   spec grid — every problem is ``optimize(graph, OptimizeSpec.problem(n))``.
2. Builds a synthetic versioned-dataset workload (paper §5.1), sweeps the
   grid, and prints the storage/recreation frontier.
3. Commits real tensor payloads through a ``Repository`` (named branches +
   tags over the VersionStore), repacks against a spec, and verifies
   checkouts are byte-identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    OptimizeSpec, VersionGraph, dc_like, generate, optimize,
)
from repro.store import Repository


def figure1() -> None:
    print("=== Paper Figure 1 — the (objective, constraint) grid ===")
    g = VersionGraph(5, directed=True)
    for i, (s, r) in enumerate(
        [(10000, 10000), (10100, 10100), (9700, 9700), (9800, 9800), (10120, 10120)], 1
    ):
        g.set_materialization(i, s, r)
    g.set_delta(1, 2, 200, 350)
    g.set_delta(1, 3, 1000, 3000)
    g.set_delta(2, 4, 50, 200)
    g.set_delta(3, 5, 800, 2500)
    g.set_delta(2, 5, 200, 550)

    spt = optimize(g, OptimizeSpec.problem(2))       # min every R_i
    mca = optimize(g, OptimizeSpec.problem(1))       # min storage C
    print(f"  store-everything (Fig 1 ii):   C={spt.objective_values['storage']:>7.0f}  "
          f"maxR={spt.objective_values['max_recreation']:.0f}")
    print(f"  min-storage MCA  (Fig 1 iii):  C={mca.objective_value:>7.0f}  "
          f"maxR={mca.objective_values['max_recreation']:.0f}")
    lmg = optimize(g, OptimizeSpec.problem(3, beta=20150))
    print(f"  balanced (Fig 1 iv budget):    C={lmg.objective_values['storage']:>7.0f}  "
          f"maxR={lmg.objective_values['max_recreation']:.0f}  "
          f"materialized={lmg.solution.materialized()}")
    print(f"  -> {lmg.summary()}")


def frontier() -> None:
    print("\n=== Spec grid on a DC-like synthetic workload (paper §5.1) ===")
    wl = generate(dc_like(150, seed=7))
    g = wl.graph
    mca = optimize(g, OptimizeSpec.problem(1))
    spt = optimize(g, OptimizeSpec.problem(2))
    c0, r0 = mca.objective_value, mca.objective_values["sum_recreation"]
    print(f"  {'spec':22s} {'storage(GB)':>12s} {'sum rec(GB)':>12s} {'max rec(GB)':>12s}")
    rows = [
        ("P1 min C", mca),
        ("P2 min each R", spt),
        ("P3 β=1.1·Cmin", optimize(g, OptimizeSpec.problem(3, beta=c0 * 1.1))),
        ("P3 β=1.5·Cmin", optimize(g, OptimizeSpec.problem(3, beta=c0 * 1.5))),
        ("P6 θ=2·maxSPT", optimize(g, OptimizeSpec.problem(
            6, theta=spt.objective_values["max_recreation"] * 2))),
        ("LAST α=2", optimize(g, OptimizeSpec.heuristic("last", alpha=2.0))),
        ("GitH w=20", optimize(g, OptimizeSpec.heuristic("gith", window=20,
                                                         max_depth=20))),
    ]
    for name, res in rows:
        v = res.objective_values
        print(f"  {name:22s} {v['storage']/1e9:12.3f} "
              f"{v['sum_recreation']/1e9:12.3f} {v['max_recreation']/1e9:12.4f}")
    # the paper's headline: ~1.1x storage slack cuts Σ-recreation enormously
    lmg = optimize(g, OptimizeSpec.problem(3, beta=c0 * 1.1))
    print(f"  -> P3 at 1.1x MCA storage reduces Σ-recreation "
          f"{r0 / lmg.objective_value:.1f}x vs MCA")


def tensors() -> None:
    print("\n=== Repository on real tensor payloads ===")
    import tempfile
    rng = np.random.RandomState(0)
    payload = {"w": rng.randn(256, 256).astype(np.float32)}
    with tempfile.TemporaryDirectory() as d:
        repo = Repository(d)
        vids = [repo.commit(payload, message="base")]
        for i in range(5):
            payload = {"w": payload["w"].copy()}
            payload["w"][i * 10 : i * 10 + 8] += 1.0  # localized edit
            vids.append(repo.commit(payload, message=f"edit {i}"))
        repo.tag("release-1")          # immutable pointer at the main tip
        repo.branch("hotfix", at=vids[2])
        store = repo.store
        print(f"  6 versions on 'main' + tag + branch, "
              f"{store.storage_bytes()/1e3:.1f} KB stored "
              f"(full would be ~{6 * 256 * 256 * 4 / 1e3:.0f} KB uncompressed)")
        sla = store.cost_model.phi_full(300_000, 300_000) * 2
        stats = repo.repack(OptimizeSpec.problem(6, theta=sla))
        print(f"  repack(P6 θ=SLA): storage {stats['before']['storage_bytes']/1e3:.1f} "
              f"-> {stats['after']['storage_bytes']/1e3:.1f} KB, "
              f"max restore {stats['after']['max_recreation_s']*1e3:.2f} ms "
              f"(solver={stats['optimize']['solver']})")
        w = repo.checkout("release-1")["w"]
        assert np.array_equal(w, payload["w"]), "checkout mismatch!"
        print("  checkout('release-1') verified byte-identical ✓")
        # batched checkout: one plan over the storage graph, shared chain
        # prefixes decoded once, bit-identical to sequential checkouts
        trees = repo.checkout_many(vids)
        assert np.array_equal(trees[-1]["w"], payload["w"])
        d01 = repo.diff(vids[0], "release-1")
        print(f"  checkout_many({len(vids)} versions) in one plan ✓ "
              f"(cache: {store.materializer.stats()['hits']} hits); "
              f"diff base..release-1: {d01.summary()}")


if __name__ == "__main__":
    figure1()
    frontier()
    tensors()
