"""Quickstart: the paper end to end in two minutes (CPU).

1. Reproduces the paper's Figure 1 worked example exactly (storage graphs,
   costs, solver outputs).
2. Builds a synthetic versioned-dataset workload (paper §5.1), runs every
   solver, and prints the storage/recreation frontier.
3. Commits real tensor payloads to a VersionStore, repacks with LMG/MP, and
   verifies checkouts are byte-identical.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    VersionGraph, dc_like, generate, git_heuristic, last_tree,
    local_move_greedy, minimum_storage_tree, modified_prim,
    shortest_path_tree, zipf_weights,
)
from repro.store import VersionStore, flatten_payload


def figure1() -> None:
    print("=== Paper Figure 1 ===")
    g = VersionGraph(5, directed=True)
    for i, (s, r) in enumerate(
        [(10000, 10000), (10100, 10100), (9700, 9700), (9800, 9800), (10120, 10120)], 1
    ):
        g.set_materialization(i, s, r)
    g.set_delta(1, 2, 200, 350)
    g.set_delta(1, 3, 1000, 3000)
    g.set_delta(2, 4, 50, 200)
    g.set_delta(3, 5, 800, 2500)
    g.set_delta(2, 5, 200, 550)

    spt = shortest_path_tree(g)
    mca = minimum_storage_tree(g)
    print(f"  store-everything (Fig 1 ii):   C={spt.storage_cost():>7.0f}  "
          f"maxR={spt.max_recreation():.0f}")
    print(f"  min-storage MCA  (Fig 1 iii):  C={mca.storage_cost():>7.0f}  "
          f"maxR={mca.max_recreation():.0f}")
    lmg = local_move_greedy(g, budget=20150)
    print(f"  balanced (Fig 1 iv budget):    C={lmg.storage_cost():>7.0f}  "
          f"maxR={lmg.max_recreation():.0f}  materialized={lmg.materialized()}")


def frontier() -> None:
    print("\n=== Solver frontier on a DC-like synthetic workload (paper §5.1) ===")
    wl = generate(dc_like(150, seed=7))
    g = wl.graph
    mca = minimum_storage_tree(g)
    spt = shortest_path_tree(g)
    c0, r0 = mca.storage_cost(), mca.sum_recreation()
    print(f"  {'solver':14s} {'storage(GB)':>12s} {'sum rec(GB)':>12s} {'max rec(GB)':>12s}")
    rows = [
        ("MCA", mca), ("SPT", spt),
        ("LMG 1.1x", local_move_greedy(g, c0 * 1.1)),
        ("LMG 1.5x", local_move_greedy(g, c0 * 1.5)),
        ("MP θ=2·spt", modified_prim(g, spt.max_recreation() * 2)),
        ("LAST α=2", last_tree(g, 2.0)),
        ("GitH w=20", git_heuristic(g, window=20, max_depth=20)),
    ]
    for name, sol in rows:
        print(f"  {name:14s} {sol.storage_cost()/1e9:12.3f} "
              f"{sol.sum_recreation()/1e9:12.3f} {sol.max_recreation()/1e9:12.4f}")
    # the paper's headline: ~1.1x storage slack cuts Σ-recreation enormously
    lmg = local_move_greedy(g, c0 * 1.1)
    print(f"  -> LMG at 1.1x MCA storage reduces Σ-recreation "
          f"{r0 / lmg.sum_recreation():.1f}x vs MCA")


def tensors() -> None:
    print("\n=== VersionStore on real tensor payloads ===")
    import tempfile
    rng = np.random.RandomState(0)
    payload = {"w": rng.randn(256, 256).astype(np.float32)}
    with tempfile.TemporaryDirectory() as d:
        store = VersionStore(d)
        vids = [store.commit(payload, message="base")]
        for i in range(5):
            payload = {"w": payload["w"].copy()}
            payload["w"][i * 10 : i * 10 + 8] += 1.0  # localized edit
            vids.append(store.commit(payload, parents=[vids[-1]]))
        print(f"  6 versions, {store.storage_bytes()/1e3:.1f} KB stored "
              f"(full would be ~{6 * 256 * 256 * 4 / 1e3:.0f} KB uncompressed)")
        stats = store.repack("mp", theta=store.cost_model.phi_full(300_000, 300_000) * 2)
        print(f"  repack(MP): storage {stats['before']['storage_bytes']/1e3:.1f} "
              f"-> {stats['after']['storage_bytes']/1e3:.1f} KB, "
              f"max restore {stats['after']['max_recreation_s']*1e3:.2f} ms")
        w = store.checkout(vids[-1])["w"]
        assert np.array_equal(w, payload["w"]), "checkout mismatch!"
        print("  checkout verified byte-identical ✓")
        # batched checkout: one plan over the storage graph, shared chain
        # prefixes decoded once, bit-identical to sequential checkouts
        trees = store.checkout_many(vids)
        assert np.array_equal(trees[-1]["w"], payload["w"])
        print(f"  checkout_many({len(vids)} versions) in one plan ✓ "
              f"(cache: {store.materializer.stats()['hits']} hits)")


if __name__ == "__main__":
    figure1()
    frontier()
    tensors()
