"""End-to-end dataset serving: commits interleaved with reads staying warm.

The scenario the append-aware cache discipline exists for — a service
answering checkout traffic for a hot set of dataset versions while a
writer keeps committing new ones.  A commit only *appends* to the storage
graph, so under the per-entry chain-fingerprint discipline (the store
default) it invalidates nothing the readers are using: the hot set stays
warm across every write, and the materializer's ``invalidations`` counter
stays at zero.  A ``repack``, which rewrites chains wholesale, still
purges everything — the demo ends with one to show both sides.

Run:  PYTHONPATH=src python examples/serve_dataset.py
"""

import asyncio
import tempfile

import numpy as np

from repro.core import OptimizeSpec
from repro.store.repository import Repository


async def run(repo: Repository) -> None:
    rng = np.random.RandomState(0)
    async with repo.serve(readers=4, fsck_interval_s=0.25) as svc:
        # seed a hot set of versions and warm them
        tree = {"x": rng.randn(128, 32).astype(np.float32)}
        await svc.commit(tree, message="v0")
        for i in range(7):
            tree = {"x": tree["x"] + rng.randn(128, 32).astype(np.float32) * 0.1}
            await svc.commit(tree, message=f"v{i + 1}")
        hot = [1, 3, 5, 8]
        await svc.checkout_many(hot)

        # interleave: every round commits a fresh version, then re-reads the
        # hot set -- which stays served from cache across the writes
        for round_no in range(5):
            await svc.commit(
                {"x": rng.randn(128, 32).astype(np.float32)},
                message=f"append {round_no}",
            )
            await svc.checkout_many(hot)

        stats = svc.stats()
        store = stats["store"]
        c = stats["counters"]
        print(
            f"[interleave] {c['requests.commit']} commits between reads: "
            f"{c['checkout.warm_hits']} warm hits, "
            f"{store['invalidations']} invalidations, "
            f"{store['purges']} purges"
        )
        assert store["invalidations"] == 0 and store["purges"] == 0

        # background fsck has been sweeping the growing graph meanwhile
        await svc.fsck()
        print(
            f"[fsck] {svc.metrics.counter('fsck.sweeps')} sweep(s), "
            f"{svc.metrics.counter('fsck.findings')} finding(s)"
        )

        # a repack rewrites chains -> the cache purges wholesale, and the
        # next reads rebuild from the re-optimized storage
        await svc.repack(OptimizeSpec.problem(2))
        trees = await svc.checkout_many(hot)
        assert all(t["x"].shape == (128, 32) for t in trees)
        purges = svc.stats()["store"]["purges"]
        print(f"[repack] storage re-optimized, cache purges={purges}")
        assert purges >= 1

        lat = svc.metrics.track("latency.checkout")
        print(
            f"[latency] {lat['count']} checkouts: "
            f"p50 {lat['p50_ms']} ms, p99 {lat['p99_ms']} ms"
        )


def main() -> None:
    with tempfile.TemporaryDirectory() as root:
        repo = Repository(root)
        asyncio.run(run(repo))
        repo.close()
    print("OK ✓")


if __name__ == "__main__":
    main()
