"""End-to-end dataset serving: commits interleaved with reads staying warm.

The scenario the append-aware cache discipline exists for — a service
answering checkout traffic for a hot set of dataset versions while a
writer keeps committing new ones.  A commit only *appends* to the storage
graph, so under the per-entry chain-fingerprint discipline (the store
default) it invalidates nothing the readers are using: the hot set stays
warm across every write, and the materializer's ``invalidations`` counter
stays at zero.  A ``repack``, which rewrites chains wholesale, still
purges everything — the demo ends with one to show both sides.

The whole run executes under ``repro.obs.tracing()``: every request is
traced end to end (enqueue → queue wait → batch fold → decode → device
launches), the service's ``TradeoffMonitor`` samples the
storage/recreation tradeoff on every commit and repack, and the demo
finishes by printing the tradeoff snapshot plus a per-span summary and
writing a Perfetto-loadable Chrome trace of everything it just did.

Run:  PYTHONPATH=src python examples/serve_dataset.py [--trace-out PATH]
"""

import argparse
import asyncio
import os
import tempfile

import numpy as np

from repro import obs
from repro.core import OptimizeSpec
from repro.store.repository import Repository


async def run(repo: Repository) -> None:
    rng = np.random.RandomState(0)
    async with repo.serve(readers=4, fsck_interval_s=0.25) as svc:
        # seed a hot set of versions and warm them
        tree = {"x": rng.randn(128, 32).astype(np.float32)}
        await svc.commit(tree, message="v0")
        for i in range(7):
            tree = {"x": tree["x"] + rng.randn(128, 32).astype(np.float32) * 0.1}
            await svc.commit(tree, message=f"v{i + 1}")
        hot = [1, 3, 5, 8]
        await svc.checkout_many(hot)

        # interleave: every round commits a fresh version, then re-reads the
        # hot set -- which stays served from cache across the writes
        for round_no in range(5):
            await svc.commit(
                {"x": rng.randn(128, 32).astype(np.float32)},
                message=f"append {round_no}",
            )
            await svc.checkout_many(hot)

        stats = svc.stats()
        store = stats["store"]
        c = stats["counters"]
        print(
            f"[interleave] {c['requests.commit']} commits between reads: "
            f"{c['checkout.warm_hits']} warm hits, "
            f"{store['invalidations']} invalidations, "
            f"{store['purges']} purges"
        )
        assert store["invalidations"] == 0 and store["purges"] == 0

        # background fsck has been sweeping the growing graph meanwhile
        await svc.fsck()
        print(
            f"[fsck] {svc.metrics.counter('fsck.sweeps')} sweep(s), "
            f"{svc.metrics.counter('fsck.findings')} finding(s)"
        )

        # a repack rewrites chains -> the cache purges wholesale, and the
        # next reads rebuild from the re-optimized storage
        await svc.repack(OptimizeSpec.problem(2))
        trees = await svc.checkout_many(hot)
        assert all(t["x"].shape == (128, 32) for t in trees)
        purges = svc.stats()["store"]["purges"]
        print(f"[repack] storage re-optimized, cache purges={purges}")
        assert purges >= 1

        lat = svc.metrics.track("latency.checkout")
        print(
            f"[latency] {lat['count']} checkouts: "
            f"p50 {lat['p50_ms']} ms, p99 {lat['p99_ms']} ms"
        )

        # the TradeoffMonitor sampled the storage/recreation tradeoff on
        # every commit and again after the repack (Problems 5/6 objective)
        trade = svc.stats()["tradeoff"]
        latest = trade["latest"]
        print(
            f"[tradeoff] {latest['versions']} versions after "
            f"{trade['samples']} samples: "
            f"{latest['full_objects']} full + {latest['delta_objects']} delta "
            f"objects, access-weighted recreation "
            f"{latest['access_weighted_recreation_s'] * 1e3:.2f} ms"
        )
        print(f"[tradeoff] {repo.store.tradeoff_monitor.describe_drift()}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument(
        "--trace-out",
        default=os.path.join(tempfile.gettempdir(), "serve_dataset_trace.json"),
        help="where to write the Perfetto-loadable Chrome trace",
    )
    args = parser.parse_args()

    with obs.tracing() as tracer:
        with tempfile.TemporaryDirectory() as root:
            repo = Repository(root)
            asyncio.run(run(repo))
            repo.close()

    summary = tracer.summary()
    print(f"[trace] {len(tracer)} spans across {len(summary)} names; top 5:")
    top = sorted(summary.items(), key=lambda kv: -kv[1]["total_s"])[:5]
    for name, s in top:
        print(f"  {name:<22} x{s['count']:<4} {s['total_s'] * 1e3:8.2f} ms")
    obs.chrome_trace(tracer, args.trace_out, process_name="serve_dataset")
    problems = obs.validate_chrome_trace(args.trace_out)
    assert not problems, problems
    print(f"[trace] wrote Perfetto trace to {args.trace_out}")
    print("OK ✓")


if __name__ == "__main__":
    main()
