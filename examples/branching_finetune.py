"""Branch/merge model versioning — the paper's DATAHUB scenario on weights.

Two teams fork a base checkpoint onto *named branches*, fine-tune on
different data, and the branches are merged (model souping) and tagged.
All six states live in one version DAG behind the ``Repository`` facade;
the storage graph is then optimized declaratively — a Problem-3 spec with
``use_access_frequencies=True`` routing the real access counts into the
spec's workload field (paper Fig. 16).

Run:  PYTHONPATH=src python examples/branching_finetune.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core import OptimizeSpec
from repro.models.registry import get_model
from repro.store import Repository
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step
from repro.data.pipeline import SyntheticTokenPipeline


def finetune(bundle, params, *, seed: int, steps: int = 8):
    tcfg = TrainConfig(optimizer=OptimizerConfig(peak_lr=5e-4, warmup_steps=2,
                                                 total_steps=steps))
    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(vocab=bundle.cfg.vocab, seq_len=64,
                                  global_batch=4, seed=seed)
    params = jax.tree.map(jnp.copy, params)  # donation below must not eat the base
    state = {"params": params, "opt": init_opt_state(params), "error_fb": None}
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step_fn(state, batch)
    return state["params"], float(m["loss"])


def main() -> None:
    cfg = ARCHS["minitron-4b"].reduced()
    bundle = get_model(cfg)
    base = bundle.init(jax.random.PRNGKey(0))

    d = tempfile.mkdtemp(prefix="repro_branches_")
    repo = Repository(d)

    v_base = repo.commit(base, message="pretrained base")
    repo.tag("pretrained", at=v_base)
    print(f"v{v_base}: base committed on 'main', tagged 'pretrained'")

    repo.branch("team-a", at="pretrained")
    team_a, loss_a = finetune(bundle, base, seed=1)
    v_a = repo.commit(team_a, branch="team-a", message="team A finetune")
    team_a2, loss_a2 = finetune(bundle, team_a, seed=11)
    v_a2 = repo.commit(team_a2, branch="team-a", message="team A round 2")

    repo.branch("team-b", at="pretrained")
    team_b, loss_b = finetune(bundle, base, seed=2)
    v_b = repo.commit(team_b, branch="team-b", message="team B finetune")

    soup = jax.tree.map(lambda a, b: ((a.astype(jnp.float32)
                                       + b.astype(jnp.float32)) / 2).astype(a.dtype),
                        team_a2, team_b)
    # merge commit: both branch tips as parents, landing on main
    v_soup = repo.commit(soup, parent=["team-a", "team-b"], branch="main",
                         message="soup(A2, B)")
    repo.tag("soup-v1", at=v_soup)
    print(f"version DAG: base->({v_a}->{v_a2}, {v_b})->merge v{v_soup}; "
          f"branches={repo.branches()} tags={repo.tags()}")

    store = repo.store
    full = sum(m.raw_bytes for m in store.log())
    print(f"raw payloads {full/1e6:.1f} MB -> stored {store.storage_bytes()/1e6:.1f} MB "
          f"(delta chains)")

    # what did team A's second round actually touch vs the base?
    dstat = repo.diff("pretrained", "team-a")
    print(f"diff pretrained..team-a: {dstat.summary()}")

    # simulate an access pattern: the soup is served constantly — after the
    # first request the materialization cache serves it from memory
    for _ in range(25):
        repo.checkout("soup-v1")
    repo.checkout("pretrained")
    mstats = store.materializer.stats()
    print(f"serving 26 checkouts: {mstats['hits']} cache hits, "
          f"{mstats['full_decodes']} full decodes + "
          f"{mstats['delta_applies']} delta applies total")

    # declarative workload-aware repack: Problem 3 (min Σ w_i R_i s.t.
    # C ≤ 1.4x current) with the recorded access counts as the workload
    spec = OptimizeSpec.problem(3, beta=store.storage_bytes() * 1.4)
    stats = repo.repack(spec, use_access_frequencies=True)
    print(f"workload-aware repack [{stats['optimize']['solver']}]: Σrestore "
          f"{stats['before']['sum_recreation_s']*1e3:.1f}ms -> "
          f"{stats['after']['sum_recreation_s']*1e3:.1f}ms "
          f"at ≤1.4x storage (gc freed {stats['gc_freed_bytes']/1e6:.1f} MB); "
          f"hot versions prefetched back into the cache")

    # every version still reconstructs exactly
    rec = repo.checkout("soup-v1")
    from repro.store import flatten_payload
    flat_soup = flatten_payload(soup)
    for k, arr in flat_soup.items():
        np.testing.assert_array_equal(rec[k], np.asarray(arr))
    print("soup checkout verified byte-identical ✓")
    repo.close()
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
