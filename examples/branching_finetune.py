"""Branch/merge model versioning — the paper's DATAHUB scenario on weights.

Two teams fork a base checkpoint, fine-tune on different data, and the
branches are merged (model souping).  All six states live in one version
DAG; the storage graph is then optimized with the paper's solvers and the
access-frequency-aware LMG variant (Fig. 16) using real access counts.

Run:  PYTHONPATH=src python examples/branching_finetune.py
"""

import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.models.registry import get_model
from repro.store import VersionStore
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step
from repro.data.pipeline import SyntheticTokenPipeline


def finetune(bundle, params, *, seed: int, steps: int = 8):
    tcfg = TrainConfig(optimizer=OptimizerConfig(peak_lr=5e-4, warmup_steps=2,
                                                 total_steps=steps))
    step_fn = jax.jit(make_train_step(bundle, tcfg), donate_argnums=(0,))
    pipe = SyntheticTokenPipeline(vocab=bundle.cfg.vocab, seq_len=64,
                                  global_batch=4, seed=seed)
    params = jax.tree.map(jnp.copy, params)  # donation below must not eat the base
    state = {"params": params, "opt": init_opt_state(params), "error_fb": None}
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        state, m = step_fn(state, batch)
    return state["params"], float(m["loss"])


def main() -> None:
    cfg = ARCHS["minitron-4b"].reduced()
    bundle = get_model(cfg)
    base = bundle.init(jax.random.PRNGKey(0))

    d = tempfile.mkdtemp(prefix="repro_branches_")
    store = VersionStore(d)

    v_base = store.commit(base, message="pretrained base")
    print(f"v{v_base}: base committed")

    team_a, loss_a = finetune(bundle, base, seed=1)
    v_a = store.commit(team_a, parents=[v_base], message="team A finetune")
    team_a2, loss_a2 = finetune(bundle, team_a, seed=11)
    v_a2 = store.commit(team_a2, parents=[v_a], message="team A round 2")

    team_b, loss_b = finetune(bundle, base, seed=2)
    v_b = store.commit(team_b, parents=[v_base], message="team B finetune")

    soup = jax.tree.map(lambda a, b: ((a.astype(jnp.float32)
                                       + b.astype(jnp.float32)) / 2).astype(a.dtype),
                        team_a2, team_b)
    v_soup = store.commit(soup, parents=[v_a2, v_b], message="soup(A2, B)")
    print(f"version DAG: base->({v_a}->{v_a2}, {v_b})->merge v{v_soup}")

    full = sum(m.raw_bytes for m in store.log())
    print(f"raw payloads {full/1e6:.1f} MB -> stored {store.storage_bytes()/1e6:.1f} MB "
          f"(delta chains)")

    # simulate an access pattern: the soup is served constantly — after the
    # first request the materialization cache serves it from memory
    for _ in range(25):
        store.checkout(v_soup)
    store.checkout(v_base)
    mstats = store.materializer.stats()
    print(f"serving 26 checkouts: {mstats['hits']} cache hits, "
          f"{mstats['full_decodes']} full decodes + "
          f"{mstats['delta_applies']} delta applies total")

    stats = store.repack("lmg", budget=store.storage_bytes() * 1.4,
                         use_access_frequencies=True)
    print(f"workload-aware LMG repack: Σrestore "
          f"{stats['before']['sum_recreation_s']*1e3:.1f}ms -> "
          f"{stats['after']['sum_recreation_s']*1e3:.1f}ms "
          f"at ≤1.4x storage (gc freed {stats['gc_freed_bytes']/1e6:.1f} MB); "
          f"hot versions prefetched back into the cache")

    # every version still reconstructs exactly
    rec = store.checkout(v_soup)
    want_leaves = jax.tree_util.tree_flatten_with_path(soup)[0]
    from repro.store import flatten_payload
    flat_soup = flatten_payload(soup)
    for k, arr in flat_soup.items():
        np.testing.assert_array_equal(rec[k], np.asarray(arr))
    print("soup checkout verified byte-identical ✓")
    shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    main()
