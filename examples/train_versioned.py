"""End-to-end driver: train an LM with versioned fault-tolerant checkpoints.

Demonstrates the full production loop on CPU:
  * train a reduced minicpm-2b (same family/code path as the 2B config;
    pass --big for a ~110M-parameter model if you have the patience);
  * async delta checkpoints every ``--save-every`` steps;
  * a **simulated preemption** mid-run -> synchronous emergency save;
  * restart: elastic restore (params + optimizer + data-iterator state) and
    seamless continuation — losses continue from where they stopped;
  * final repack with the MP solver (Problem 6: min storage subject to the
    restore-latency SLA), then a restore-from-cold verification.

Run:  PYTHONPATH=src python examples/train_versioned.py [--steps 60] [--big]
"""

import argparse
import shutil
import tempfile

import numpy as np

from repro.checkpoint import PreemptionGuard
from repro.launch.train import RunConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--big", action="store_true",
                    help="~110M params (slow on CPU) instead of the tiny config")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_")
    common = dict(
        arch="minicpm-2b",
        reduced=True,
        steps=args.steps,
        seq_len=256 if args.big else 128,
        global_batch=8,
        save_every=10,
        ckpt_dir=ckpt_dir,
        max_restore_cost_s=30.0,
    )

    # ---- phase 1: run until a simulated preemption --------------------------
    print("=== phase 1: train until preemption ===")
    guard = PreemptionGuard()

    class PreemptAt(PreemptionGuard):
        def __init__(self, at):
            super().__init__()
            self.at = at
            self.count = 0

        @property
        def preempted(self):
            self.count += 1
            return self.count >= self.at

    guard = PreemptAt(at=args.steps // 2)
    out1 = train(RunConfig(**common), guard=guard)
    assert out1["preempted"], "expected the simulated preemption to trigger"
    print(f"    -> stopped after {out1['steps_done']} steps, "
          f"emergency checkpoint committed")

    # ---- phase 2: restart and finish ----------------------------------------
    print("=== phase 2: restart (elastic restore) and finish ===")
    out2 = train(RunConfig(**common))
    full_losses = out1["losses"] + out2["losses"]
    print(f"    -> resumed and finished: loss {full_losses[0]:.3f} -> "
          f"{full_losses[-1]:.3f} over {len(full_losses)} steps")
    assert full_losses[-1] < full_losses[0], "loss should decrease end-to-end"

    # ---- phase 3: repack + cold restore --------------------------------------
    print("=== phase 3: repack the checkpoint store (Problem 6 / MP) ===")
    mgr = out2["manager"]
    stats = mgr.repack()
    b, a = stats["before"], stats["after"]
    print(f"    storage {b['storage_bytes']/1e6:7.2f} MB -> {a['storage_bytes']/1e6:7.2f} MB")
    print(f"    max restore {b['max_recreation_s']:7.3f} s -> {a['max_recreation_s']:7.3f} s "
          f"(SLA θ=30s)")
    assert a["max_recreation_s"] <= 30.0

    state = mgr.restore(template=_tpl(out2))
    print("    cold restore after repack OK ✓")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


def _tpl(out):
    import jax
    import jax.numpy as jnp
    st = out["final_state"]
    return {
        "params": st["params"],
        "opt": st["opt"],
        "data": {"step": jnp.zeros((), jnp.int32), "epoch": jnp.zeros((), jnp.int32)},
    }


if __name__ == "__main__":
    main()
