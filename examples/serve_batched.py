"""Batched serving with a contiguous KV cache (prefill + decode steps).

Runs the reduced qwen3-32b family (GQA + qk-norm) through the ServeEngine:
batched prefill, greedy decode, throughput report.  The identical bundle
functions lower at pod scale in the dry-run's prefill_32k/decode_32k cells.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.registry import get_model
from repro.serving.engine import ServeEngine


def main() -> None:
    cfg = ARCHS["qwen3-32b"].reduced()
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    B, prompt, new = 4, 48, 24
    engine = ServeEngine(bundle, params, max_len=prompt + new, batch=B)
    rng = np.random.RandomState(0)
    batch = {"tokens": rng.randint(0, cfg.vocab, (B, prompt)).astype(np.int32)}
    res = engine.generate(batch, max_new_tokens=new)
    print(f"[serve] batch={B} prompt={prompt} -> {res.steps} new tokens/request")
    print(f"[serve] prefill {res.prefill_s*1e3:.1f} ms, "
          f"decode {res.decode_s/max(res.steps,1)*1e3:.1f} ms/step, "
          f"{res.steps*B/max(res.decode_s,1e-9):.1f} tok/s")
    print(f"[serve] greedy determinism check:", end=" ")
    res2 = ServeEngine(bundle, params, max_len=prompt + new, batch=B).generate(
        batch, max_new_tokens=new)
    assert np.array_equal(res.tokens, res2.tokens)
    print("OK ✓")


if __name__ == "__main__":
    main()
