"""Batched + coalesced dataset serving through the DatasetService tier.

Builds a small branching version history, then fires concurrent checkout
traffic at the async service front-end to show its two de-duplication
mechanisms doing their job:

* **coalescing** — eight simultaneous requests for the same ref share one
  materialization (the ``checkout.coalesced`` counter accounts for 7 of 8);
* **batching** — requests for distinct refs arriving within the batching
  window fold into a single ``checkout_many`` plan, so storage chains
  shared between the refs decode once.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import asyncio
import tempfile

import numpy as np

from repro.store.repository import Repository


def build_history(repo: Repository, versions: int = 12) -> None:
    rng = np.random.RandomState(0)
    tree = {"w": rng.randn(64, 48).astype(np.float32)}
    repo.commit(tree, message="root")
    for i in range(versions - 1):
        tree = {"w": tree["w"].copy()}
        tree["w"][i % 60 : i % 60 + 2] += rng.randn(2, 48).astype(np.float32)
        repo.commit(tree, message=f"step {i}")
    repo.tag("release", at=repo.resolve("main"))


async def serve(repo: Repository) -> None:
    async with repo.serve(readers=4, batch_window_s=0.005) as svc:
        # 8 concurrent requests for one ref -> one decode, 7 coalesced
        trees = await asyncio.gather(
            *(svc.checkout("release") for _ in range(8))
        )
        assert all(np.array_equal(t["w"], trees[0]["w"]) for t in trees)
        c = svc.metrics.counter
        print(
            f"[coalesce] 8 concurrent checkout('release') -> "
            f"{c('checkout.coalesced')} coalesced, "
            f"{c('checkout.batched_refs')} materialized"
        )

        # distinct refs inside one batching window -> one folded plan
        before = c("checkout.batches")
        await svc.checkout_many([2, 4, 6, 8, 10])
        print(
            f"[batch] 5 distinct refs -> "
            f"{c('checkout.batches') - before} checkout_many dispatch(es)"
        )

        # the same refs again: all warm now, served from cache
        await svc.checkout_many([2, 4, 6, 8, 10])
        print(
            f"[warm] repeat pass: {c('checkout.warm_hits')} warm hits, "
            f"{c('checkout.warm_misses')} misses overall"
        )

        lat = svc.metrics.track("latency.checkout")
        print(
            f"[latency] {lat['count']} checkouts: "
            f"p50 {lat['p50_ms']} ms, p99 {lat['p99_ms']} ms"
        )


def main() -> None:
    with tempfile.TemporaryDirectory() as root:
        repo = Repository(root)
        build_history(repo)
        asyncio.run(serve(repo))
        repo.close()
    print("OK ✓")


if __name__ == "__main__":
    main()
