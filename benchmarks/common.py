"""Shared benchmark infrastructure: workloads, timing, CSV rows."""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

from repro.core import (
    StorageSolution,
    WorkloadSpec,
    dc_like,
    generate,
    lc_like,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable[[], Any], *, repeats: int = 1) -> tuple:
    t0 = time.monotonic()
    out = None
    for _ in range(repeats):
        out = fn()
    dt = (time.monotonic() - t0) / repeats
    return out, dt * 1e6  # µs


@functools.lru_cache(maxsize=None)
def workload(kind: str, n: int, seed: int = 0, directed: bool = True):
    if kind == "dc":
        spec = dc_like(n, seed=seed, directed=directed)
    elif kind == "lc":
        spec = lc_like(n, seed=seed, directed=directed)
    elif kind == "bf":  # many small forks of one repo (Bootstrap-forks shape)
        spec = WorkloadSpec(
            commits=n, branch_interval=2, branch_prob=0.9, branch_limit=6,
            branch_length=2, reveal_hops=6, edit_rate=0.02, seed=seed,
            directed=directed,
        )
    elif kind == "lf":  # few large forks with deep histories (Linux-forks)
        spec = WorkloadSpec(
            commits=n, branch_interval=10, branch_prob=0.5, branch_limit=2,
            branch_length=30, reveal_hops=12, edit_rate=0.01,
            init_blocks=1200, seed=seed, directed=directed,
        )
    else:
        raise ValueError(kind)
    return generate(spec)


def random_cost_graph(n: int, avg_deg: int = 20, seed: int = 0):
    """Cost-only version graph for solver *runtime* scaling (paper Fig 17
    times the algorithms on precomputed deltas; measuring real block-set
    deltas at n≥800 is generator-bound, not solver-bound)."""
    import random

    from repro.core import VersionGraph

    rng = random.Random(seed)
    g = VersionGraph(n, directed=True)
    for i in g.versions():
        size = rng.uniform(1e6, 2e6)
        g.set_materialization(i, size, size)
    for i in range(2, n + 1):
        parent = rng.randint(1, i - 1)
        d = rng.uniform(1e3, 1e5)
        g.set_delta(parent, i, d, d)           # derivation edge
        for _ in range(avg_deg - 1):           # extra revealed deltas
            j = rng.randint(1, n)
            if j != i:
                d = rng.uniform(1e3, 5e5)
                g.set_delta(j, i, d, d)
    return g


def frontier_point(sol: StorageSolution) -> Dict[str, float]:
    return {
        "storage": sol.storage_cost(),
        "sum_rec": sol.sum_recreation(),
        "max_rec": sol.max_recreation(),
    }
