"""Serving-path checkout benchmark: cold vs warm, batched vs sequential.

Models the DataHub serving workload the materialization subsystem exists
for: many requests hitting hot versions of a branching store under a
zipfian access distribution.  Three measurements:

* **cold** — every checkout decodes its full storage chain
  (``cache_budget_bytes=0`` disables the FlatTree cache);
* **warm** — the same workload through the byte-budgeted
  ``MaterializationCache`` after one warmup pass, with the measured hit
  rate (acceptance: warm ≥ 10× faster than cold at n=1k);
* **batch** — ``checkout_many`` on chain-sharing batches vs the same
  requests issued sequentially, both uncached, isolating the planner's
  shared-prefix deduplication (acceptance: batched strictly faster).

The store is built with bounded-depth delta chains (a commit whose chain
would exceed ``max_chain`` is stored full), mirroring what any repack with a
recreation bound produces — without it, cold checkouts of a 1k-linear chain
would measure pathology, not the serving path.

Results append to ``BENCH_serving_checkout.json`` in the repo root (one
entry per run, accumulating history across PRs) and the suite registers as
``serving_checkout`` in ``benchmarks.run`` with a small n for CI smoke.

Run standalone:
    PYTHONPATH=src python -m benchmarks.serving_checkout [--n 1000]
        [--requests 600] [--zipf 1.1] [--batch-size 8] [--batches 12]
"""

from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.store import VersionStore

from .common import Row

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_serving_checkout.json"
DEFAULT_N = 1_000
DEFAULT_REQUESTS = 600
DEFAULT_ZIPF_S = 1.1

# serving replicas flush access counts rarely; keep metadata writes out of
# the per-checkout latency being measured
_NO_FLUSH = 1 << 30


def build_store(
    root: str,
    n: int,
    *,
    seed: int = 0,
    shape=(48, 64),
    branch_every: int = 4,
    max_chain: int = 12,
    cache_budget_bytes: int = 256 << 20,
) -> VersionStore:
    """Branching history of ``n`` versions with bounded-depth delta chains."""
    rng = np.random.RandomState(seed)
    store = VersionStore(
        root,
        cache_budget_bytes=cache_budget_bytes,
        access_flush_every=_NO_FLUSH,
    )
    payload = {"w": rng.randn(*shape).astype(np.float32)}
    vids = [store.commit(payload, message="root")]
    payloads = {vids[0]: payload}
    depth = {vids[0]: 0}
    for i in range(n - 1):
        if i % branch_every == branch_every - 1:
            parent = int(vids[rng.randint(0, len(vids))])
        else:
            parent = vids[-1]
        p = {"w": payloads[parent]["w"].copy()}
        row = rng.randint(0, shape[0] - 2)
        p["w"][row : row + 2] += rng.randn(2, shape[1]).astype(np.float32)
        if depth[parent] >= max_chain:
            vid = store.commit(p, message=f"c{i} (chain cap)")
            depth[vid] = 0
        else:
            vid = store.commit(p, parents=[parent], message=f"c{i}")
            # commit may still have stored it full if the delta was larger
            depth[vid] = (
                depth[parent] + 1
                if store.versions[vid].stored_base is not None
                else 0
            )
        payloads[vid] = p
        vids.append(vid)
    return store


def zipf_requests(
    vids: List[int], requests: int, *, s: float, seed: int
) -> List[int]:
    """Zipfian workload: rank r of a seeded permutation gets p ∝ 1/r^s."""
    rng = np.random.RandomState(seed)
    ranked = rng.permutation(vids)
    p = 1.0 / np.arange(1, len(ranked) + 1) ** s
    p /= p.sum()
    return [int(v) for v in rng.choice(ranked, size=requests, p=p)]


def _timed_checkouts(store: VersionStore, workload: List[int]) -> float:
    t0 = time.monotonic()
    for vid in workload:
        store.checkout(vid)
    return time.monotonic() - t0


def chain_sharing_batches(
    store: VersionStore, *, batch_size: int, batches: int, seed: int
) -> List[List[int]]:
    """Batches drawn from single storage chains (maximal prefix sharing)."""
    rng = np.random.RandomState(seed)
    by_depth = sorted(
        store.versions, key=lambda v: -_chain_len(store, v)
    )
    out = []
    for i in range(batches):
        tip = by_depth[i % max(1, len(by_depth) // 4)]
        chain = []
        v: Optional[int] = tip
        while v is not None and len(chain) < batch_size:
            chain.append(v)
            v = store.versions[v].stored_base
        while len(chain) < batch_size:  # top up from the tip's neighborhood
            chain.append(int(rng.choice(list(store.versions))))
        out.append(chain)
    return out


def _chain_len(store: VersionStore, vid: int) -> int:
    n, v = 0, vid
    while v is not None:
        v = store.versions[v].stored_base
        n += 1
    return n


def run_benchmark(
    n: int = DEFAULT_N,
    *,
    requests: int = DEFAULT_REQUESTS,
    zipf_s: float = DEFAULT_ZIPF_S,
    batch_size: int = 8,
    batches: int = 12,
    cold_requests: Optional[int] = None,
    seed: int = 0,
) -> Dict:
    cold_requests = cold_requests or min(requests, 200)
    with tempfile.TemporaryDirectory(prefix="repro_serving_") as d:
        store = build_store(d, n, seed=seed)
        vids = sorted(store.versions)
        workload = zipf_requests(vids, requests, s=zipf_s, seed=seed + 1)

        # cold: no FlatTree cache, every request re-decodes its chain
        cold_store = VersionStore(
            d, cache_budget_bytes=0, access_flush_every=_NO_FLUSH
        )
        cold_s = _timed_checkouts(cold_store, workload[:cold_requests])
        cold_ms = cold_s / cold_requests * 1e3

        # warm: one warmup pass, then the measured pass on a hot cache
        warm_store = VersionStore(d, access_flush_every=_NO_FLUSH)
        _timed_checkouts(warm_store, workload)  # warmup
        s0 = warm_store.materializer.stats()
        warm_s = _timed_checkouts(warm_store, workload)
        s1 = warm_store.materializer.stats()
        warm_ms = warm_s / requests * 1e3
        hits = s1["hits"] - s0["hits"]
        misses = s1["misses"] - s0["misses"]
        hit_rate = hits / max(1, hits + misses)

        # batched vs sequential on chain-sharing batches, both uncached —
        # what remains is exactly the planner's shared-prefix dedup
        batch_list = chain_sharing_batches(
            store, batch_size=batch_size, batches=batches, seed=seed + 2
        )
        batch_store = VersionStore(
            d, cache_budget_bytes=0, access_flush_every=_NO_FLUSH
        )
        t0 = time.monotonic()
        for b in batch_list:
            batch_store.checkout_many(b)
        batched_s = time.monotonic() - t0
        t0 = time.monotonic()
        for b in batch_list:
            for v in b:
                batch_store.checkout(v)
        sequential_s = time.monotonic() - t0

        result = {
            "n": n,
            "requests": requests,
            "cold_requests": cold_requests,
            "zipf_s": zipf_s,
            "storage_bytes": store.storage_bytes(),
            "cold_ms_per_checkout": round(cold_ms, 4),
            "warm_ms_per_checkout": round(warm_ms, 4),
            "warm_speedup": round(cold_ms / max(warm_ms, 1e-9), 2),
            "hit_rate": round(hit_rate, 4),
            "cache_bytes": warm_store.materializer.cache.current_bytes,
            "batch": {
                "batch_size": batch_size,
                "batches": batches,
                "batched_s": round(batched_s, 4),
                "sequential_s": round(sequential_s, 4),
                "speedup": round(sequential_s / max(batched_s, 1e-9), 2),
            },
        }
    return result


def record(result: Dict, path: Path = BENCH_PATH) -> None:
    history = []
    if path.exists():
        history = json.loads(path.read_text())
    history.append(
        {"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"), "result": result}
    )
    path.write_text(json.dumps(history, indent=2) + "\n")


def serving_checkout(n: int = 150, requests: int = 240) -> Iterable[Row]:
    """``benchmarks.run`` suite adapter: small n so the orchestrator and the
    CI smoke stay fast; the standalone CLI runs the full 1k-version check."""
    result = run_benchmark(n, requests=requests, batches=6)
    record(result)
    yield Row(
        name=f"serving/cold_checkout/n{n}",
        us_per_call=result["cold_ms_per_checkout"] * 1e3,
        derived=f"storage_mb={result['storage_bytes']/1e6:.1f}",
    )
    yield Row(
        name=f"serving/warm_checkout/n{n}",
        us_per_call=result["warm_ms_per_checkout"] * 1e3,
        derived=(
            f"speedup={result['warm_speedup']};hit_rate={result['hit_rate']}"
        ),
    )
    yield Row(
        name=f"serving/batch{result['batch']['batch_size']}/n{n}",
        us_per_call=result["batch"]["batched_s"]
        / max(result["batch"]["batches"], 1)
        * 1e6,
        derived=f"vs_sequential={result['batch']['speedup']}x",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--zipf", type=float, default=DEFAULT_ZIPF_S)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    result = run_benchmark(
        args.n,
        requests=args.requests,
        zipf_s=args.zipf,
        batch_size=args.batch_size,
        batches=args.batches,
        seed=args.seed,
    )
    record(result)
    print(json.dumps(result, indent=2))
    ok_warm = result["warm_speedup"] >= 10.0
    ok_batch = result["batch"]["speedup"] > 1.0
    print(
        f"# warm {result['warm_speedup']}x vs cold "
        f"({'OK' if ok_warm else 'BELOW 10x'}), "
        f"batched {result['batch']['speedup']}x vs sequential "
        f"({'OK' if ok_batch else 'NOT FASTER'})"
    )


if __name__ == "__main__":
    main()
